//! Table 1 reproduction: standard vs sequence-aware patched kernel across
//! the Batch = 1 shape grid, on the metadata-enabled path — plus the §5.1
//! contrast column for the internal-heuristic (no metadata) path.

use crate::heuristics::DispatchPath;
use crate::planner::Planner;
use crate::sim::Simulator;
use crate::util::prng::Rng;
use crate::util::table::{speedup, us, Align, Table};
use crate::workload::shapes::{table1_grid, Table1Row};

use super::ab::ab_median_us;

/// One measured Table-1 cell.
#[derive(Debug, Clone)]
pub struct Table1Cell {
    pub row: Table1Row,
    pub standard_us: f64,
    pub patched_us: f64,
    /// Both policies re-measured on the internal-heuristic (no-metadata)
    /// dispatch path — §5.1's contrast experiment.
    pub internal_standard_us: f64,
    pub internal_patched_us: f64,
    pub standard_splits: usize,
    pub patched_splits: usize,
}

impl Table1Cell {
    /// Upstream-over-patched latency ratio for this cell.
    pub fn speedup(&self) -> f64 {
        self.standard_us / self.patched_us
    }

    /// A/B speedup when neither side has precomputed metadata.
    pub fn internal_speedup(&self) -> f64 {
        self.internal_standard_us / self.internal_patched_us
    }
}

/// Run the full Table-1 A/B on the simulator.
pub fn run(sim: &Simulator, replays: usize, seed: u64) -> Vec<Table1Cell> {
    let mut rng = Rng::new(seed);
    let mut std_planner = Planner::standard();
    let mut pat_planner = Planner::sequence_aware();
    let mut cells = Vec::new();
    for row in table1_grid() {
        let shape = row.shape();
        let md_std = std_planner.plan(&shape).metadata;
        let md_pat = pat_planner.plan(&shape).metadata;
        let (standard_us, patched_us) = ab_median_us(sim, &md_std, &md_pat, replays, &mut rng);
        // §5.1: without precomputed metadata the same policies only yield
        // ~1.00-1.05x — re-run the A/B with both sides on the internal
        // dispatch path.
        let (internal_standard_us, internal_patched_us) = ab_median_us(
            sim,
            &md_std.with_path(DispatchPath::InternalHeuristic),
            &md_pat.with_path(DispatchPath::InternalHeuristic),
            replays,
            &mut rng,
        );
        cells.push(Table1Cell {
            row,
            standard_us,
            patched_us,
            internal_standard_us,
            internal_patched_us,
            standard_splits: md_std.num_splits,
            patched_splits: md_pat.num_splits,
        });
    }
    cells
}

/// Render the paper-format table (with paper columns for comparison).
pub fn render(cells: &[Table1Cell]) -> String {
    let mut t = Table::new(&[
        "L_K", "H_KV", "Std (µs)", "Patched (µs)", "Speedup", "Paper Std", "Paper Pat",
        "Paper Spd", "s std→pat", "No-meta Spd",
    ])
    .align(&[Align::Right; 10]);
    for c in cells {
        t.row(&[
            c.row.l_k.to_string(),
            c.row.h_kv.to_string(),
            us(c.standard_us),
            us(c.patched_us),
            speedup(c.speedup()),
            us(c.row.paper_standard_us),
            us(c.row.paper_patched_us),
            speedup(c.row.paper_speedup()),
            format!("{}→{}", c.standard_splits, c.patched_splits),
            speedup(c.internal_speedup()),
        ]);
    }
    t.render()
}

/// Shape checks the reproduction must satisfy (used by tests and the
/// bench's exit status): wins exactly where the paper wins, ~1.2x there,
/// 1.00x controls, internal path ≤ 1.07x.
pub fn verify(cells: &[Table1Cell]) -> Result<(), String> {
    for c in cells {
        let is_target = c.row.l_k == 512 && c.row.h_kv <= 2;
        let sp = c.speedup();
        if is_target {
            if !(1.10..=1.35).contains(&sp) {
                return Err(format!(
                    "target cell L_K={} H_KV={}: speedup {sp:.3} outside [1.10, 1.35]",
                    c.row.l_k, c.row.h_kv
                ));
            }
            let int_sp = c.internal_speedup();
            if !(0.99..=1.07).contains(&int_sp) {
                return Err(format!(
                    "internal-path speedup {int_sp:.3} should be ~1.00-1.05 (got L_K={} H_KV={})",
                    c.row.l_k, c.row.h_kv
                ));
            }
        } else if !(0.99..=1.01).contains(&sp) {
            return Err(format!(
                "control cell L_K={} H_KV={}: speedup {sp:.3} should be 1.00x",
                c.row.l_k, c.row.h_kv
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table1_shape() {
        let cells = run(&Simulator::h100(), 101, 42);
        assert_eq!(cells.len(), 18);
        verify(&cells).unwrap();
        // Splits chosen: 1→3 at the target cells, unchanged elsewhere
        // within the guard region.
        for c in &cells {
            if c.row.l_k == 512 && c.row.h_kv <= 2 {
                assert_eq!((c.standard_splits, c.patched_splits), (1, 3));
            } else {
                assert_eq!(c.standard_splits, c.patched_splits);
            }
        }
    }

    #[test]
    fn render_includes_paper_columns() {
        let cells = run(&Simulator::h100(), 21, 1);
        let out = render(&cells);
        assert!(out.contains("Paper Spd"));
        assert!(out.contains("1→3"));
    }
}
