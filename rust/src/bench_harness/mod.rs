//! Bench harnesses: one per paper table/figure, plus the timing substrate.
//!
//! Each harness produces the same rows/series the paper reports, printed
//! through `util::table` and returned as data so EXPERIMENTS.md and the
//! `rust/benches/*` entrypoints share one implementation.
//!
//! * [`timer`]      — warmup/sample wall-clock bencher (criterion is
//!                    unavailable offline),
//! * [`ab`]         — interleaved A/B measurement on the simulator (the
//!                    paper's CUDA-Graph-replay methodology),
//! * [`table1`]     — Table 1: standard vs patched across the shape grid,
//! * [`ucurve`]     — Figure 3: the s = 1..64 split sweep,
//! * [`regression`] — §5.3: the 160-config no-regression sweep.

pub mod ab;
pub mod ablations;
pub mod regression;
pub mod table1;
pub mod timer;
pub mod ucurve;

pub use ab::ab_median_us;
pub use timer::{BenchResult, Bencher};
