//! Figure 3 reproduction: the extended split sweep (s = 1..64) for the
//! boundary case Batch = 1, L_K = 512, H_KV = 1, D = 128 with precomputed
//! scheduler metadata, plus an ASCII rendering of the curve.

use crate::heuristics::tiles::DecodeShape;
use crate::planner::Planner;
use crate::sim::Simulator;
use crate::util::prng::Rng;
use crate::util::table::{us, Align, Table};

use super::ab::median_us;

/// The split counts Figure 3 samples (aot.py compiles the same set).
pub const SWEEP_SPLITS: [usize; 12] = [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64];

/// One sweep point.
#[derive(Debug, Clone, Copy)]
pub struct UcurvePoint {
    pub num_splits: usize,
    pub latency_us: f64,
    pub active_ctas: usize,
    pub occupancy: f64,
}

/// Run the sweep on the simulator.
pub fn run(sim: &Simulator, replays: usize, seed: u64) -> Vec<UcurvePoint> {
    let shape = DecodeShape::llama70b_tp8(1, 512);
    let planner = Planner::standard(); // forced plans: policy is bypassed
    let mut rng = Rng::new(seed);
    SWEEP_SPLITS
        .iter()
        .map(|&s| {
            let md = planner.plan_forced(&shape, s).metadata;
            let timing = sim.kernel(&md);
            UcurvePoint {
                num_splits: s,
                latency_us: median_us(sim, &md, replays, &mut rng),
                active_ctas: timing.active_ctas,
                occupancy: timing.occupancy,
            }
        })
        .collect()
}

/// Paper-format table.
pub fn render_table(points: &[UcurvePoint]) -> String {
    let mut t = Table::new(&["num_splits", "Latency (µs)", "Active CTAs", "SM occupancy"])
        .align(&[Align::Right; 4]);
    for p in points {
        t.row(&[
            p.num_splits.to_string(),
            us(p.latency_us),
            p.active_ctas.to_string(),
            format!("{:.1}%", p.occupancy * 100.0),
        ]);
    }
    t.render()
}

/// ASCII plot of the curve (latency vs split count), the Figure-3 visual.
pub fn render_plot(points: &[UcurvePoint], height: usize) -> String {
    assert!(height >= 4 && !points.is_empty());
    let lo = points.iter().map(|p| p.latency_us).fold(f64::INFINITY, f64::min);
    let hi = points.iter().map(|p| p.latency_us).fold(0.0f64, f64::max);
    let span = (hi - lo).max(1e-9);
    let mut rows = vec![String::new(); height];
    for (r, row) in rows.iter_mut().enumerate() {
        let level = hi - span * r as f64 / (height - 1) as f64;
        row.push_str(&format!("{:>7.2} |", level));
        for p in points {
            let cell = (hi - p.latency_us) / span * (height - 1) as f64;
            let hit = (cell.round() as usize) == r;
            row.push_str(if hit { "  *  " } else { "     " });
        }
    }
    let mut out = rows.join("\n");
    out.push_str("\n        +");
    out.push_str(&"-".repeat(points.len() * 5));
    out.push_str("\n         ");
    for p in points {
        out.push_str(&format!("{:^5}", p.num_splits));
    }
    out.push_str("\n         (num_splits; latency µs on the left)\n");
    out
}

/// Shape checks for Figure 3: s = 1 well above the plateau, shallow
/// plateau, s = 3 within ~5% of the best (the paper's "under ~2%" claim,
/// loosened for the simulator's plateau tilt — see EXPERIMENTS.md).
pub fn verify(points: &[UcurvePoint]) -> Result<(), String> {
    let p1 = points.iter().find(|p| p.num_splits == 1).ok_or("missing s=1")?;
    let p3 = points.iter().find(|p| p.num_splits == 3).ok_or("missing s=3")?;
    let plateau: Vec<&UcurvePoint> = points.iter().filter(|p| p.num_splits >= 2).collect();
    let best = plateau.iter().map(|p| p.latency_us).fold(f64::INFINITY, f64::min);
    let worst = plateau.iter().map(|p| p.latency_us).fold(0.0f64, f64::max);
    if p1.latency_us <= worst {
        return Err(format!(
            "s=1 ({:.2}) must sit above the plateau (max {:.2})",
            p1.latency_us, worst
        ));
    }
    if (p1.latency_us - worst) / p1.latency_us < 0.10 {
        return Err("drop from s=1 into the plateau should be steep (>10%)".into());
    }
    if (worst - best) / best > 0.08 {
        return Err(format!("plateau spread {:.1}% too wide", (worst - best) / best * 100.0));
    }
    if (p3.latency_us - best) / best > 0.06 {
        return Err(format!(
            "s=3 ({:.2}) should be within ~5% of the best ({best:.2})",
            p3.latency_us
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_reproduces_figure3_shape() {
        let pts = run(&Simulator::h100(), 51, 3);
        assert_eq!(pts.len(), SWEEP_SPLITS.len());
        verify(&pts).unwrap();
        // Occupancy rises with splits up to nblk = 4 CTAs.
        assert_eq!(pts[0].active_ctas, 1);
        assert!(pts.last().unwrap().active_ctas == 4);
    }

    #[test]
    fn plot_renders() {
        let pts = run(&Simulator::h100(), 11, 5);
        let plot = render_plot(&pts, 12);
        assert!(plot.contains('*'));
        assert!(plot.contains("num_splits"));
        let table = render_table(&pts);
        assert!(table.contains("SM occupancy"));
    }
}
