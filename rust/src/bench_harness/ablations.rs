//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! A1 — **Hardware scale** (§2.2: "This static threshold overlooks the
//!      hardware scale of H100"): the same A/B across device profiles
//!      (A100, H100 PCIe, H100 SXM) — the win exists wherever the grid
//!      underfills the part, and grows with SM count.
//! A2 — **Boundary sweep** (§4.1): L_K ∈ {128..640} × policy, showing
//!      unchanged behavior below the bucket, the win inside it, and the
//!      efficiency-loop takeover beyond it.
//! A3 — **pack_gqa layout** (§3.1 knob): packed vs unpacked grids across
//!      H_KV, quantifying why the evolved candidates kept pack_gqa=True.
//! A4 — **sm_margin** (§3.1 knob): reserved-SM sweep at the boundary
//!      shape, showing why the search settled on margin 0.
//! A5 — **Policy ladder** (§4.1/§5.2 future work): standard → conservative
//!      patch → learned table → evolved genome, TPOT on the chat panel.
//!
//! Every launch here is planned through [`crate::planner`]: device
//! profiles come from `DeviceProfile` presets and knob sweeps are planner
//! configurations, not hand-assembled metadata.

use crate::evolve::{Evaluator, Genome};
use crate::heuristics::extended::{ExtendedPolicy, TuneConfig};
use crate::heuristics::tiles::DecodeShape;
use crate::heuristics::{SequenceAwarePolicy, StandardPolicy};
use crate::planner::{DeviceProfile, Planner, PlannerBuilder};
use crate::sim::Simulator;
use crate::util::table::{speedup, us, Align, Table};

/// A1: boundary-cell speedup across GPU generations.
pub fn hardware_scale() -> Table {
    let shape = DecodeShape::llama70b_tp8(1, 512);
    let mut t = Table::new(&["GPU", "SMs", "Std (µs)", "Patched (µs)", "Speedup", "Occupancy s=1"])
        .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Right]);
    for device in [DeviceProfile::A100_SXM, DeviceProfile::H100_PCIE, DeviceProfile::H100_SXM] {
        let sim = Simulator::for_profile(&device);
        let mut std_p = PlannerBuilder::policy(StandardPolicy).device(device).build();
        let mut pat_p = PlannerBuilder::policy(SequenceAwarePolicy).device(device).build();
        let a = sim.kernel_us(&std_p.plan(&shape).metadata);
        let b = sim.kernel_us(&pat_p.plan(&shape).metadata);
        t.row(&[
            device.name.to_string(),
            device.num_sms.to_string(),
            us(a),
            us(b),
            speedup(a / b),
            format!("{:.1}%", 100.0 / device.num_sms as f64),
        ]);
    }
    t
}

/// A2: the §4.1 boundary sweep (which L_K change behavior, and how).
pub fn boundary_sweep(sim: &Simulator) -> Table {
    let mut std_p = Planner::standard();
    let mut pat_p = Planner::sequence_aware();
    let mut t = Table::new(&["L_K", "nblk", "s std", "s pat", "Std (µs)", "Patched (µs)", "Speedup"])
        .align(&[Align::Right; 7]);
    for l_k in [128usize, 256, 384, 448, 512, 576, 640, 1024] {
        let shape = DecodeShape::llama70b_tp8(1, l_k);
        let md_std = std_p.plan(&shape).metadata;
        let md_pat = pat_p.plan(&shape).metadata;
        let a = sim.kernel_us(&md_std);
        let b = sim.kernel_us(&md_pat);
        t.row(&[
            l_k.to_string(),
            shape.nblk().to_string(),
            md_std.num_splits.to_string(),
            md_pat.num_splits.to_string(),
            us(a),
            us(b),
            speedup(a / b),
        ]);
    }
    t
}

/// A3: pack_gqa on/off across H_KV at the boundary length.
pub fn pack_gqa_ablation(sim: &Simulator) -> Table {
    let mut packed = PlannerBuilder::policy(SequenceAwarePolicy).pack_gqa(true).build();
    let mut unpacked = PlannerBuilder::policy(SequenceAwarePolicy).pack_gqa(false).build();
    let mut t = Table::new(&["H_KV", "tiles packed", "tiles unpacked", "Packed (µs)", "Unpacked (µs)", "Packed win"])
        .align(&[Align::Right; 6]);
    for h_kv in [1usize, 2, 4, 8] {
        let shape = DecodeShape::decode(1, 512, 8 * h_kv, h_kv, 128);
        let md_p = packed.plan(&shape).metadata;
        let md_u = unpacked.plan(&shape).metadata;
        let a = sim.kernel_us(&md_p);
        let b = sim.kernel_us(&md_u);
        t.row(&[
            h_kv.to_string(),
            shape.total_mblocks(true).to_string(),
            shape.total_mblocks(false).to_string(),
            us(a),
            us(b),
            speedup(b / a),
        ]);
    }
    t
}

/// A4: sm_margin sweep — at the paper's boundary shape (2 CTAs: reserving
/// SMs costs nothing, which is why the evolved candidates kept margin 0)
/// and at a near-saturation grid (128 CTAs: any margin forces a second
/// wave — the cost the knob trades against).
pub fn sm_margin_ablation(sim: &Simulator) -> Table {
    let boundary = DecodeShape::llama70b_tp8(1, 512);
    // 16 tiles x s=8 = 128 CTAs: one wave on a full H100, two with margin.
    let dense = DecodeShape::decode(2, 8192, 64, 8, 128);
    let mut t = Table::new(&["sm_margin", "SMs left", "Boundary 2-CTA (µs)", "Dense 128-CTA (µs)"])
        .align(&[Align::Right; 4]);
    for margin in [0usize, 4, 8, 16, 32, 64] {
        let mut planner = PlannerBuilder::policy(SequenceAwarePolicy).sm_margin(margin).build();
        let md_b = planner.plan(&boundary).metadata;
        let md_d = planner.plan_forced(&dense, 8).metadata;
        t.row(&[
            margin.to_string(),
            planner.device().sm_budget(margin).to_string(),
            us(sim.kernel_us(&md_b)),
            us(sim.kernel_us(&md_d)),
        ]);
    }
    t
}

/// A5: the policy ladder on the §3.1 chat panel (TPOT).
pub fn policy_ladder(sim: &Simulator) -> Table {
    let evaluator = Evaluator::new(sim.clone());
    let upstream = evaluator.panel_tpot_us(&Genome::upstream());

    let panel_tpot = |planner: &mut Planner| {
        let mut total = 0.0;
        let mut steps = 0usize;
        for &(prompt, n) in &crate::workload::ChatWorkload::evolution_panel() {
            for step in 0..n {
                let shape = DecodeShape::llama70b_tp8(1, prompt + step + 1);
                total += sim.kernel_us(&planner.plan(&shape).metadata);
                steps += 1;
            }
        }
        total / steps as f64
    };

    let t_pat = panel_tpot(&mut Planner::sequence_aware());
    let probe = Planner::standard();
    let table_policy = ExtendedPolicy::tune(&TuneConfig::default(), |shape, s| {
        sim.kernel_us(&probe.plan_forced(shape, s).metadata)
    });
    let n_buckets = table_policy.len();
    let t_ext = panel_tpot(&mut PlannerBuilder::policy(table_policy).build());
    let t_fig1 = evaluator.panel_tpot_us(&Genome::figure1());

    let mut t = Table::new(&["Policy", "Chat-panel TPOT (µs)", "vs upstream"])
        .align(&[Align::Left, Align::Right, Align::Right]);
    t.row(&["upstream (premature guard)".into(), us(upstream), speedup(1.0)]);
    t.row(&["paper patch (Fig 2, conservative)".into(), us(t_pat), speedup(upstream / t_pat)]);
    t.row(&[
        format!("learned table ({n_buckets} buckets, future work)"),
        us(t_ext),
        speedup(upstream / t_ext),
    ]);
    t.row(&["evolved Python (Fig 1, aggressive)".into(), us(t_fig1), speedup(upstream / t_fig1)]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hardware_scale_win_everywhere_low_tile() {
        // The occupancy hole exists on every modern part; speedup column
        // should show >1.1x on all three GPUs.
        let t = hardware_scale();
        let render = t.render();
        assert!(render.contains("A100"));
        assert!(render.contains("H100-SXM5"));
        assert!(!render.contains("| 1.00x |"), "every row should win:\n{render}");
    }

    #[test]
    fn boundary_sweep_transitions() {
        let sim = Simulator::h100();
        let t = boundary_sweep(&sim).render();
        // Below the bucket: both s=1. Inside: 1 vs 3. Beyond: equal again.
        assert!(t.contains("1.00x"));
        assert!(t.contains("1.2"));
    }

    #[test]
    fn ladder_is_monotone() {
        let sim = Simulator::h100();
        let t = policy_ladder(&sim);
        // Structural check only (values asserted in module tests):
        assert_eq!(t.render().lines().count(), 2 + 4);
    }

    #[test]
    fn margin_hurts_at_scale() {
        let sim = Simulator::h100();
        let out = sm_margin_ablation(&sim).render();
        assert!(out.contains("128"));
    }
}
