//! Interleaved A/B measurement on the simulator.
//!
//! §5 methodology: "CUDA Graph replay and A/B-interleaved timing within
//! the Python bindings to measure pure kernel execution times". We keep
//! the interleaving and the median-of-replays reduction, swapping graph
//! replay for the calibrated latency model plus its measurement-noise
//! stream — so the harness *methodology* is the paper's even though the
//! substrate is simulated (DESIGN.md §Substitutions).

use crate::heuristics::SchedulerMetadata;
use crate::sim::Simulator;
use crate::util::prng::Rng;
use crate::util::stats::median;

/// Interleaved A/B: alternate noisy "replays" of the two schedules and
/// return (median_a_us, median_b_us).
pub fn ab_median_us(
    sim: &Simulator,
    a: &SchedulerMetadata,
    b: &SchedulerMetadata,
    replays: usize,
    rng: &mut Rng,
) -> (f64, f64) {
    assert!(replays > 0);
    let mut ta = Vec::with_capacity(replays);
    let mut tb = Vec::with_capacity(replays);
    for _ in 0..replays {
        // Interleave: A then B within each replay round, sharing the noise
        // stream's drift exactly like back-to-back graph launches.
        ta.push(sim.kernel_us_noisy(a, rng));
        tb.push(sim.kernel_us_noisy(b, rng));
    }
    (median(&ta), median(&tb))
}

/// Median of noisy replays of a single schedule.
pub fn median_us(sim: &Simulator, md: &SchedulerMetadata, replays: usize, rng: &mut Rng) -> f64 {
    let samples: Vec<f64> = (0..replays).map(|_| sim.kernel_us_noisy(md, rng)).collect();
    median(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::tiles::DecodeShape;
    use crate::planner::Planner;

    #[test]
    fn medians_converge_to_model() {
        let sim = Simulator::h100();
        let planner = Planner::standard();
        let shape = DecodeShape::llama70b_tp8(1, 512);
        let a = planner.plan_forced(&shape, 1).metadata;
        let b = planner.plan_forced(&shape, 3).metadata;
        let mut rng = Rng::new(1);
        let (ma, mb) = ab_median_us(&sim, &a, &b, 201, &mut rng);
        let clean_a = sim.kernel_us(&a);
        let clean_b = sim.kernel_us(&b);
        assert!((ma - clean_a).abs() / clean_a < 0.01);
        assert!((mb - clean_b).abs() / clean_b < 0.01);
        assert!(ma > mb, "s=1 must be slower at the boundary bucket");
    }

    #[test]
    fn deterministic_given_seed() {
        let sim = Simulator::h100();
        let md = Planner::standard()
            .plan_forced(&DecodeShape::llama70b_tp8(1, 256), 1)
            .metadata;
        let x = median_us(&sim, &md, 51, &mut Rng::new(9));
        let y = median_us(&sim, &md, 51, &mut Rng::new(9));
        assert_eq!(x, y);
    }
}
