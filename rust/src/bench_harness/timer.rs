//! Wall-clock micro-bencher (criterion substitute).
//!
//! Warmup then fixed-count sampling, reporting mean ± 95% CI and
//! percentiles. Samples are *per-batch* (each sample times `batch_iters`
//! closure invocations) so sub-µs operations resolve above timer noise.

use std::time::Instant;

use crate::util::stats::Summary;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration latency summary, ns.
    pub per_iter_ns: Summary,
    pub samples: usize,
    pub iters_per_sample: usize,
}

impl BenchResult {
    /// Mean wall time per iteration, nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        self.per_iter_ns.mean
    }

    /// One human-readable result row (name, per-iter stats).
    pub fn report_line(&self) -> String {
        let s = &self.per_iter_ns;
        let (scale, unit) = if s.mean >= 1e6 {
            (1e6, "ms")
        } else if s.mean >= 1e3 {
            (1e3, "µs")
        } else {
            (1.0, "ns")
        };
        format!(
            "{:<44} {:>10.3} {unit}/iter (p50 {:.3}, p99 {:.3}, n={})",
            self.name,
            s.mean / scale,
            s.p50 / scale,
            s.p99 / scale,
            s.n
        )
    }
}

/// The bencher.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub samples: usize,
    pub batch_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 50, samples: 60, batch_iters: 20 }
    }
}

impl Bencher {
    /// Configuration for expensive closures (PJRT executions).
    pub fn heavy() -> Bencher {
        Bencher { warmup_iters: 3, samples: 15, batch_iters: 1 }
    }

    /// Time `f`, returning per-iteration stats. The closure's return value
    /// is black-boxed to keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.batch_iters {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64;
            per_iter.push(dt / self.batch_iters as f64);
        }
        BenchResult {
            name: name.to_string(),
            per_iter_ns: Summary::of(&per_iter),
            samples: self.samples,
            iters_per_sample: self.batch_iters,
        }
    }

    /// Bench and print the one-line report (the benches' main loop).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, f: F) -> BenchResult {
        let r = self.bench(name, f);
        println!("{}", r.report_line());
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { warmup_iters: 2, samples: 10, batch_iters: 100 };
        let r = b.bench("noop-ish", || std::hint::black_box(3u64).wrapping_mul(7));
        assert!(r.mean_ns() >= 0.0);
        assert_eq!(r.per_iter_ns.n, 10);
    }

    #[test]
    fn slower_closure_measures_slower() {
        let b = Bencher { warmup_iters: 2, samples: 15, batch_iters: 5 };
        let fast = b.bench("fast", || 1u64);
        let slow = b.bench("slow", || {
            let mut acc = 0u64;
            for i in 0..5_000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            acc
        });
        assert!(slow.mean_ns() > fast.mean_ns() * 3.0);
    }

    #[test]
    fn report_line_scales_units() {
        let mk = |mean_ns: f64| BenchResult {
            name: "x".into(),
            per_iter_ns: Summary::of(&[mean_ns]),
            samples: 1,
            iters_per_sample: 1,
        };
        assert!(mk(500.0).report_line().contains("ns/iter"));
        assert!(mk(5_000.0).report_line().contains("µs/iter"));
        assert!(mk(5_000_000.0).report_line().contains("ms/iter"));
    }
}
