//! §5.3 reproduction: the 160-configuration safety/regression sweep.
//!
//! Batch ∈ {1,2,4,8} × L_K ∈ {128..8192} × H_KV ∈ {1,2,4,8,32}, standard
//! vs sequence-aware, asserting the paper's claim: no configuration below
//! 0.99x, wins only at L_K = 512 with H_KV ∈ {1, 2} (low-tile cells).

use crate::heuristics::tiles::DecodeShape;
use crate::planner::Planner;
use crate::sim::Simulator;
use crate::util::prng::Rng;
use crate::util::table::{speedup, us, Align, Table};
use crate::workload::shapes::regression_grid;

use super::ab::ab_median_us;

/// One sweep cell.
#[derive(Debug, Clone)]
pub struct RegressionCell {
    pub shape: DecodeShape,
    pub standard_us: f64,
    pub patched_us: f64,
}

impl RegressionCell {
    /// Upstream-over-patched latency ratio (> 1 means patched is faster).
    pub fn speedup(&self) -> f64 {
        self.standard_us / self.patched_us
    }
}

/// Sweep summary.
#[derive(Debug, Clone)]
pub struct RegressionSummary {
    pub total: usize,
    pub wins: usize,
    pub unchanged: usize,
    pub regressions: usize,
    pub min_speedup: f64,
    pub max_speedup: f64,
}

/// Run the §5.3 sweep: every config cell, interleaved replays.
pub fn run(sim: &Simulator, replays: usize, seed: u64) -> Vec<RegressionCell> {
    let mut rng = Rng::new(seed);
    let mut std_planner = Planner::standard();
    let mut pat_planner = Planner::sequence_aware();
    regression_grid()
        .into_iter()
        .map(|shape| {
            let md_std = std_planner.plan(&shape).metadata;
            let md_pat = pat_planner.plan(&shape).metadata;
            let (standard_us, patched_us) = ab_median_us(sim, &md_std, &md_pat, replays, &mut rng);
            RegressionCell { shape, standard_us, patched_us }
        })
        .collect()
}

/// Collapse per-cell results into the sweep-level verdict counts.
pub fn summarize(cells: &[RegressionCell]) -> RegressionSummary {
    let mut s = RegressionSummary {
        total: cells.len(),
        wins: 0,
        unchanged: 0,
        regressions: 0,
        min_speedup: f64::INFINITY,
        max_speedup: 0.0,
    };
    for c in cells {
        let sp = c.speedup();
        s.min_speedup = s.min_speedup.min(sp);
        s.max_speedup = s.max_speedup.max(sp);
        if sp >= 1.05 {
            s.wins += 1;
        } else if sp >= 0.99 {
            s.unchanged += 1;
        } else {
            s.regressions += 1;
        }
    }
    s
}

/// Render only the interesting rows (wins + any regressions) plus the
/// summary — 160 rows of 1.00x would drown the signal.
pub fn render(cells: &[RegressionCell]) -> String {
    let s = summarize(cells);
    let mut t = Table::new(&["Batch", "L_K", "H_KV", "Std (µs)", "Patched (µs)", "Speedup"])
        .align(&[Align::Right; 6]);
    for c in cells {
        let sp = c.speedup();
        if !(0.99..1.05).contains(&sp) {
            t.row(&[
                c.shape.batch.to_string(),
                c.shape.l_k.to_string(),
                c.shape.h_kv.to_string(),
                us(c.standard_us),
                us(c.patched_us),
                speedup(sp),
            ]);
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "{} configs: {} wins (>=1.05x), {} unchanged, {} regressions; speedup range [{:.3}, {:.3}]\n",
        s.total, s.wins, s.unchanged, s.regressions, s.min_speedup, s.max_speedup
    ));
    if !t.is_empty() {
        out.push_str("non-1.00x cells:\n");
        out.push_str(&t.render());
    }
    out
}

/// The paper's §5.3 claims as a checkable predicate.
pub fn verify(cells: &[RegressionCell]) -> Result<(), String> {
    let s = summarize(cells);
    if s.total != 160 {
        return Err(format!("expected 160 configs, got {}", s.total));
    }
    if s.min_speedup < 0.99 {
        return Err(format!("regression found: min speedup {:.3} < 0.99", s.min_speedup));
    }
    for c in cells {
        let sp = c.speedup();
        let expected_win = c.shape.l_k == 512 && c.shape.h_kv <= 2 && c.shape.batch * c.shape.h_kv < 4;
        if expected_win && sp < 1.05 {
            return Err(format!(
                "expected win missing at B={} L_K={} H_KV={}: {sp:.3}",
                c.shape.batch, c.shape.l_k, c.shape.h_kv
            ));
        }
        if !expected_win && sp > 1.05 {
            return Err(format!(
                "unexpected win at B={} L_K={} H_KV={}: {sp:.3} (policy surface wider than paper)",
                c.shape.batch, c.shape.l_k, c.shape.h_kv
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_matches_paper_claims() {
        let cells = run(&Simulator::h100(), 41, 7);
        verify(&cells).unwrap();
        let s = summarize(&cells);
        // Wins: L_K=512, (B, H_KV) with B*H_KV < 4 and H_KV <= 2:
        // (1,1), (1,2), (2,1) — three cells.
        assert_eq!(s.wins, 3, "{s:?}");
        assert_eq!(s.regressions, 0);
    }

    #[test]
    fn render_shows_summary() {
        let cells = run(&Simulator::h100(), 11, 9);
        let out = render(&cells);
        assert!(out.contains("160 configs"));
        assert!(out.contains("0 regressions"));
    }
}
