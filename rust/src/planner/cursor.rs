//! [`PlanCursor`]: the zero-allocation steady-state path for decode
//! planning.
//!
//! Autoregressive decode is monotone — a request's `L_K` grows by exactly
//! one token per step — so the split decision can only change when `L_K`
//! crosses a *decision boundary*: the next nblk bucket edge for bucket-pure
//! policies ([`crate::heuristics::SplitPolicy::decision_horizon`]), or the
//! nearest genome-rule `lk_min`/`lk_max` edge for evolved sources. A
//! cursor pins the current [`CachedDecision`] together with the inclusive
//! `[valid_from_lk, valid_until_lk]` window it holds over, plus the fixed
//! shape fields it was computed for. The steady-state `plan()` is then a
//! range check and a handful of integer compares followed by an in-place
//! metadata stamp — no hashing, no LRU traffic, no allocation. Only a
//! horizon crossing (or a batch/geometry change) falls back to the
//! planner, whose LRU cache remains the cold/irregular-shape path and the
//! cursor's refill source.
//!
//! Soundness (property-tested in `tests/planner_properties.rs` over
//! exhaustive `L_K` sweeps for every registry policy and the figure-1
//! genome): `cursor.plan(planner, shape)` is byte-identical to
//! `planner.plan(shape)` for every shape, because the window is computed
//! by `PlanSource::validity_window` — the same source that makes the
//! decision — and refills route through the planner's own decision path.

use crate::heuristics::tiles::DecodeShape;

use super::cache::CachedDecision;
use super::{LaunchPlan, Planner};

/// The shape fields a cursor's decision is pinned to (everything except
/// `l_k`, which the validity window covers).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
struct CursorKey {
    batch: usize,
    l_q: usize,
    h_q: usize,
    h_kv: usize,
    d: usize,
}

impl CursorKey {
    #[inline]
    fn of(shape: &DecodeShape) -> CursorKey {
        CursorKey {
            batch: shape.batch,
            l_q: shape.l_q,
            h_q: shape.h_q,
            h_kv: shape.h_kv,
            d: shape.d,
        }
    }
}

/// Hit/refill counters for one cursor (the decode hot-path bench reports
/// these next to the planner's `CacheStats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CursorStats {
    /// Steady-state plans served from the pinned decision.
    pub hits: u64,
    /// Horizon crossings / key changes that recomputed through the planner.
    pub refills: u64,
}

impl CursorStats {
    /// Fold another cursor's counters into this one.
    pub fn merge(&mut self, other: CursorStats) {
        self.hits += other.hits;
        self.refills += other.refills;
    }
}

/// An incremental plan cursor over one decode trajectory (or one live
/// decode bucket in a serving engine). Create via [`Planner::cursor`] or
/// [`PlanCursor::new`]; it carries no reference to the planner, so one
/// planner can refill any number of cursors (the engine keeps one per
/// active decode-batch size).
#[derive(Debug, Clone, Default)]
pub struct PlanCursor {
    key: CursorKey,
    /// `None` until the first refill; the empty window below keeps the
    /// steady-state check a plain range test either way.
    decision: Option<CachedDecision>,
    /// Inclusive `l_k` window the decision holds over. Starts empty
    /// (`from > until`) so the first call always refills.
    valid_from_lk: usize,
    valid_until_lk: usize,
    /// Identity of the planner that refilled the pinned decision
    /// (`Planner::id`). Checked on the hit path: a cursor handed a
    /// *different* planner (other policy, device, or knobs) refills
    /// instead of silently serving the previous planner's decision.
    planner_id: u64,
    hits: u64,
    refills: u64,
}

impl PlanCursor {
    /// An unpinned cursor (first `plan` refills it).
    pub fn new() -> PlanCursor {
        PlanCursor {
            key: CursorKey::default(),
            decision: None,
            valid_from_lk: 1,
            valid_until_lk: 0,
            planner_id: 0, // no planner has id 0: first call always refills
            hits: 0,
            refills: 0,
        }
    }

    /// Plan one decode launch. Steady state (the decode loop: same
    /// planner, same batch, `l_k` inside the window) stamps the pinned
    /// decision onto the exact shape without touching the planner;
    /// anything else — horizon crossing, shape-key change, or a different
    /// planner — refills through `planner` (LRU cache, then the
    /// policy/genome).
    ///
    /// Guaranteed element-wise identical to [`Planner::plan`] for every
    /// shape, including across planner switches (the pinned decision is
    /// keyed to the refilling planner's identity).
    // pallas-lint: no_alloc
    #[inline]
    pub fn plan(&mut self, planner: &mut Planner, shape: &DecodeShape) -> LaunchPlan {
        if let Some(decision) = self.decision {
            if shape.l_k >= self.valid_from_lk
                && shape.l_k <= self.valid_until_lk
                && self.planner_id == planner.id
                && self.key == CursorKey::of(shape)
            {
                self.hits += 1;
                return planner.materialize(shape, &decision);
            }
        }
        self.refill(planner, shape)
    }

    #[cold]
    fn refill(&mut self, planner: &mut Planner, shape: &DecodeShape) -> LaunchPlan {
        let (decision, from, until) = planner.cursor_refill(shape);
        self.key = CursorKey::of(shape);
        self.decision = Some(decision);
        self.valid_from_lk = from;
        self.valid_until_lk = until;
        self.planner_id = planner.id;
        self.refills += 1;
        planner.materialize(shape, &decision)
    }

    /// The batch size this cursor is currently pinned to (0 before the
    /// first refill) — how the decode scheduler indexes its cursor set.
    pub fn batch(&self) -> usize {
        self.key.batch
    }

    /// The query length this cursor is currently pinned to (0 before the
    /// first refill): 1 for decode cursors, the chunk length for
    /// mixed-wave cursors. The scheduler indexes on `(batch, l_q)` so
    /// chunk waves never thrash the decode cursors.
    pub fn l_q(&self) -> usize {
        self.key.l_q
    }

    /// The inclusive `l_k` window of the pinned decision, if any.
    pub fn valid_window(&self) -> Option<(usize, usize)> {
        self.decision.as_ref().map(|_| (self.valid_from_lk, self.valid_until_lk))
    }

    /// Hit/refill counters since construction.
    pub fn stats(&self) -> CursorStats {
        CursorStats { hits: self.hits, refills: self.refills }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evolve::genome::Genome;
    use crate::heuristics::sequence_aware::BOUNDARY_SPLIT;
    use crate::planner::PlannerBuilder;

    #[test]
    fn steady_state_hits_inside_the_bucket() {
        let mut planner = Planner::sequence_aware();
        let mut cursor = planner.cursor();
        for l_k in 385..=512usize {
            let plan = cursor.plan(&mut planner, &DecodeShape::llama70b_tp8(1, l_k));
            assert_eq!(plan.num_splits(), BOUNDARY_SPLIT, "l_k={l_k}");
            assert_eq!(plan.metadata.shape.l_k, l_k, "exact shape stamped");
        }
        let stats = cursor.stats();
        assert_eq!(stats.refills, 1, "{stats:?}");
        assert_eq!(stats.hits, 127, "{stats:?}");
        assert_eq!(cursor.valid_window(), Some((385, 512)));
        // The cursor shields the LRU entirely after its one refill.
        assert_eq!(planner.cache_stats().misses, 1);
        assert_eq!(planner.cache_stats().hits, 0);
    }

    #[test]
    fn bucket_edge_refills_and_matches_plan() {
        let mut planner = Planner::sequence_aware();
        let mut oracle = Planner::sequence_aware();
        let mut cursor = planner.cursor();
        for l_k in [384usize, 385, 512, 513, 514] {
            let shape = DecodeShape::llama70b_tp8(1, l_k);
            assert_eq!(cursor.plan(&mut planner, &shape), oracle.plan(&shape), "l_k={l_k}");
        }
        // 384 | 385..512 | 513.. are three windows: three refills.
        assert_eq!(cursor.stats().refills, 3);
        assert_eq!(cursor.stats().hits, 2);
    }

    #[test]
    fn batch_change_invalidates_the_key() {
        let mut planner = Planner::sequence_aware();
        let mut oracle = Planner::sequence_aware();
        let mut cursor = planner.cursor();
        for (batch, l_k) in [(1usize, 512usize), (2, 512), (1, 512), (4, 512)] {
            let shape = DecodeShape::llama70b_tp8(batch, l_k);
            assert_eq!(cursor.plan(&mut planner, &shape), oracle.plan(&shape), "b={batch}");
        }
        // Every batch flip is a key mismatch: 4 refills, 0 hits.
        assert_eq!(cursor.stats().refills, 4);
    }

    #[test]
    fn non_monotone_lk_respects_the_lower_window_edge() {
        // Jumping backwards below valid_from must refill, not serve the
        // stale bucket's decision.
        let mut planner = Planner::sequence_aware();
        let mut oracle = Planner::sequence_aware();
        let mut cursor = planner.cursor();
        for l_k in [500usize, 384, 500, 100, 512] {
            let shape = DecodeShape::llama70b_tp8(1, l_k);
            assert_eq!(cursor.plan(&mut planner, &shape), oracle.plan(&shape), "l_k={l_k}");
        }
    }

    #[test]
    fn genome_rule_edges_bound_the_window() {
        // figure1: seqlen<256 → s=16, else (<=512, batch 1) → s=12. The
        // window at l_k=200 must stop at 255 even though the nblk bucket
        // (129..256) runs to 256.
        let mut planner = PlannerBuilder::genome(Genome::figure1()).build();
        let mut cursor = planner.cursor();
        assert_eq!(cursor.plan(&mut planner, &DecodeShape::llama70b_tp8(1, 200)).num_splits(), 16);
        assert_eq!(cursor.valid_window(), Some((129, 255)));
        assert_eq!(cursor.plan(&mut planner, &DecodeShape::llama70b_tp8(1, 255)).num_splits(), 16);
        assert_eq!(cursor.stats().hits, 1);
        // 256 crosses the rule edge AND the bucket edge: refill to s=12.
        assert_eq!(cursor.plan(&mut planner, &DecodeShape::llama70b_tp8(1, 256)).num_splits(), 12);
        assert_eq!(cursor.stats().refills, 2);
    }

    #[test]
    fn switching_planners_refills_instead_of_serving_stale_decisions() {
        // The same cursor driven by two different planners must never
        // leak one planner's pinned decision to the other: the standard
        // policy says s=1 in the boundary bucket, sequence-aware says
        // s=3, and both windows are the identical [385, 512].
        let mut std_p = Planner::standard();
        let mut seq_p = Planner::sequence_aware();
        let mut cursor = PlanCursor::new();
        let shape = |l_k| DecodeShape::llama70b_tp8(1, l_k);
        assert_eq!(cursor.plan(&mut std_p, &shape(400)).num_splits(), 1);
        assert_eq!(cursor.plan(&mut seq_p, &shape(450)).num_splits(), BOUNDARY_SPLIT);
        assert_eq!(cursor.plan(&mut std_p, &shape(460)).num_splits(), 1);
        assert_eq!(cursor.stats().refills, 3, "every planner switch refills");
        // Same planner again: back to steady-state hits.
        assert_eq!(cursor.plan(&mut std_p, &shape(461)).num_splits(), 1);
        assert_eq!(cursor.stats().hits, 1);
        // A clone is a fresh identity (fresh cache): it also refills.
        let mut cloned = std_p.clone();
        assert_eq!(cursor.plan(&mut cloned, &shape(462)).num_splits(), 1);
        assert_eq!(cursor.stats().refills, 4);
    }

    #[test]
    fn fresh_cursor_reports_empty_window() {
        let cursor = PlanCursor::new();
        assert_eq!(cursor.valid_window(), None);
        assert_eq!(cursor.batch(), 0);
        assert_eq!(cursor.l_q(), 0);
        assert_eq!(cursor.stats(), CursorStats::default());
    }
}
