//! The plan cache: a small LRU keyed by shape bucket.
//!
//! The serving scheduler re-plans *every decode step* while the KV length
//! grows by one token per step — but every built-in policy's decision only
//! depends on the shape through `nblk = ceil(L_K / 128)` and the work-tile
//! count, so 128 consecutive steps share one decision. The cache exploits
//! that: keys hold the nblk bucket (or the exact `L_K` for sources that
//! are not bucket-pure, e.g. evolved genomes with arbitrary `L_K` ranges),
//! and a one-entry fast path keeps the steady-state hit at a handful of
//! field compares — cheaper than re-running even the guard path of the
//! heuristic, and far cheaper than the long-context efficiency loop. (The
//! true steady-state serving path is cheaper still: a
//! [`crate::planner::PlanCursor`] pins one decision plus its `l_k`
//! horizon and bypasses even the hash; this cache is the cursor's refill
//! source and the cold/irregular-shape path.)
//!
//! Eviction is exact LRU via a monotonic tick with an O(capacity) scan on
//! overflow; capacities are small (default 512) and overflow is rare in
//! steady state, so the simple scan beats a linked-list LRU's constant
//! overhead on the hit path.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Cache key: every field of the decode shape that can influence a plan.
/// `lk_key` is the nblk bucket for bucket-pure sources, the exact `L_K`
/// otherwise (a single planner never mixes the two interpretations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct PlanKey {
    pub batch: usize,
    pub l_q: usize,
    pub h_q: usize,
    pub h_kv: usize,
    pub d: usize,
    pub lk_key: usize,
}

/// The shape-bucket-invariant part of a plan (everything except the exact
/// shape, which is re-attached on materialization).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct CachedDecision {
    pub num_splits: usize,
    pub pack_gqa: bool,
    pub sm_margin: usize,
    pub effective_splits: usize,
    pub grid_ctas: usize,
    pub waves: usize,
    pub occupancy: f64,
    pub combine_estimate_us: f64,
}

/// Counters exposed through `Planner::cache_stats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub capacity: usize,
}

impl CacheStats {
    /// Hits over total lookups (0 when never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// FxHash-style multiply-xor hasher: the SipHash default costs more than
/// the whole cached decision is worth on a 6-word key.
#[derive(Default)]
pub(crate) struct FxHasher {
    hash: u64,
}

const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl Hasher for FxHasher {
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.add(b as u64);
        }
    }

    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }

    fn finish(&self) -> u64 {
        self.hash
    }
}

impl FxHasher {
    #[inline]
    fn add(&mut self, v: u64) {
        self.hash = (self.hash.rotate_left(5) ^ v).wrapping_mul(FX_SEED);
    }
}

type FxBuild = BuildHasherDefault<FxHasher>;

struct Slot {
    decision: CachedDecision,
    tick: u64,
}

/// The LRU itself. Not thread-safe by design: the planner owns it behind
/// `&mut self`, which keeps the steady-state hit lock-free.
pub(crate) struct PlanCache {
    map: HashMap<PlanKey, Slot, FxBuild>,
    /// One-entry fast path for the decode-loop steady state.
    last: Option<(PlanKey, CachedDecision)>,
    tick: u64,
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    /// An empty cache holding at most `capacity` decisions.
    pub fn new(capacity: usize) -> PlanCache {
        assert!(capacity > 0, "use Option<PlanCache>::None to disable caching");
        PlanCache {
            map: HashMap::with_capacity_and_hasher(capacity.min(1024), FxBuild::default()),
            last: None,
            tick: 0,
            capacity,
            hits: 0,
            misses: 0,
        }
    }

    #[inline]
    /// Look up a decision (promotes the entry to most-recent).
    pub fn get(&mut self, key: &PlanKey) -> Option<CachedDecision> {
        if let Some((k, d)) = &self.last {
            if k == key {
                self.hits += 1;
                return Some(*d);
            }
        }
        self.tick += 1;
        match self.map.get_mut(key) {
            Some(slot) => {
                slot.tick = self.tick;
                let d = slot.decision;
                self.last = Some((*key, d));
                self.hits += 1;
                Some(d)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a decision, evicting the least-recent entry when full.
    pub fn insert(&mut self, key: PlanKey, decision: CachedDecision) {
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            // The one-entry fast path serves hits without touching the
            // map's ticks; fold that recency back in before choosing a
            // victim, or the hottest entry would look least-recently-used.
            if let Some((last_key, _)) = self.last {
                self.tick += 1;
                if let Some(slot) = self.map.get_mut(&last_key) {
                    slot.tick = self.tick;
                }
            }
            // Evict the least-recently-used entry (O(capacity) scan). Bind
            // the owned key first so the map iteration borrow has ended
            // before `remove` mutates the map.
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, slot)| slot.tick)
                .map(|(k, _)| *k);
            if let Some(oldest) = oldest {
                self.map.remove(&oldest);
            }
        }
        self.tick += 1;
        self.map.insert(key, Slot { decision, tick: self.tick });
        self.last = Some((key, decision));
    }

    /// Hit/miss/eviction counters since construction.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            entries: self.map.len(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(lk_key: usize) -> PlanKey {
        PlanKey { batch: 1, l_q: 1, h_q: 8, h_kv: 1, d: 128, lk_key }
    }

    fn decision(s: usize) -> CachedDecision {
        CachedDecision {
            num_splits: s,
            pack_gqa: true,
            sm_margin: 0,
            effective_splits: s,
            grid_ctas: s,
            waves: 1,
            occupancy: s as f64 / 132.0,
            combine_estimate_us: 0.0,
        }
    }

    #[test]
    fn hit_after_insert_and_last_slot() {
        let mut c = PlanCache::new(8);
        assert_eq!(c.get(&key(4)), None);
        c.insert(key(4), decision(3));
        assert_eq!(c.get(&key(4)).unwrap().num_splits, 3);
        assert_eq!(c.get(&key(4)).unwrap().num_splits, 3); // last-slot path
        let s = c.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 2);
        assert!(s.hit_rate() > 0.6);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = PlanCache::new(2);
        c.insert(key(1), decision(1));
        c.insert(key(2), decision(2));
        // Touch key(1) so key(2) becomes the LRU.
        assert!(c.get(&key(1)).is_some());
        c.insert(key(3), decision(3));
        assert_eq!(c.stats().entries, 2);
        assert!(c.get(&key(2)).is_none(), "LRU entry should be evicted");
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(3)).is_some());
    }

    #[test]
    fn reinsert_same_key_does_not_evict() {
        let mut c = PlanCache::new(2);
        c.insert(key(1), decision(1));
        c.insert(key(2), decision(2));
        c.insert(key(2), decision(4)); // overwrite, no eviction
        assert_eq!(c.stats().entries, 2);
        assert_eq!(c.get(&key(2)).unwrap().num_splits, 4);
        assert!(c.get(&key(1)).is_some());
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let mut c = PlanCache::new(64);
        for lk in 1..=32 {
            c.insert(key(lk), decision(lk));
        }
        for lk in 1..=32 {
            assert_eq!(c.get(&key(lk)).unwrap().num_splits, lk, "lk_key={lk}");
        }
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        PlanCache::new(0);
    }
}
