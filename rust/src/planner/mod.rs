//! The split planner: the single entry point for all split planning.
//!
//! The paper's contribution is a *launch-planning decision* — pick
//! `num_splits` per decode step on the metadata-enabled path (§5.1). The
//! seed scattered that decision across ad-hoc call signatures
//! (`SplitPolicy::num_splits`, `SplitPolicy::metadata`,
//! `SchedulerMetadata::forced`, struct-literal metadata in benches) with an
//! `H100_NUM_SMS` constant baked in. This module is the façade that
//! replaces all of it, mirroring FlashAttention-3's single
//! `get_scheduler_metadata()` contract:
//!
//! * [`DeviceProfile`] — the accelerator facts (SM count, CTAs/SM, split
//!   cap, combine model) with H100/A100/H200 presets ([`device`]),
//! * [`Planner`] — built once via [`PlannerBuilder`] (policy + device +
//!   `sm_margin` + `pack_gqa` + [`DispatchPath`]), then queried with
//!   [`Planner::plan`] / [`Planner::plan_batch_into`] /
//!   [`Planner::plan_forced`],
//! * an LRU shape-bucket plan cache ([`cache`]) so the serving hot path
//!   stops recomputing identical decisions every decode step,
//! * [`PlanCursor`] ([`cursor`]) — the zero-allocation steady-state path:
//!   decode monotonicity pins one decision plus its `l_k` validity window
//!   (`SplitPolicy::decision_horizon` / genome rule edges), so the
//!   per-token cost is a range check and an in-place metadata stamp; the
//!   LRU stays the cold-path refill source,
//! * [`PolicyRegistry`] — string-keyed policy construction
//!   (standard / sequence-aware / extended / evolved-genome) shared by the
//!   CLI, the evaluator, and the bench harnesses ([`registry`]).
//!
//! [`crate::heuristics::SplitPolicy`] stays the inner decision trait; no
//! caller outside this module constructs [`SchedulerMetadata`] by hand.

pub mod cache;
pub mod cursor;
pub mod device;
pub mod plan;
pub mod registry;

pub use cache::CacheStats;
pub use cursor::{CursorStats, PlanCursor};
pub use device::{CombineModel, DeviceProfile};
pub use plan::LaunchPlan;
pub use registry::PolicyRegistry;

use std::fmt;
use std::sync::Arc;

use crate::evolve::genome::Genome;
use crate::heuristics::standard::num_splits_heuristic_upstream;
use crate::heuristics::tiles::{DecodeShape, SplitGeometry, KV_BLOCK};
use crate::heuristics::{
    DispatchPath, SchedulerMetadata, SequenceAwarePolicy, SplitPolicy, StandardPolicy,
};

use cache::{CachedDecision, PlanCache, PlanKey};

/// Default LRU capacity: serving steady state sees a handful of
/// (batch-bucket × nblk) combinations, so 512 is generous.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 512;

/// What produces the split decision inside a [`Planner`].
#[derive(Clone)]
pub enum PlanSource {
    /// A [`SplitPolicy`] implementation (standard, sequence-aware,
    /// extended table, or any custom policy).
    Policy(Arc<dyn SplitPolicy>),
    /// An evolved rule genome (§3): rules may override `pack_gqa` and
    /// `sm_margin` per shape, falling through to the upstream heuristic.
    Genome(Genome),
}

impl PlanSource {
    /// Wrap a [`SplitPolicy`] implementation as a plan source.
    pub fn policy<P: SplitPolicy + 'static>(policy: P) -> PlanSource {
        PlanSource::Policy(Arc::new(policy))
    }

    /// The source's registry/display name.
    pub fn name(&self) -> &'static str {
        match self {
            PlanSource::Policy(p) => p.name(),
            PlanSource::Genome(_) => "evolved-genome",
        }
    }

    /// Whether plans may be cached per nblk bucket (true for bucket-pure
    /// policies) or must be keyed by exact `L_K` (genome rules carry
    /// arbitrary `L_K` range conditions).
    fn bucket_pure(&self) -> bool {
        match self {
            PlanSource::Policy(p) => p.shape_bucket_pure(),
            PlanSource::Genome(_) => false,
        }
    }

    /// The inclusive `l_k` window around `shape.l_k` over which this
    /// source's decision (and its bucket-derived launch geometry) is
    /// constant, all other shape fields held fixed — what a [`PlanCursor`]
    /// pins. Both edges clamp to the current nblk bucket, because the
    /// cached [`CachedDecision`] carries `effective_splits`/`grid_ctas`/
    /// `waves`, which change at every bucket edge even when the split
    /// count does not.
    ///
    /// * Policies: `[bucket start, decision_horizon]` when bucket-pure,
    ///   the degenerate `[l_k, decision_horizon]` otherwise.
    /// * Genomes: the bucket intersected with the nearest rule-condition
    ///   edges (`lk_min`/`lk_max` of every rule whose batch/h_kv guards
    ///   can match this shape) — the set of matching rules, and hence the
    ///   first match, is constant strictly between those edges.
    fn validity_window(&self, shape: &DecodeShape) -> (usize, usize) {
        let nblk = shape.nblk();
        let bucket_start = (nblk - 1) * KV_BLOCK + 1;
        let bucket_end = nblk * KV_BLOCK;
        match self {
            PlanSource::Policy(p) => {
                let until = p.decision_horizon(shape).clamp(shape.l_k, bucket_end);
                let from = if p.shape_bucket_pure() { bucket_start } else { shape.l_k };
                (from, until)
            }
            PlanSource::Genome(g) => {
                let mut from = bucket_start;
                let mut until = bucket_end;
                for r in &g.rules {
                    if shape.batch > r.batch_max || shape.h_kv > r.hkv_max {
                        continue; // can never match this cursor's fixed fields
                    }
                    if r.lk_min > shape.l_k {
                        until = until.min(r.lk_min - 1);
                    } else {
                        from = from.max(r.lk_min);
                    }
                    if r.lk_max < shape.l_k {
                        from = from.max(r.lk_max + 1);
                    } else {
                        until = until.min(r.lk_max);
                    }
                }
                (from, until)
            }
        }
    }
}

impl fmt::Debug for PlanSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanSource::Policy(p) => write!(f, "Policy({})", p.name()),
            PlanSource::Genome(g) => write!(f, "Genome({} rules)", g.rules.len()),
        }
    }
}

/// Builder for [`Planner`]: configure the launch environment once instead
/// of threading `(sm_margin, pack_gqa, num_sm)` through every call.
pub struct PlannerBuilder {
    source: PlanSource,
    device: DeviceProfile,
    sm_margin: usize,
    pack_gqa: bool,
    path: DispatchPath,
    cache_capacity: usize,
}

impl PlannerBuilder {
    /// Start from any [`SplitPolicy`].
    pub fn policy<P: SplitPolicy + 'static>(policy: P) -> PlannerBuilder {
        PlannerBuilder::source(PlanSource::policy(policy))
    }

    /// Start from an evolved genome.
    pub fn genome(genome: Genome) -> PlannerBuilder {
        PlannerBuilder::source(PlanSource::Genome(genome))
    }

    /// Start a builder from any plan source (policy or genome).
    pub fn source(source: PlanSource) -> PlannerBuilder {
        PlannerBuilder {
            source,
            device: DeviceProfile::H100_SXM,
            sm_margin: 0,
            pack_gqa: true,
            path: DispatchPath::PrecomputedMetadata,
            cache_capacity: DEFAULT_PLAN_CACHE_CAPACITY,
        }
    }

    /// Select the device profile (default: H100 SXM).
    pub fn device(mut self, device: DeviceProfile) -> PlannerBuilder {
        self.device = device;
        self
    }

    /// SMs reserved for the combine-scheduler CTA (§3.1's knob).
    pub fn sm_margin(mut self, sm_margin: usize) -> PlannerBuilder {
        self.sm_margin = sm_margin;
        self
    }

    /// Enable/disable the packed-GQA tile layout (default: on).
    pub fn pack_gqa(mut self, pack_gqa: bool) -> PlannerBuilder {
        self.pack_gqa = pack_gqa;
        self
    }

    /// Select the dispatch path stamped into metadata.
    pub fn dispatch_path(mut self, path: DispatchPath) -> PlannerBuilder {
        self.path = path;
        self
    }

    /// Plan-cache capacity; 0 disables caching entirely.
    pub fn cache_capacity(mut self, capacity: usize) -> PlannerBuilder {
        self.cache_capacity = capacity;
        self
    }

    /// Freeze the configuration into a [`Planner`].
    pub fn build(self) -> Planner {
        let bucketed = self.source.bucket_pure();
        Planner {
            cache: (self.cache_capacity > 0).then(|| PlanCache::new(self.cache_capacity)),
            cache_capacity: self.cache_capacity,
            bucketed,
            source: self.source,
            device: self.device,
            sm_margin: self.sm_margin,
            pack_gqa: self.pack_gqa,
            path: self.path,
            id: next_planner_id(),
        }
    }
}

/// The planner: policy + device + launch knobs + plan cache, behind one
/// `plan()` call. Owns its cache mutably (`&mut self`) so the steady-state
/// cache hit needs no locking.
///
/// ```
/// use fa3_split::heuristics::tiles::DecodeShape;
/// use fa3_split::planner::Planner;
///
/// let mut planner = Planner::sequence_aware();
/// let plan = planner.plan(&DecodeShape::llama70b_tp8(1, 512));
/// assert_eq!(plan.num_splits(), 3); // the paper's boundary override
///
/// // Steady-state decode rides a cursor: identical plans, no allocation.
/// let mut cursor = planner.cursor();
/// let again = cursor.plan(&mut planner, &DecodeShape::llama70b_tp8(1, 512));
/// assert_eq!(plan, again);
/// ```
pub struct Planner {
    source: PlanSource,
    device: DeviceProfile,
    sm_margin: usize,
    pack_gqa: bool,
    path: DispatchPath,
    bucketed: bool,
    cache: Option<PlanCache>,
    cache_capacity: usize,
    /// Process-unique identity (fresh per build/clone). A [`PlanCursor`]
    /// stamps it at refill and re-checks it on the hit path, so a cursor
    /// accidentally handed a *different* planner refills instead of
    /// silently serving the previous planner's pinned decision.
    id: u64,
}

/// Monotonic planner-identity source (see [`Planner::id`]; relaxed is
/// enough — only uniqueness matters, not ordering).
fn next_planner_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

impl Planner {
    /// Upstream policy on H100 defaults — the seed's implicit configuration.
    pub fn standard() -> Planner {
        PlannerBuilder::policy(StandardPolicy).build()
    }

    /// The paper's sequence-aware policy on H100 defaults.
    pub fn sequence_aware() -> Planner {
        PlannerBuilder::policy(SequenceAwarePolicy).build()
    }

    /// Plan one decode launch. Cached: repeated shapes (and, for
    /// bucket-pure policies, any shape in the same nblk bucket) return the
    /// memoized decision.
    pub fn plan(&mut self, shape: &DecodeShape) -> LaunchPlan {
        let decision = self.decision_for(shape);
        self.materialize(shape, &decision)
    }

    /// The decision half of [`Planner::plan`]: LRU lookup, then the
    /// source. The cache is moved out of its `Option` for the lookup so
    /// the miss path can call `compute(&self)` without the borrow dance
    /// (`Option::take`/put-back moves a struct, never allocates).
    fn decision_for(&mut self, shape: &DecodeShape) -> CachedDecision {
        match self.cache.take() {
            None => self.compute(shape),
            Some(mut cache) => {
                let key = self.key_for(shape);
                let decision = match cache.get(&key) {
                    Some(hit) => hit,
                    None => {
                        let computed = self.compute(shape);
                        cache.insert(key, computed);
                        computed
                    }
                };
                self.cache = Some(cache);
                decision
            }
        }
    }

    /// A fresh [`PlanCursor`] for this planner: the zero-allocation
    /// steady-state path for monotone decode (`cursor.plan(&mut planner,
    /// &shape)`). The cursor holds no reference — one planner refills any
    /// number of cursors.
    pub fn cursor(&self) -> PlanCursor {
        PlanCursor::new()
    }

    /// Cursor refill: the decision plus the inclusive `l_k` validity
    /// window it holds over (the LRU cache is the refill source; the
    /// window comes from the same source that made the decision).
    pub(crate) fn cursor_refill(&mut self, shape: &DecodeShape) -> (CachedDecision, usize, usize) {
        let decision = self.decision_for(shape);
        let (from, until) = self.source.validity_window(shape);
        (decision, from, until)
    }

    /// Plan a batch of shapes into a caller-owned buffer (cleared first),
    /// so per-step batch planners reuse their output allocation across
    /// steps. Element-wise identical to calling [`Planner::plan`] per
    /// shape; duplicate shapes within the batch hit the cache's fast path.
    /// Consumed by `DecodeScheduler::decide_batch` for schedulers that
    /// plan several buckets per step (the built-in engine plans one).
    pub fn plan_batch_into(&mut self, out: &mut Vec<LaunchPlan>, shapes: &[DecodeShape]) {
        out.clear();
        out.reserve(shapes.len());
        for shape in shapes {
            out.push(self.plan(shape));
        }
    }

    /// Allocating convenience over [`Planner::plan_batch_into`].
    pub fn plan_batch(&mut self, shapes: &[DecodeShape]) -> Vec<LaunchPlan> {
        let mut out = Vec::new();
        self.plan_batch_into(&mut out, shapes);
        out
    }

    /// Plan with a manually-forced split count (A/B benches, the Figure 3
    /// sweep) under this planner's device/margin/layout. Bypasses both the
    /// policy and the cache.
    pub fn plan_forced(&self, shape: &DecodeShape, num_splits: usize) -> LaunchPlan {
        assert!(num_splits >= 1);
        let s = num_splits.min(self.device.max_splits);
        let decision = self.derive(shape, s, self.pack_gqa, self.sm_margin);
        self.materialize(shape, &decision)
    }

    /// The policy/genome name (registry key for built-ins).
    pub fn name(&self) -> &'static str {
        self.source.name()
    }

    /// The device profile plans are computed against.
    pub fn device(&self) -> &DeviceProfile {
        &self.device
    }

    /// SMs reserved for the combine scheduler.
    pub fn sm_margin(&self) -> usize {
        self.sm_margin
    }

    /// Whether plans use the packed-GQA tile layout.
    pub fn pack_gqa(&self) -> bool {
        self.pack_gqa
    }

    /// The dispatch path stamped into every plan.
    pub fn dispatch_path(&self) -> DispatchPath {
        self.path
    }

    /// Cache hit/miss counters (all-zero when caching is disabled).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    fn key_for(&self, shape: &DecodeShape) -> PlanKey {
        PlanKey {
            batch: shape.batch,
            l_q: shape.l_q,
            h_q: shape.h_q,
            h_kv: shape.h_kv,
            d: shape.d,
            lk_key: if self.bucketed { shape.nblk() } else { shape.l_k },
        }
    }

    /// Run the source's decision logic (the cache-miss path).
    fn compute(&self, shape: &DecodeShape) -> CachedDecision {
        let (num_splits, pack_gqa, sm_margin) = match &self.source {
            PlanSource::Policy(policy) => {
                let budget = self.device.sm_budget(self.sm_margin);
                let s = policy.num_splits(shape, budget, self.pack_gqa);
                (s.clamp(1, self.device.max_splits), self.pack_gqa, self.sm_margin)
            }
            PlanSource::Genome(genome) => {
                match genome.rules.iter().find(|r| r.matches(shape)) {
                    Some(rule) => (
                        rule.num_splits.clamp(1, self.device.max_splits),
                        rule.pack_gqa,
                        rule.sm_margin,
                    ),
                    None => {
                        // Upstream fallback: unmatched shapes behave exactly
                        // like the standard heuristic under this planner's
                        // defaults — a genome is always a delta vs upstream.
                        let budget = self.device.sm_budget(self.sm_margin);
                        let s = num_splits_heuristic_upstream(
                            shape.total_mblocks(self.pack_gqa),
                            budget,
                            shape.nblk(),
                            self.device.max_splits,
                        );
                        (s.clamp(1, self.device.max_splits), self.pack_gqa, self.sm_margin)
                    }
                }
            }
        };
        self.derive(shape, num_splits, pack_gqa, sm_margin)
    }

    /// Derive the shape-bucket-invariant launch facts for a decision.
    fn derive(
        &self,
        shape: &DecodeShape,
        num_splits: usize,
        pack_gqa: bool,
        sm_margin: usize,
    ) -> CachedDecision {
        let effective_splits = SplitGeometry::effective_splits(shape.l_k, num_splits);
        let grid_ctas = shape.total_mblocks(pack_gqa) * effective_splits;
        let budget = self.device.sm_budget(sm_margin);
        let waves = grid_ctas.div_ceil(self.device.wave_capacity(sm_margin)).max(1);
        CachedDecision {
            num_splits,
            pack_gqa,
            sm_margin,
            effective_splits,
            grid_ctas,
            waves,
            occupancy: (grid_ctas as f64 / budget as f64).min(1.0),
            combine_estimate_us: self.device.combine.estimate_us(effective_splits),
        }
    }

    /// Attach the exact shape back onto a (possibly cached) decision.
    // pallas-lint: no_alloc
    fn materialize(&self, shape: &DecodeShape, d: &CachedDecision) -> LaunchPlan {
        LaunchPlan {
            metadata: SchedulerMetadata {
                shape: *shape,
                num_splits: d.num_splits,
                pack_gqa: d.pack_gqa,
                sm_margin: d.sm_margin,
                num_sms: self.device.num_sms,
                path: self.path,
            },
            effective_splits: d.effective_splits,
            grid_ctas: d.grid_ctas,
            waves: d.waves,
            occupancy: d.occupancy,
            combine_estimate_us: d.combine_estimate_us,
        }
    }
}

impl Clone for Planner {
    /// Clones configuration and source but starts with a fresh, empty
    /// cache and a fresh identity (cached decisions are re-derivable by
    /// construction; cursors pinned to the original refill on the clone).
    fn clone(&self) -> Planner {
        Planner {
            source: self.source.clone(),
            device: self.device,
            sm_margin: self.sm_margin,
            pack_gqa: self.pack_gqa,
            path: self.path,
            bucketed: self.bucketed,
            cache: (self.cache_capacity > 0).then(|| PlanCache::new(self.cache_capacity)),
            cache_capacity: self.cache_capacity,
            id: next_planner_id(),
        }
    }
}

impl fmt::Debug for Planner {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Planner")
            .field("source", &self.source)
            .field("device", &self.device.name)
            .field("sm_margin", &self.sm_margin)
            .field("pack_gqa", &self.pack_gqa)
            .field("path", &self.path)
            .field("cache", &self.cache_stats())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::sequence_aware::BOUNDARY_SPLIT;

    #[test]
    fn plan_matches_raw_policy_decision() {
        let mut p = Planner::sequence_aware();
        for l_k in [128usize, 384, 448, 512, 640, 2048, 4096] {
            let shape = DecodeShape::llama70b_tp8(1, l_k);
            let expect = SequenceAwarePolicy.num_splits(
                &shape,
                DeviceProfile::H100_SXM.sm_budget(0),
                true,
            );
            assert_eq!(p.plan(&shape).num_splits(), expect, "l_k={l_k}");
        }
        let boundary = DecodeShape::llama70b_tp8(1, 512);
        assert_eq!(p.plan(&boundary).num_splits(), BOUNDARY_SPLIT);
    }

    #[test]
    fn cached_equals_uncached() {
        let mut cached = Planner::sequence_aware();
        let mut uncached = PlannerBuilder::policy(SequenceAwarePolicy).cache_capacity(0).build();
        for l_k in 1..=2048usize {
            let shape = DecodeShape::llama70b_tp8(1, l_k);
            assert_eq!(cached.plan(&shape), uncached.plan(&shape), "l_k={l_k}");
        }
        let stats = cached.cache_stats();
        assert!(stats.hits > stats.misses, "bucketing should dominate: {stats:?}");
        assert_eq!(uncached.cache_stats(), CacheStats::default());
    }

    #[test]
    fn bucketed_cache_reuses_nblk_bucket() {
        let mut p = Planner::sequence_aware();
        // 385..=512 is one nblk=4 bucket: one miss, the rest hits.
        for l_k in 385..=512usize {
            let plan = p.plan(&DecodeShape::llama70b_tp8(1, l_k));
            assert_eq!(plan.num_splits(), BOUNDARY_SPLIT);
            assert_eq!(plan.metadata.shape.l_k, l_k, "exact shape preserved");
        }
        let stats = p.cache_stats();
        assert_eq!(stats.misses, 1, "{stats:?}");
        assert_eq!(stats.hits, 127, "{stats:?}");
    }

    #[test]
    fn plan_forced_mirrors_seed_forced_semantics() {
        let p = Planner::standard();
        let shape = DecodeShape::llama70b_tp8(1, 512);
        let plan = p.plan_forced(&shape, 64);
        assert_eq!(plan.num_splits(), 64);
        // Over-split: effective splits cap at nblk = 4 CTAs.
        assert_eq!(plan.effective_splits, 4);
        assert_eq!(plan.grid_ctas, 4);
        assert_eq!(plan.metadata.path, DispatchPath::PrecomputedMetadata);
        assert!(plan.metadata.pack_gqa);
        assert_eq!(plan.metadata.sm_margin, 0);
        // The upstream cap applies even to forced plans.
        assert_eq!(p.plan_forced(&shape, 100_000).num_splits(), 128);
    }

    #[test]
    fn genome_source_honors_rules_and_fallback() {
        let mut p = PlannerBuilder::genome(Genome::figure1()).build();
        // L_K = 200 matches the seqlen<256 rule: s = 16.
        assert_eq!(p.plan(&DecodeShape::llama70b_tp8(1, 200)).num_splits(), 16);
        // L_K = 400 falls to the second rule: s = 12.
        assert_eq!(p.plan(&DecodeShape::llama70b_tp8(1, 400)).num_splits(), 12);
        // Batch 2 matches nothing: upstream guard ⇒ 1.
        assert_eq!(p.plan(&DecodeShape::llama70b_tp8(2, 400)).num_splits(), 1);
        // Beyond 512: upstream efficiency loop engages.
        assert!(p.plan(&DecodeShape::llama70b_tp8(1, 513)).num_splits() > 1);
    }

    #[test]
    fn genome_rule_knobs_flow_into_metadata() {
        use crate::evolve::genome::Rule;
        let genome = Genome {
            rules: vec![Rule {
                batch_max: 1,
                lk_min: 1,
                lk_max: 512,
                hkv_max: usize::MAX,
                num_splits: 10_000, // clamped to the device cap
                pack_gqa: false,
                sm_margin: 8,
            }],
        };
        let mut p = PlannerBuilder::genome(genome).build();
        let plan = p.plan(&DecodeShape::llama70b_tp8(1, 512));
        assert_eq!(plan.num_splits(), DeviceProfile::H100_SXM.max_splits);
        assert!(!plan.metadata.pack_gqa);
        assert_eq!(plan.metadata.sm_margin, 8);
    }

    #[test]
    fn genome_cache_keys_exact_lengths() {
        // figure1 distinguishes L_K 200 from 300 inside the same nblk
        // bucket boundary (255/256): the cache must not merge them.
        let mut p = PlannerBuilder::genome(Genome::figure1()).build();
        assert_eq!(p.plan(&DecodeShape::llama70b_tp8(1, 255)).num_splits(), 16);
        assert_eq!(p.plan(&DecodeShape::llama70b_tp8(1, 256)).num_splits(), 12);
        assert_eq!(p.plan(&DecodeShape::llama70b_tp8(1, 255)).num_splits(), 16);
    }

    #[test]
    fn oversized_margin_saturates_instead_of_panicking() {
        let mut p = PlannerBuilder::policy(SequenceAwarePolicy).sm_margin(10_000).build();
        let plan = p.plan(&DecodeShape::llama70b_tp8(1, 512));
        assert!(plan.num_splits() >= 1);
        assert!((0.0..=1.0).contains(&plan.occupancy));
        // The metadata-side occupancy helper must also survive (the seed
        // underflowed here in debug builds).
        assert!((0.0..=1.0).contains(&plan.metadata.occupancy()));
    }

    #[test]
    fn plan_batch_matches_per_shape_plan() {
        let shapes: Vec<DecodeShape> = [256usize, 512, 512, 2048, 512]
            .iter()
            .map(|&l_k| DecodeShape::llama70b_tp8(1, l_k))
            .collect();
        let mut a = Planner::sequence_aware();
        let batch = a.plan_batch(&shapes);
        let mut b = Planner::sequence_aware();
        for (i, shape) in shapes.iter().enumerate() {
            assert_eq!(batch[i], b.plan(shape), "index {i}");
        }
    }

    #[test]
    fn plan_batch_into_reuses_the_buffer() {
        let shapes: Vec<DecodeShape> =
            [256usize, 512, 2048].iter().map(|&l_k| DecodeShape::llama70b_tp8(1, l_k)).collect();
        let mut p = Planner::sequence_aware();
        let mut out = Vec::new();
        p.plan_batch_into(&mut out, &shapes);
        assert_eq!(out.len(), 3);
        let cap = out.capacity();
        let first: Vec<LaunchPlan> = out.clone();
        // Second fill into the same buffer: same plans, no regrowth.
        p.plan_batch_into(&mut out, &shapes);
        assert_eq!(out, first);
        assert_eq!(out.capacity(), cap, "buffer must be reused, not reallocated");
        // plan_batch delegates to plan_batch_into.
        assert_eq!(p.plan_batch(&shapes), first);
    }

    #[test]
    fn validity_window_clamps_to_the_bucket() {
        // Policy sources: the window is exactly the nblk bucket.
        let policy = PlanSource::policy(SequenceAwarePolicy);
        assert_eq!(policy.validity_window(&DecodeShape::llama70b_tp8(1, 1)), (1, 128));
        assert_eq!(policy.validity_window(&DecodeShape::llama70b_tp8(1, 385)), (385, 512));
        assert_eq!(policy.validity_window(&DecodeShape::llama70b_tp8(1, 512)), (385, 512));
        // Genome sources: rule edges cut the bucket. figure1's seqlen<256
        // rule splits the 129..=256 bucket at 255/256.
        let genome = PlanSource::Genome(Genome::figure1());
        assert_eq!(genome.validity_window(&DecodeShape::llama70b_tp8(1, 200)), (129, 255));
        assert_eq!(genome.validity_window(&DecodeShape::llama70b_tp8(1, 256)), (256, 256));
        assert_eq!(genome.validity_window(&DecodeShape::llama70b_tp8(1, 400)), (385, 512));
        // Rules whose batch guard can't match this shape are ignored:
        // batch 2 matches nothing in figure1, so the window is the bucket.
        assert_eq!(genome.validity_window(&DecodeShape::llama70b_tp8(2, 200)), (129, 256));
        // The window always contains l_k itself.
        for l_k in 1..=1024usize {
            let (from, until) = genome.validity_window(&DecodeShape::llama70b_tp8(1, l_k));
            assert!(from <= l_k && l_k <= until, "l_k={l_k} window=({from},{until})");
        }
    }

    #[test]
    fn device_profile_changes_the_budget() {
        // 100 tiles saturate A100 (>= 0.8 * 108) but not H100 (0.8 * 132).
        let shape = DecodeShape::decode(25, 2048, 32, 4, 128);
        assert_eq!(shape.total_mblocks(true), 100);
        let mut h100 = PlannerBuilder::policy(StandardPolicy).build();
        let mut a100 = PlannerBuilder::policy(StandardPolicy)
            .device(DeviceProfile::A100_SXM)
            .build();
        assert_eq!(a100.plan(&shape).num_splits(), 1, "saturated on A100");
        assert!(h100.plan(&shape).num_splits() >= 1);
        // Wave math follows the device: 200 CTAs is 2 waves on both, but
        // occupancy differs.
        let p_h = h100.plan_forced(&shape, 2);
        let p_a = a100.plan_forced(&shape, 2);
        assert!(p_a.occupancy >= p_h.occupancy);
    }

    #[test]
    fn internal_dispatch_path_is_stamped() {
        let mut p = PlannerBuilder::policy(SequenceAwarePolicy)
            .dispatch_path(DispatchPath::InternalHeuristic)
            .build();
        let plan = p.plan(&DecodeShape::llama70b_tp8(1, 512));
        assert_eq!(plan.metadata.path, DispatchPath::InternalHeuristic);
    }

    #[test]
    fn clone_starts_with_fresh_cache() {
        let mut p = Planner::sequence_aware();
        p.plan(&DecodeShape::llama70b_tp8(1, 512));
        assert!(p.cache_stats().misses > 0);
        let q = p.clone();
        assert_eq!(q.cache_stats(), CacheStats::default());
        assert_eq!(q.name(), p.name());
    }
}
