//! String-keyed policy registry: one place that maps policy names to plan
//! sources, so the CLI, the evaluator, the examples, and the bench
//! harnesses stop pattern-matching name strings independently (the seed
//! had three divergent copies of that `match`).

use crate::evolve::genome::Genome;
use crate::heuristics::extended::{ExtendedPolicy, TuneConfig};
use crate::heuristics::{SequenceAwarePolicy, StandardPolicy};
use crate::sim::Simulator;

use super::{DeviceProfile, PlanSource, Planner, PlannerBuilder};

/// Factories receive the target device so device-dependent construction
/// (the auto-tuned `extended` table) tunes against the right part.
type SourceFactory = Box<dyn Fn(&DeviceProfile) -> PlanSource + Send + Sync>;

struct PolicyEntry {
    name: String,
    aliases: Vec<String>,
    help: String,
    factory: SourceFactory,
}

/// Registry of named split policies.
pub struct PolicyRegistry {
    entries: Vec<PolicyEntry>,
}

impl PolicyRegistry {
    /// An empty registry (register your own entries).
    pub fn new() -> PolicyRegistry {
        PolicyRegistry { entries: Vec::new() }
    }

    /// The built-in ladder: standard → sequence-aware → extended →
    /// evolved-genome (§4.1/§5.2's progression from upstream to learned).
    pub fn builtin() -> PolicyRegistry {
        let mut reg = PolicyRegistry::new();
        reg.register(
            "standard",
            &[],
            "upstream FA3 heuristic, premature L_K <= 512 guard included (§2.2)",
            |_| PlanSource::policy(StandardPolicy),
        );
        reg.register(
            "sequence-aware",
            &["patched"],
            "the paper's conservative Figure-2 patch (boundary-bucket override)",
            |_| PlanSource::policy(SequenceAwarePolicy),
        );
        reg.register(
            "extended",
            &["extended-table"],
            "learned (nblk, tiles) split table auto-tuned against the target device (§5.2)",
            |device| {
                // Tune against the target device's simulator and SM budget
                // so the table's regression-free-by-construction guarantee
                // holds on the part it will actually plan for; the probe
                // planner supplies forced-split metadata for the oracle.
                let sim = Simulator::for_profile(device);
                let probe = PlannerBuilder::policy(StandardPolicy).device(*device).build();
                let cfg = TuneConfig { num_sm: device.num_sms, ..TuneConfig::default() };
                PlanSource::policy(ExtendedPolicy::tune(&cfg, |shape, s| {
                    sim.kernel_us(&probe.plan_forced(shape, s).metadata)
                }))
            },
        );
        reg.register(
            "evolved-genome",
            &["genome", "figure1"],
            "the paper's Figure-1 evolved candidate (aggressive, rule-DSL genome)",
            |_| PlanSource::Genome(Genome::figure1()),
        );
        reg
    }

    /// Register a policy under `name` (plus aliases). Later registrations
    /// shadow earlier ones, so callers can override built-ins. The factory
    /// receives the target [`DeviceProfile`] (ignore it for
    /// device-independent policies).
    pub fn register(
        &mut self,
        name: impl Into<String>,
        aliases: &[&str],
        help: impl Into<String>,
        factory: impl Fn(&DeviceProfile) -> PlanSource + Send + Sync + 'static,
    ) {
        self.entries.insert(
            0,
            PolicyEntry {
                name: name.into(),
                aliases: aliases.iter().map(|s| s.to_string()).collect(),
                help: help.into(),
                factory: Box::new(factory),
            },
        );
    }

    /// Canonical names, registration order (most recent first).
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// `standard|sequence-aware|extended|evolved-genome` — for CLI help.
    pub fn help_line(&self) -> String {
        let mut names: Vec<&str> = self.names();
        names.reverse(); // builtin ladder order reads better
        names.join("|")
    }

    /// One help bullet per policy.
    pub fn describe(&self) -> String {
        let mut out = String::new();
        for e in self.entries.iter().rev() {
            out.push_str(&format!("  {:<16} {}\n", e.name, e.help));
        }
        out
    }

    fn entry(&self, name: &str) -> Option<&PolicyEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name || e.aliases.iter().any(|a| a == name))
    }

    /// Instantiate the named plan source for a specific device.
    pub fn source_for(&self, name: &str, device: &DeviceProfile) -> Result<PlanSource, String> {
        match self.entry(name) {
            Some(e) => Ok((e.factory)(device)),
            None => Err(format!(
                "unknown policy '{name}' (known: {})",
                self.help_line()
            )),
        }
    }

    /// Instantiate the named plan source on the H100 default device.
    pub fn source(&self, name: &str) -> Result<PlanSource, String> {
        self.source_for(name, &DeviceProfile::H100_SXM)
    }

    /// A [`PlannerBuilder`] for the named policy targeting `device`. Use
    /// this (not `builder` + `.device(..)`) when the device differs from
    /// H100, so device-dependent sources are constructed for the right
    /// part.
    pub fn builder_for(
        &self,
        name: &str,
        device: &DeviceProfile,
    ) -> Result<PlannerBuilder, String> {
        self.source_for(name, device)
            .map(|src| PlannerBuilder::source(src).device(*device))
    }

    /// A [`PlannerBuilder`] for the named policy (H100 defaults; customize
    /// knobs before building).
    pub fn builder(&self, name: &str) -> Result<PlannerBuilder, String> {
        self.builder_for(name, &DeviceProfile::H100_SXM)
    }

    /// A ready [`Planner`] on H100 defaults for the named policy.
    pub fn planner(&self, name: &str) -> Result<Planner, String> {
        self.builder(name).map(PlannerBuilder::build)
    }
}

impl Default for PolicyRegistry {
    fn default() -> Self {
        PolicyRegistry::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::sequence_aware::BOUNDARY_SPLIT;
    use crate::heuristics::tiles::DecodeShape;

    #[test]
    fn builtin_names_and_aliases() {
        let reg = PolicyRegistry::builtin();
        let mut names = reg.names();
        names.sort_unstable();
        assert_eq!(names, vec!["evolved-genome", "extended", "sequence-aware", "standard"]);
        // Alias resolution (the seed accepted "patched" on the CLI).
        assert_eq!(reg.planner("patched").unwrap().name(), "sequence-aware");
        assert_eq!(reg.planner("figure1").unwrap().name(), "evolved-genome");
        assert!(reg.help_line().starts_with("standard"));
        assert!(reg.describe().contains("sequence-aware"));
    }

    #[test]
    fn unknown_name_lists_known_policies() {
        let reg = PolicyRegistry::builtin();
        let err = reg.planner("nope").unwrap_err();
        assert!(err.contains("unknown policy 'nope'"));
        assert!(err.contains("sequence-aware"));
    }

    #[test]
    fn builtins_decide_the_boundary_shape_as_documented() {
        let reg = PolicyRegistry::builtin();
        let boundary = DecodeShape::llama70b_tp8(1, 512);
        assert_eq!(reg.planner("standard").unwrap().plan(&boundary).num_splits(), 1);
        assert_eq!(
            reg.planner("sequence-aware").unwrap().plan(&boundary).num_splits(),
            BOUNDARY_SPLIT
        );
        // The tuned table and the evolved genome both split here too.
        assert!(reg.planner("extended").unwrap().plan(&boundary).num_splits() > 1);
        assert!(reg.planner("evolved-genome").unwrap().plan(&boundary).num_splits() > 1);
    }

    #[test]
    fn registration_shadows_builtins() {
        let mut reg = PolicyRegistry::builtin();
        reg.register("standard", &[], "custom override", |_| {
            PlanSource::policy(SequenceAwarePolicy)
        });
        let mut p = reg.planner("standard").unwrap();
        assert_eq!(p.plan(&DecodeShape::llama70b_tp8(1, 512)).num_splits(), BOUNDARY_SPLIT);
    }

    #[test]
    fn extended_is_tuned_for_the_requested_device() {
        // builder_for must construct the table against the target part:
        // the planner it yields carries that device, and its table entries
        // must not regress vs upstream *on that device's model*.
        let reg = PolicyRegistry::builtin();
        let device = DeviceProfile::A100_SXM;
        let mut ext = reg.builder_for("extended", &device).unwrap().build();
        assert_eq!(ext.device().name, device.name);
        let mut std_p = PlannerBuilder::policy(StandardPolicy).device(device).build();
        let sim = Simulator::for_profile(&device);
        for l_k in (64..=2048usize).step_by(64) {
            for batch in [1usize, 2, 4] {
                let shape = DecodeShape::decode(batch, l_k, 8, 1, 128);
                let t_ext = sim.kernel_us(&ext.plan(&shape).metadata);
                let t_std = sim.kernel_us(&std_p.plan(&shape).metadata);
                assert!(
                    t_ext <= t_std * 1.0000001,
                    "A100-tuned table regressed at B={batch} L_K={l_k}: {t_ext:.3} vs {t_std:.3}"
                );
            }
        }
    }
}
