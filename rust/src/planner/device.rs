//! Device profiles: the accelerator facts split planning depends on.
//!
//! The seed hardcoded `H100_NUM_SMS = 132` into the heuristics module —
//! §2.2's own critique ("the static threshold overlooks the hardware scale
//! of H100") applied to us. A [`DeviceProfile`] carries the SM count, the
//! per-SM CTA budget for this kernel family, the upstream split cap, and a
//! coarse combine-overhead model, so the same policies plan correctly for
//! any part. The measurement-grade latency model stays in
//! [`crate::sim::Calibration`]; the profile's [`CombineModel`] is only the
//! planner-side estimate used for plan diagnostics.

/// Coarse per-device estimate of the split-combine reduction cost. The
/// paper's trade-off (§5.3): more splits ⇒ more partials to combine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CombineModel {
    /// Fixed cost of launching the combine kernel at all (s > 1), µs.
    pub base_us: f64,
    /// Marginal cost per non-empty partial, µs.
    pub per_partial_us: f64,
}

impl CombineModel {
    /// Estimated combine cost for `effective_splits` non-empty partials.
    pub fn estimate_us(&self, effective_splits: usize) -> f64 {
        if effective_splits <= 1 {
            return 0.0;
        }
        self.base_us + self.per_partial_us * (effective_splits - 1) as f64
    }
}

/// Static description of the accelerator the planner targets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Streaming multiprocessors available to compute grids.
    pub num_sms: usize,
    /// CTAs of this kernel family that fit per SM per wave. The FA3 decode
    /// kernel is register/SMEM-bound enough that one CTA owns an SM, so
    /// every current preset uses 1; a lighter kernel would raise it and
    /// the planner's wave math follows.
    pub max_ctas_per_sm: usize,
    /// Cap on `num_splits` (the upstream FA3 launch-grid limit).
    pub max_splits: usize,
    /// Peak HBM bandwidth, GB/s (arithmetic-intensity context; feeds the
    /// simulator's [`crate::sim::GpuSpec`] conversion).
    pub hbm_bw_gbps: f64,
    /// L2 capacity in bytes.
    pub l2_bytes: usize,
    /// Planner-side combine-overhead estimate.
    pub combine: CombineModel,
}

impl DeviceProfile {
    /// NVIDIA H100 SXM5 — the paper's testbed (§2.1: 132 SMs).
    pub const H100_SXM: DeviceProfile = DeviceProfile {
        name: "H100-SXM5",
        num_sms: 132,
        max_ctas_per_sm: 1,
        max_splits: 128,
        hbm_bw_gbps: 3350.0,
        l2_bytes: 50 * 1024 * 1024,
        combine: CombineModel { base_us: 0.40, per_partial_us: 0.30 },
    };

    /// H100 PCIe variant: fewer SMs, lower bandwidth.
    pub const H100_PCIE: DeviceProfile = DeviceProfile {
        name: "H100-PCIe",
        num_sms: 114,
        max_ctas_per_sm: 1,
        max_splits: 128,
        hbm_bw_gbps: 2000.0,
        l2_bytes: 50 * 1024 * 1024,
        combine: CombineModel { base_us: 0.40, per_partial_us: 0.30 },
    };

    /// A100 SXM4 — the generation the upstream heuristic was tuned on.
    pub const A100_SXM: DeviceProfile = DeviceProfile {
        name: "A100-SXM4",
        num_sms: 108,
        max_ctas_per_sm: 1,
        max_splits: 128,
        hbm_bw_gbps: 2039.0,
        l2_bytes: 40 * 1024 * 1024,
        // Older atomics/reduction path: slightly pricier per partial.
        combine: CombineModel { base_us: 0.45, per_partial_us: 0.35 },
    };

    /// H200 SXM — same GH100 compute die as H100 SXM (132 SMs), HBM3e.
    pub const H200_SXM: DeviceProfile = DeviceProfile {
        name: "H200-SXM",
        num_sms: 132,
        max_ctas_per_sm: 1,
        max_splits: 128,
        hbm_bw_gbps: 4800.0,
        l2_bytes: 50 * 1024 * 1024,
        combine: CombineModel { base_us: 0.40, per_partial_us: 0.30 },
    };

    /// All built-in presets.
    pub fn presets() -> [DeviceProfile; 4] {
        [Self::H100_SXM, Self::H100_PCIE, Self::A100_SXM, Self::H200_SXM]
    }

    /// `H100-SXM5|H100-PCIe|…` — CLI help/error listing derived from the
    /// presets, so new profiles appear everywhere automatically.
    pub fn help_line() -> String {
        Self::presets().map(|p| p.name.to_string()).join("|")
    }

    /// Look up a preset by CLI-friendly name (`h100-sxm`, `h100`, `h100-pcie`,
    /// `a100`, `a100-sxm`, `h200`, `h200-sxm`, or the display name).
    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        let lower = name.to_ascii_lowercase();
        match lower.as_str() {
            "h100" | "h100-sxm" | "h100-sxm5" => Some(Self::H100_SXM),
            "h100-pcie" => Some(Self::H100_PCIE),
            "a100" | "a100-sxm" | "a100-sxm4" => Some(Self::A100_SXM),
            "h200" | "h200-sxm" => Some(Self::H200_SXM),
            _ => Self::presets()
                .into_iter()
                .find(|p| p.name.eq_ignore_ascii_case(&lower)),
        }
    }

    /// SMs available to the grid once `sm_margin` is reserved for the
    /// combine scheduler (§3.1 knob). Saturating: an over-large margin
    /// degrades to a single-SM budget instead of panicking.
    pub fn sm_budget(&self, sm_margin: usize) -> usize {
        self.num_sms.saturating_sub(sm_margin).max(1)
    }

    /// CTAs one wave can retire under `sm_margin`.
    pub fn wave_capacity(&self, sm_margin: usize) -> usize {
        self.sm_budget(sm_margin) * self.max_ctas_per_sm.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_matches_paper_constants() {
        assert_eq!(DeviceProfile::H100_SXM.num_sms, 132); // §2.1
        assert_eq!(DeviceProfile::H100_SXM.max_splits, 128);
    }

    #[test]
    fn budget_saturates() {
        let p = DeviceProfile::H100_SXM;
        assert_eq!(p.sm_budget(0), 132);
        assert_eq!(p.sm_budget(32), 100);
        assert_eq!(p.sm_budget(10_000), 1);
        assert_eq!(p.wave_capacity(0), 132);
    }

    #[test]
    fn name_lookup() {
        assert_eq!(DeviceProfile::by_name("h100").unwrap().num_sms, 132);
        assert_eq!(DeviceProfile::by_name("H100-PCIe").unwrap().num_sms, 114);
        assert_eq!(DeviceProfile::by_name("a100").unwrap().num_sms, 108);
        assert_eq!(DeviceProfile::by_name("h200").unwrap().hbm_bw_gbps, 4800.0);
        assert!(DeviceProfile::by_name("tpu-v5").is_none());
    }

    #[test]
    fn help_line_lists_every_preset() {
        let help = DeviceProfile::help_line();
        for p in DeviceProfile::presets() {
            assert!(help.contains(p.name), "{help}");
            // Every listed name round-trips through the lookup.
            assert_eq!(DeviceProfile::by_name(p.name).unwrap().name, p.name);
        }
    }

    #[test]
    fn combine_estimate_shape() {
        let c = DeviceProfile::H100_SXM.combine;
        assert_eq!(c.estimate_us(1), 0.0);
        assert!(c.estimate_us(3) > c.estimate_us(2));
        // A100's combine is never cheaper than H100's at equal partials.
        assert!(
            DeviceProfile::A100_SXM.combine.estimate_us(4) >= c.estimate_us(4)
        );
    }
}
