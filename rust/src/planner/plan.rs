//! [`LaunchPlan`]: the planner's output — launch metadata plus the derived
//! occupancy facts every consumer used to recompute for itself.

use crate::heuristics::SchedulerMetadata;

/// One planned decode-attention launch.
///
/// `metadata` is the exact [`SchedulerMetadata`] the kernel launch (or the
/// simulator) consumes — the `get_scheduler_metadata()` analog. The rest
/// are derived quantities (CTA grid, wave count, first-wave occupancy, the
/// device-profile combine estimate) so call sites stop doing their own
/// occupancy arithmetic against hardcoded SM counts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaunchPlan {
    pub metadata: SchedulerMetadata,
    /// Splits that actually receive work (`min`-saturated at `nblk`).
    pub effective_splits: usize,
    /// Active CTAs this launch puts on the device.
    pub grid_ctas: usize,
    /// Wave count after quantization onto the device's wave capacity.
    pub waves: usize,
    /// First-wave SM occupancy fraction (the §2.1 headline quantity).
    pub occupancy: f64,
    /// Device-profile estimate of the split-combine overhead, µs. Coarse —
    /// the simulator's calibration remains the measurement-grade model.
    pub combine_estimate_us: f64,
}

impl LaunchPlan {
    /// The chosen split count (≥ 1).
    pub fn num_splits(&self) -> usize {
        self.metadata.num_splits
    }

    /// The exact decode shape this plan was materialized for.
    pub fn shape(&self) -> &crate::heuristics::tiles::DecodeShape {
        &self.metadata.shape
    }
}
