//! Evolutionary search over split-scheduling heuristics — the OpenEvolve
//! analog (§3).
//!
//! The paper discovered the premature-guard flaw by letting an LLM-guided
//! evolutionary loop rewrite the Python-level scheduling logic
//! (`num_splits`, `pack_gqa`, `sm_margin`) against a live H100, with model
//! semantics frozen. We reproduce that discovery loop with the same search
//! space and the same fitness signal (TPOT on short-prompt Batch=1 chat
//! decode), swapping the live GPU for the calibrated simulator and the LLM
//! mutation proposer for typed mutations over a rule-DSL genome:
//!
//! * [`genome`]    — ordered condition→(s, pack_gqa, sm_margin) rules with
//!                   upstream fallback (what Figure 1's evolved Python is),
//! * [`mutate`]    — mutation + crossover operators,
//! * [`evaluator`] — fitness (panel TPOT) + the invalid-candidate rejector
//!                   (the paper's subprocess evaluator),
//! * [`search`]    — the generational loop.
//!
//! Genomes are executed through [`crate::planner::Planner`]
//! (`PlannerBuilder::genome(..)`), so candidates are scored on exactly the
//! launch path the serving stack deploys.

pub mod evaluator;
pub mod genome;
pub mod mutate;
pub mod search;

pub use evaluator::{EvalResult, Evaluator};
pub use genome::{Genome, Rule};
pub use mutate::Mutator;
pub use search::{Search, SearchConfig, SearchReport};
