//! Fitness evaluation + the invalid-candidate rejector.
//!
//! §3.2: "The evaluation framework compiled and cached target variants via
//! a subprocess evaluator, rejecting invalid or numerically unstable
//! candidates." Our analog: numerics are invariant by construction (split
//! count never changes the math — the L1 tests prove it) and out-of-range
//! knobs are clamped by the genome, so "invalid/unstable" maps to
//! *pathological* behavior: a genome is rejected if it regresses any
//! safety-panel configuration by more than `tolerance` (default 15%) —
//! e.g. forcing wide splits on dense grids, where the combine's atomic
//! contention bites (§5.3). Small off-target regressions are NOT rejected:
//! the paper's own Figure-1 candidate has them, which is exactly why §4
//! distills a conservative C++ rule afterwards.
//!
//! Fitness: mean attention TPOT (µs) over the §3.1 chat panel —
//! short-prompt, Batch = 1 generations — plus a tiny parsimony term so
//! equal-TPOT genomes prefer fewer rules (the paper's distillation
//! pressure toward a small upstreamable rule).

use crate::heuristics::tiles::DecodeShape;
use crate::planner::{DeviceProfile, Planner, PlannerBuilder};
use crate::sim::Simulator;
use crate::workload::chatgen::ChatWorkload;

use super::genome::Genome;

/// Evaluation outcome.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Mean TPOT over the fitness panel, µs (lower is better).
    pub tpot_us: f64,
    /// Fitness including parsimony (what selection uses).
    pub fitness: f64,
    /// None if valid; Some(reason) if rejected.
    pub rejected: Option<String>,
}

impl EvalResult {
    /// Whether the genome passed the regression gate.
    pub fn is_valid(&self) -> bool {
        self.rejected.is_none()
    }
}

/// The evaluator: simulator + panels.
pub struct Evaluator {
    sim: Simulator,
    /// Device profile the candidate planners target.
    device: DeviceProfile,
    /// (prompt_len, n_tokens) fitness generations (Batch = 1 chat).
    fitness_panel: Vec<(usize, usize)>,
    /// Safety shapes that must not regress vs upstream.
    safety_panel: Vec<DecodeShape>,
    /// Allowed relative regression before rejection (measurement noise).
    pub tolerance: f64,
    /// Parsimony weight, µs per rule.
    pub parsimony_us: f64,
}

impl Evaluator {
    /// An evaluator pricing genomes on `sim`.
    pub fn new(sim: Simulator) -> Evaluator {
        Evaluator {
            sim,
            device: DeviceProfile::H100_SXM,
            fitness_panel: ChatWorkload::evolution_panel(),
            safety_panel: crate::workload::shapes::regression_grid(),
            tolerance: 0.15,
            parsimony_us: 0.02,
        }
    }

    /// The planner a candidate genome is evaluated through — the same
    /// façade the serving stack uses, so fitness measures deployable
    /// behavior (rule knobs, device split cap, upstream fallback included).
    fn planner_for(&self, genome: &Genome) -> Planner {
        PlannerBuilder::genome(genome.clone()).device(self.device).build()
    }

    /// Mean attention TPOT of `genome` over the fitness panel.
    pub fn panel_tpot_us(&self, genome: &Genome) -> f64 {
        let mut planner = self.planner_for(genome);
        let mut total = 0.0;
        let mut steps = 0usize;
        for &(prompt, n_tokens) in &self.fitness_panel {
            for step in 0..n_tokens {
                let l_k = prompt + step + 1;
                let shape = DecodeShape::llama70b_tp8(1, l_k);
                let plan = planner.plan(&shape);
                total += self.sim.kernel_us(&plan.metadata);
                steps += 1;
            }
        }
        total / steps as f64
    }

    /// Full evaluation: fitness + safety rejection.
    pub fn evaluate(&self, genome: &Genome) -> EvalResult {
        // Safety: compare against upstream on the §5.3 grid.
        let mut upstream = self.planner_for(&Genome::upstream());
        let mut candidate = self.planner_for(genome);
        for shape in &self.safety_panel {
            let t_up = self.sim.kernel_us(&upstream.plan(shape).metadata);
            let t_ge = self.sim.kernel_us(&candidate.plan(shape).metadata);
            if t_ge > t_up * (1.0 + self.tolerance) {
                return EvalResult {
                    tpot_us: f64::INFINITY,
                    fitness: f64::INFINITY,
                    rejected: Some(format!(
                        "regression at B={} L_K={} H_KV={}: {:.2}µs vs upstream {:.2}µs",
                        shape.batch, shape.l_k, shape.h_kv, t_ge, t_up
                    )),
                };
            }
        }
        let tpot = self.panel_tpot_us(genome);
        EvalResult {
            tpot_us: tpot,
            fitness: tpot + self.parsimony_us * genome.complexity() as f64,
            rejected: None,
        }
    }

    /// The simulator fitness is priced on.
    pub fn simulator(&self) -> &Simulator {
        &self.sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evolve::genome::Rule;

    fn eval() -> Evaluator {
        Evaluator::new(Simulator::h100())
    }

    #[test]
    fn upstream_is_valid_baseline() {
        let e = eval();
        let r = e.evaluate(&Genome::upstream());
        assert!(r.is_valid());
        assert!(r.tpot_us > 0.0);
    }

    #[test]
    fn figure1_candidate_beats_upstream() {
        // The paper's evolved candidate must win on the chat panel —
        // that's the §3 observation that motivated everything.
        let e = eval();
        let up = e.evaluate(&Genome::upstream());
        let fig1 = e.evaluate(&Genome::figure1());
        assert!(fig1.is_valid(), "{:?}", fig1.rejected);
        assert!(
            fig1.tpot_us < up.tpot_us,
            "figure1 {:.2} should beat upstream {:.2}",
            fig1.tpot_us,
            up.tpot_us
        );
    }

    #[test]
    fn harmful_genome_rejected() {
        // Forcing huge splits on saturated dense shapes adds combine
        // overhead: the safety panel must reject it.
        let g = Genome {
            rules: vec![Rule {
                batch_max: usize::MAX,
                lk_min: 1,
                lk_max: usize::MAX,
                hkv_max: usize::MAX,
                num_splits: 64,
                pack_gqa: true,
                sm_margin: 0,
            }],
        };
        let r = eval().evaluate(&g);
        assert!(!r.is_valid());
        assert!(r.fitness.is_infinite());
    }

    #[test]
    fn parsimony_breaks_ties() {
        let e = eval();
        // Two genomes with identical decisions but different rule counts:
        // a redundant duplicate rule must score slightly worse.
        let lean = Genome {
            rules: vec![Rule {
                batch_max: 1,
                lk_min: 385,
                lk_max: 512,
                hkv_max: 2,
                num_splits: 3,
                pack_gqa: true,
                sm_margin: 0,
            }],
        };
        let mut fat = lean.clone();
        fat.rules.push(lean.rules[0].clone());
        let r_lean = e.evaluate(&lean);
        let r_fat = e.evaluate(&fat);
        assert!(r_lean.is_valid() && r_fat.is_valid());
        assert!((r_lean.tpot_us - r_fat.tpot_us).abs() < 1e-9);
        assert!(r_lean.fitness < r_fat.fitness);
    }
}
