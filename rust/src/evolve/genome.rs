//! The heuristic genome: an ordered rule list over the three knobs §3.1
//! exposed to the search (num_splits, pack_gqa, sm_margin).
//!
//! This is exactly the *shape* of the evolved Python heuristics the paper
//! shows (Figure 1): nested conditions on batch size and sequence length
//! selecting a split count. First matching rule wins; unmatched shapes
//! fall through to the upstream C++ heuristic, so a genome is always a
//! *delta* against upstream — the same property that made the paper's
//! final patch upstreamable.
//!
//! A genome is pure data: to turn it into launch schedules, build a
//! planner over it (`planner::PlannerBuilder::genome(genome)`) — the
//! planner applies the rules, the device's split cap, and the upstream
//! fallback, and is the only component that constructs scheduler metadata.

use crate::heuristics::tiles::DecodeShape;

/// One condition→action rule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rule {
    /// Applies when `batch <= batch_max`.
    pub batch_max: usize,
    /// Applies when `lk_min <= l_k <= lk_max`.
    pub lk_min: usize,
    pub lk_max: usize,
    /// Applies when `h_kv <= hkv_max`.
    pub hkv_max: usize,
    /// Action: forced split count.
    pub num_splits: usize,
    /// Action: GQA packing layout.
    pub pack_gqa: bool,
    /// Action: SMs reserved for the combine scheduler.
    pub sm_margin: usize,
}

impl Rule {
    /// Whether this rule's guards accept `shape`.
    pub fn matches(&self, shape: &DecodeShape) -> bool {
        shape.batch <= self.batch_max
            && (self.lk_min..=self.lk_max).contains(&shape.l_k)
            && shape.h_kv <= self.hkv_max
    }
}

/// An evolved heuristic: ordered rules with upstream fallback.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Genome {
    pub rules: Vec<Rule>,
}

impl Genome {
    /// The identity genome: always falls through to upstream.
    pub fn upstream() -> Genome {
        Genome { rules: Vec::new() }
    }

    /// The paper's Figure-1 evolved candidate, transcribed:
    /// `batch==1 → s=12, pack_gqa, margin 0; seqlen<256 → s=16`.
    pub fn figure1() -> Genome {
        Genome {
            rules: vec![
                Rule {
                    batch_max: 1,
                    lk_min: 1,
                    lk_max: 255,
                    hkv_max: usize::MAX,
                    num_splits: 16,
                    pack_gqa: true,
                    sm_margin: 0,
                },
                Rule {
                    batch_max: 1,
                    lk_min: 1,
                    lk_max: 512,
                    hkv_max: usize::MAX,
                    num_splits: 12,
                    pack_gqa: true,
                    sm_margin: 0,
                },
            ],
        }
    }

    /// Structural complexity (parsimony pressure in the fitness).
    pub fn complexity(&self) -> usize {
        self.rules.len()
    }

    /// Render as the Python-bindings heuristic the paper's Figure 1 shows —
    /// the human-readable artifact of the search.
    pub fn render_python(&self) -> String {
        let mut out = String::new();
        out.push_str("def num_splits_heuristic(batch_size, seqlen_k, num_heads_kv):\n");
        if self.rules.is_empty() {
            out.push_str("    return None  # defer to the C++ heuristic\n");
            return out;
        }
        for rule in &self.rules {
            let mut conds = Vec::new();
            if rule.batch_max != usize::MAX {
                conds.push(if rule.batch_max == 1 {
                    "batch_size == 1".to_string()
                } else {
                    format!("batch_size <= {}", rule.batch_max)
                });
            }
            if rule.lk_min > 1 {
                conds.push(format!("seqlen_k >= {}", rule.lk_min));
            }
            if rule.lk_max != usize::MAX {
                conds.push(format!("seqlen_k <= {}", rule.lk_max));
            }
            if rule.hkv_max != usize::MAX {
                conds.push(format!("num_heads_kv <= {}", rule.hkv_max));
            }
            let cond = if conds.is_empty() { "True".to_string() } else { conds.join(" and ") };
            out.push_str(&format!("    if {cond}:\n"));
            out.push_str(&format!(
                "        return dict(num_splits={}, pack_gqa={}, sm_margin={})\n",
                rule.num_splits,
                if rule.pack_gqa { "True" } else { "False" },
                rule.sm_margin
            ));
        }
        out.push_str("    return None  # defer to the C++ heuristic\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{DeviceProfile, Planner, PlannerBuilder};

    fn decide(g: &Genome, shape: &DecodeShape) -> usize {
        PlannerBuilder::genome(g.clone()).build().plan(shape).num_splits()
    }

    #[test]
    fn empty_genome_is_upstream() {
        let g = Genome::upstream();
        assert_eq!(decide(&g, &DecodeShape::llama70b_tp8(1, 512)), 1); // premature guard
        assert!(decide(&g, &DecodeShape::llama70b_tp8(1, 2048)) > 1); // efficiency loop
    }

    #[test]
    fn first_matching_rule_wins() {
        let g = Genome::figure1();
        // L_K = 200 matches the seqlen<256 rule first: s = 16.
        assert_eq!(decide(&g, &DecodeShape::llama70b_tp8(1, 200)), 16);
        // L_K = 400 falls to the second rule: s = 12.
        assert_eq!(decide(&g, &DecodeShape::llama70b_tp8(1, 400)), 12);
        // Batch 2 matches nothing: upstream (guard ⇒ 1).
        assert_eq!(decide(&g, &DecodeShape::llama70b_tp8(2, 400)), 1);
        // Beyond 512 matches nothing: falls through to upstream, which is
        // past the guard there (nblk = 5 ⇒ efficiency loop).
        let beyond = DecodeShape::llama70b_tp8(1, 513);
        let up = Planner::standard().plan(&beyond).num_splits();
        assert_eq!(decide(&g, &beyond), up);
        assert!(up > 1, "nblk=5 engages the efficiency loop");
    }

    #[test]
    fn split_counts_clamped() {
        let g = Genome {
            rules: vec![Rule {
                batch_max: usize::MAX,
                lk_min: 1,
                lk_max: usize::MAX,
                hkv_max: usize::MAX,
                num_splits: 10_000,
                pack_gqa: true,
                sm_margin: 0,
            }],
        };
        assert_eq!(
            decide(&g, &DecodeShape::llama70b_tp8(1, 512)),
            DeviceProfile::H100_SXM.max_splits
        );
    }

    #[test]
    fn render_python_shape() {
        let code = Genome::figure1().render_python();
        assert!(code.contains("batch_size == 1"));
        assert!(code.contains("num_splits=12"));
        assert!(code.contains("num_splits=16"));
        assert!(code.contains("seqlen_k <= 255"));
        let empty = Genome::upstream().render_python();
        assert!(empty.contains("defer to the C++ heuristic"));
    }
}
