//! The heuristic genome: an ordered rule list over the three knobs §3.1
//! exposed to the search (num_splits, pack_gqa, sm_margin).
//!
//! This is exactly the *shape* of the evolved Python heuristics the paper
//! shows (Figure 1): nested conditions on batch size and sequence length
//! selecting a split count. First matching rule wins; unmatched shapes
//! fall through to the upstream C++ heuristic, so a genome is always a
//! *delta* against upstream — the same property that made the paper's
//! final patch upstreamable.

use crate::heuristics::standard::num_splits_heuristic_upstream;
use crate::heuristics::tiles::DecodeShape;
use crate::heuristics::{DispatchPath, SchedulerMetadata, H100_NUM_SMS, MAX_SPLITS};

/// One condition→action rule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rule {
    /// Applies when `batch <= batch_max`.
    pub batch_max: usize,
    /// Applies when `lk_min <= l_k <= lk_max`.
    pub lk_min: usize,
    pub lk_max: usize,
    /// Applies when `h_kv <= hkv_max`.
    pub hkv_max: usize,
    /// Action: forced split count.
    pub num_splits: usize,
    /// Action: GQA packing layout.
    pub pack_gqa: bool,
    /// Action: SMs reserved for the combine scheduler.
    pub sm_margin: usize,
}

impl Rule {
    pub fn matches(&self, shape: &DecodeShape) -> bool {
        shape.batch <= self.batch_max
            && (self.lk_min..=self.lk_max).contains(&shape.l_k)
            && shape.h_kv <= self.hkv_max
    }
}

/// An evolved heuristic: ordered rules with upstream fallback.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Genome {
    pub rules: Vec<Rule>,
}

impl Genome {
    /// The identity genome: always falls through to upstream.
    pub fn upstream() -> Genome {
        Genome { rules: Vec::new() }
    }

    /// The paper's Figure-1 evolved candidate, transcribed:
    /// `batch==1 → s=12, pack_gqa, margin 0; seqlen<256 → s=16`.
    pub fn figure1() -> Genome {
        Genome {
            rules: vec![
                Rule {
                    batch_max: 1,
                    lk_min: 1,
                    lk_max: 255,
                    hkv_max: usize::MAX,
                    num_splits: 16,
                    pack_gqa: true,
                    sm_margin: 0,
                },
                Rule {
                    batch_max: 1,
                    lk_min: 1,
                    lk_max: 512,
                    hkv_max: usize::MAX,
                    num_splits: 12,
                    pack_gqa: true,
                    sm_margin: 0,
                },
            ],
        }
    }

    /// Decide the launch schedule for `shape`.
    pub fn decide(&self, shape: &DecodeShape) -> SchedulerMetadata {
        for rule in &self.rules {
            if rule.matches(shape) {
                let num_sm = H100_NUM_SMS.saturating_sub(rule.sm_margin).max(1);
                let _ = num_sm;
                return SchedulerMetadata {
                    shape: *shape,
                    num_splits: rule.num_splits.clamp(1, MAX_SPLITS),
                    pack_gqa: rule.pack_gqa,
                    sm_margin: rule.sm_margin,
                    path: DispatchPath::PrecomputedMetadata,
                };
            }
        }
        // Upstream fallback (pack_gqa on, no margin — upstream defaults).
        let splits = num_splits_heuristic_upstream(
            shape.total_mblocks(true),
            H100_NUM_SMS,
            shape.nblk(),
            MAX_SPLITS,
        );
        SchedulerMetadata {
            shape: *shape,
            num_splits: splits,
            pack_gqa: true,
            sm_margin: 0,
            path: DispatchPath::PrecomputedMetadata,
        }
    }

    /// Structural complexity (parsimony pressure in the fitness).
    pub fn complexity(&self) -> usize {
        self.rules.len()
    }

    /// Render as the Python-bindings heuristic the paper's Figure 1 shows —
    /// the human-readable artifact of the search.
    pub fn render_python(&self) -> String {
        let mut out = String::new();
        out.push_str("def num_splits_heuristic(batch_size, seqlen_k, num_heads_kv):\n");
        if self.rules.is_empty() {
            out.push_str("    return None  # defer to the C++ heuristic\n");
            return out;
        }
        for rule in &self.rules {
            let mut conds = Vec::new();
            if rule.batch_max != usize::MAX {
                conds.push(if rule.batch_max == 1 {
                    "batch_size == 1".to_string()
                } else {
                    format!("batch_size <= {}", rule.batch_max)
                });
            }
            if rule.lk_min > 1 {
                conds.push(format!("seqlen_k >= {}", rule.lk_min));
            }
            if rule.lk_max != usize::MAX {
                conds.push(format!("seqlen_k <= {}", rule.lk_max));
            }
            if rule.hkv_max != usize::MAX {
                conds.push(format!("num_heads_kv <= {}", rule.hkv_max));
            }
            let cond = if conds.is_empty() { "True".to_string() } else { conds.join(" and ") };
            out.push_str(&format!("    if {cond}:\n"));
            out.push_str(&format!(
                "        return dict(num_splits={}, pack_gqa={}, sm_margin={})\n",
                rule.num_splits,
                if rule.pack_gqa { "True" } else { "False" },
                rule.sm_margin
            ));
        }
        out.push_str("    return None  # defer to the C++ heuristic\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_genome_is_upstream() {
        let g = Genome::upstream();
        let shape = DecodeShape::llama70b_tp8(1, 512);
        let md = g.decide(&shape);
        assert_eq!(md.num_splits, 1); // premature guard
        let long = DecodeShape::llama70b_tp8(1, 2048);
        assert!(g.decide(&long).num_splits > 1); // efficiency loop
    }

    #[test]
    fn first_matching_rule_wins() {
        let g = Genome::figure1();
        // L_K = 200 matches the seqlen<256 rule first: s = 16.
        assert_eq!(g.decide(&DecodeShape::llama70b_tp8(1, 200)).num_splits, 16);
        // L_K = 400 falls to the second rule: s = 12.
        assert_eq!(g.decide(&DecodeShape::llama70b_tp8(1, 400)).num_splits, 12);
        // Batch 2 matches nothing: upstream (guard ⇒ 1).
        assert_eq!(g.decide(&DecodeShape::llama70b_tp8(2, 400)).num_splits, 1);
        // Beyond 512 matches nothing: falls through to upstream, which is
        // past the guard there (nblk = 5 ⇒ efficiency loop).
        let beyond = DecodeShape::llama70b_tp8(1, 513);
        let up = crate::heuristics::standard::num_splits_heuristic_upstream(
            beyond.total_mblocks(true),
            H100_NUM_SMS,
            beyond.nblk(),
            MAX_SPLITS,
        );
        assert_eq!(g.decide(&beyond).num_splits, up);
        assert!(up > 1, "nblk=5 engages the efficiency loop");
    }

    #[test]
    fn split_counts_clamped() {
        let g = Genome {
            rules: vec![Rule {
                batch_max: usize::MAX,
                lk_min: 1,
                lk_max: usize::MAX,
                hkv_max: usize::MAX,
                num_splits: 10_000,
                pack_gqa: true,
                sm_margin: 0,
            }],
        };
        assert_eq!(g.decide(&DecodeShape::llama70b_tp8(1, 512)).num_splits, MAX_SPLITS);
    }

    #[test]
    fn render_python_shape() {
        let code = Genome::figure1().render_python();
        assert!(code.contains("batch_size == 1"));
        assert!(code.contains("num_splits=12"));
        assert!(code.contains("num_splits=16"));
        assert!(code.contains("seqlen_k <= 255"));
        let empty = Genome::upstream().render_python();
        assert!(empty.contains("defer to the C++ heuristic"));
    }
}
