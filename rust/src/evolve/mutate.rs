//! Mutation and crossover operators over the rule-DSL genome.
//!
//! These replace the paper's LLM proposer: typed edits that preserve
//! well-formedness (ranges stay ordered, knobs stay in bounds) while
//! exploring the same space the LLM explored — split counts, sequence
//! ranges, batch/head conditions, layout and margin knobs.

use crate::util::prng::Rng;

use super::genome::{Genome, Rule};

/// Bounds for generated rules.
const LK_MAX: usize = 8192;
const SPLIT_CHOICES: [usize; 10] = [1, 2, 3, 4, 6, 8, 12, 16, 24, 32];
const MARGIN_CHOICES: [usize; 4] = [0, 4, 8, 16];

/// Mutation engine.
pub struct Mutator {
    /// Probability of structural edits (add/remove rule) vs knob tweaks.
    pub p_structural: f64,
}

impl Default for Mutator {
    fn default() -> Self {
        Mutator { p_structural: 0.3 }
    }
}

impl Mutator {
    /// A random well-formed rule (used for seeding and add-rule edits).
    pub fn random_rule(&self, rng: &mut Rng) -> Rule {
        let lk_a = rng.range(1, LK_MAX);
        let lk_b = rng.range(1, LK_MAX);
        Rule {
            batch_max: *rng.choose(&[1, 1, 1, 2, 4, usize::MAX]),
            lk_min: lk_a.min(lk_b),
            lk_max: lk_a.max(lk_b),
            hkv_max: *rng.choose(&[1, 2, 2, 4, 8, usize::MAX]),
            num_splits: *rng.choose(&SPLIT_CHOICES),
            pack_gqa: rng.chance(0.85),
            sm_margin: *rng.choose(&MARGIN_CHOICES),
        }
    }

    /// A random single-rule genome (initial population).
    pub fn random_genome(&self, rng: &mut Rng) -> Genome {
        let n = rng.range(1, 2);
        Genome { rules: (0..n).map(|_| self.random_rule(rng)).collect() }
    }

    /// Mutate in place (always changes something).
    pub fn mutate(&self, genome: &mut Genome, rng: &mut Rng) {
        if genome.rules.is_empty() || rng.chance(self.p_structural) {
            self.mutate_structure(genome, rng);
        } else {
            self.mutate_knob(genome, rng);
        }
    }

    fn mutate_structure(&self, genome: &mut Genome, rng: &mut Rng) {
        let can_remove = genome.rules.len() > 1;
        if genome.rules.is_empty() || (!can_remove && genome.rules.len() < 4) && rng.chance(0.7) {
            genome.rules.push(self.random_rule(rng));
        } else if can_remove && rng.chance(0.5) {
            let i = rng.range(0, genome.rules.len() - 1);
            genome.rules.remove(i);
        } else if genome.rules.len() >= 2 && rng.chance(0.5) {
            // Swap priority of two rules.
            let i = rng.range(0, genome.rules.len() - 2);
            genome.rules.swap(i, i + 1);
        } else if genome.rules.len() < 6 {
            genome.rules.push(self.random_rule(rng));
        }
    }

    fn mutate_knob(&self, genome: &mut Genome, rng: &mut Rng) {
        let i = rng.range(0, genome.rules.len() - 1);
        let rule = &mut genome.rules[i];
        match rng.range(0, 6) {
            0 => {
                // Nudge or resample the split count.
                rule.num_splits = match rng.range(0, 2) {
                    0 => (rule.num_splits + 1).min(64),
                    1 => rule.num_splits.saturating_sub(1).max(1),
                    _ => *rng.choose(&SPLIT_CHOICES),
                };
            }
            1 => {
                // Shift a sequence bound by a block-ish quantum.
                let delta = *rng.choose(&[64usize, 128, 256]);
                if rng.chance(0.5) {
                    rule.lk_max = (rule.lk_max.saturating_add(delta)).min(LK_MAX);
                } else {
                    rule.lk_max = rule.lk_max.saturating_sub(delta).max(rule.lk_min);
                }
            }
            2 => {
                let delta = *rng.choose(&[64usize, 128, 256]);
                if rng.chance(0.5) {
                    rule.lk_min = rule.lk_min.saturating_sub(delta).max(1);
                } else {
                    rule.lk_min = (rule.lk_min + delta).min(rule.lk_max);
                }
            }
            3 => rule.batch_max = *rng.choose(&[1, 1, 2, 4, 8, usize::MAX]),
            4 => rule.hkv_max = *rng.choose(&[1, 2, 2, 4, 8, usize::MAX]),
            _ => {
                if rng.chance(0.5) {
                    rule.pack_gqa = !rule.pack_gqa;
                } else {
                    rule.sm_margin = *rng.choose(&MARGIN_CHOICES);
                }
            }
        }
    }

    /// One-point crossover on rule lists.
    pub fn crossover(&self, a: &Genome, b: &Genome, rng: &mut Rng) -> Genome {
        if a.rules.is_empty() {
            return b.clone();
        }
        if b.rules.is_empty() {
            return a.clone();
        }
        let cut_a = rng.range(0, a.rules.len());
        let cut_b = rng.range(0, b.rules.len());
        let mut rules: Vec<Rule> = a.rules[..cut_a].to_vec();
        rules.extend_from_slice(&b.rules[cut_b..]);
        if rules.is_empty() {
            rules.push(a.rules[0].clone());
        }
        rules.truncate(6);
        Genome { rules }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wellformed(g: &Genome) -> bool {
        g.rules.iter().all(|r| {
            r.lk_min <= r.lk_max && r.num_splits >= 1 && r.num_splits <= 64 && r.sm_margin <= 16
        })
    }

    #[test]
    fn random_genomes_wellformed() {
        let m = Mutator::default();
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let g = m.random_genome(&mut rng);
            assert!(wellformed(&g));
            assert!(!g.rules.is_empty());
        }
    }

    #[test]
    fn mutation_preserves_wellformedness() {
        let m = Mutator::default();
        let mut rng = Rng::new(2);
        let mut g = m.random_genome(&mut rng);
        for _ in 0..500 {
            m.mutate(&mut g, &mut rng);
            assert!(wellformed(&g), "{g:?}");
            assert!(g.rules.len() <= 7);
        }
    }

    #[test]
    fn mutation_changes_something_often() {
        let m = Mutator::default();
        let mut rng = Rng::new(3);
        let base = m.random_genome(&mut rng);
        let mut changed = 0;
        for _ in 0..100 {
            let mut g = base.clone();
            m.mutate(&mut g, &mut rng);
            if g != base {
                changed += 1;
            }
        }
        assert!(changed > 80, "only {changed}/100 mutations changed the genome");
    }

    #[test]
    fn crossover_mixes_parents() {
        let m = Mutator::default();
        let mut rng = Rng::new(4);
        let a = Genome::figure1();
        let b = m.random_genome(&mut rng);
        let child = m.crossover(&a, &b, &mut rng);
        assert!(wellformed(&child));
        assert!(!child.rules.is_empty());
        // Empty parent yields the other parent.
        let up = Genome::upstream();
        assert_eq!(m.crossover(&up, &a, &mut rng), a);
        assert_eq!(m.crossover(&a, &up, &mut rng), a);
    }
}
