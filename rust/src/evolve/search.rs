//! The generational search loop (the OpenEvolve driver analog).
//!
//! Standard (µ + λ) EA with tournament selection, elitism, and the
//! evaluator's reject-on-regression filter. Deterministic in the seed, so
//! the §3 reproduction in EXPERIMENTS.md is exactly replayable.

use crate::sim::Simulator;
use crate::util::prng::Rng;

use super::evaluator::Evaluator;
use super::genome::Genome;
use super::mutate::Mutator;

/// Search configuration.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub seed: u64,
    pub population: usize,
    pub generations: usize,
    pub tournament: usize,
    pub elites: usize,
    pub p_crossover: f64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            seed: 0x0E501,
            population: 48,
            generations: 30,
            tournament: 4,
            elites: 4,
            p_crossover: 0.4,
        }
    }
}

/// Per-generation history entry.
#[derive(Debug, Clone)]
pub struct GenerationStats {
    pub generation: usize,
    pub best_tpot_us: f64,
    pub mean_valid_tpot_us: f64,
    pub rejected: usize,
}

/// Search outcome.
#[derive(Debug, Clone)]
pub struct SearchReport {
    pub best: Genome,
    pub best_tpot_us: f64,
    pub upstream_tpot_us: f64,
    pub history: Vec<GenerationStats>,
}

impl SearchReport {
    /// Best-genome speedup over the upstream heuristic.
    pub fn speedup(&self) -> f64 {
        self.upstream_tpot_us / self.best_tpot_us
    }
}

/// The search driver.
pub struct Search {
    cfg: SearchConfig,
    evaluator: Evaluator,
    mutator: Mutator,
}

impl Search {
    /// A search with population seeded from the upstream heuristic.
    pub fn new(cfg: SearchConfig, sim: Simulator) -> Search {
        Search { cfg, evaluator: Evaluator::new(sim), mutator: Mutator::default() }
    }

    /// The fitness evaluator (read-only).
    pub fn evaluator(&self) -> &Evaluator {
        &self.evaluator
    }

    /// Run the search. `log` receives one line per generation.
    pub fn run(&self, mut log: impl FnMut(&GenerationStats)) -> SearchReport {
        let mut rng = Rng::new(self.cfg.seed);
        let upstream_tpot = self.evaluator.panel_tpot_us(&Genome::upstream());

        // Seed population: upstream identity + randoms (the paper seeded
        // with the existing heuristic as generation zero).
        let mut population: Vec<Genome> = vec![Genome::upstream()];
        while population.len() < self.cfg.population {
            population.push(self.mutator.random_genome(&mut rng));
        }

        let mut scored: Vec<(Genome, f64)> = Vec::new();
        let mut history = Vec::new();

        for generation in 0..self.cfg.generations {
            let mut rejected = 0usize;
            scored = population
                .iter()
                .map(|g| {
                    let r = self.evaluator.evaluate(g);
                    if !r.is_valid() {
                        rejected += 1;
                    }
                    (g.clone(), r.fitness)
                })
                .collect();
            scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

            let valid: Vec<f64> =
                scored.iter().map(|s| s.1).filter(|f| f.is_finite()).collect();
            let stats = GenerationStats {
                generation,
                best_tpot_us: self.evaluator.panel_tpot_us(&scored[0].0),
                mean_valid_tpot_us: if valid.is_empty() {
                    f64::INFINITY
                } else {
                    valid.iter().sum::<f64>() / valid.len() as f64
                },
                rejected,
            };
            log(&stats);
            history.push(stats);

            // Next generation: elites + offspring.
            let mut next: Vec<Genome> =
                scored.iter().take(self.cfg.elites).map(|s| s.0.clone()).collect();
            while next.len() < self.cfg.population {
                let parent_a = self.tournament(&scored, &mut rng);
                let mut child = if rng.chance(self.cfg.p_crossover) {
                    let parent_b = self.tournament(&scored, &mut rng);
                    self.mutator.crossover(parent_a, parent_b, &mut rng)
                } else {
                    parent_a.clone()
                };
                self.mutator.mutate(&mut child, &mut rng);
                next.push(child);
            }
            population = next;
        }

        let best = scored[0].0.clone();
        let best_tpot_us = self.evaluator.panel_tpot_us(&best);
        SearchReport { best, best_tpot_us, upstream_tpot_us: upstream_tpot, history }
    }

    fn tournament<'a>(&self, scored: &'a [(Genome, f64)], rng: &mut Rng) -> &'a Genome {
        let mut best: Option<&(Genome, f64)> = None;
        for _ in 0..self.cfg.tournament {
            let cand = rng.choose(scored);
            if best.map(|b| cand.1 < b.1).unwrap_or(true) {
                best = Some(cand);
            }
        }
        &best.unwrap().0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::tiles::DecodeShape;

    fn quick_cfg(seed: u64) -> SearchConfig {
        SearchConfig { seed, population: 24, generations: 12, ..Default::default() }
    }

    #[test]
    fn search_rediscovers_splitting_in_low_tile_regime() {
        // The §3 result: evolution finds that forcing num_splits > 1 for
        // short single-batch prompts beats the upstream guard.
        let search = Search::new(quick_cfg(7), Simulator::h100());
        let report = search.run(|_| {});
        assert!(
            report.speedup() > 1.05,
            "search should beat upstream: {:.3} ({:.2} vs {:.2} µs)",
            report.speedup(),
            report.best_tpot_us,
            report.upstream_tpot_us
        );
        // The winning genome must split the boundary-bucket shape.
        let mut planner = crate::planner::PlannerBuilder::genome(report.best.clone()).build();
        let plan = planner.plan(&DecodeShape::llama70b_tp8(1, 512));
        assert!(plan.num_splits() > 1, "best genome: {:?}", report.best);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = Search::new(quick_cfg(9), Simulator::h100()).run(|_| {});
        let b = Search::new(quick_cfg(9), Simulator::h100()).run(|_| {});
        assert_eq!(a.best, b.best);
        assert_eq!(a.best_tpot_us, b.best_tpot_us);
    }

    #[test]
    fn best_never_regresses_across_generations() {
        let search = Search::new(quick_cfg(11), Simulator::h100());
        let report = search.run(|_| {});
        let mut last = f64::INFINITY;
        for g in &report.history {
            assert!(
                g.best_tpot_us <= last + 1e-9,
                "elitism must keep the best: gen {} went {last} -> {}",
                g.generation,
                g.best_tpot_us
            );
            last = g.best_tpot_us;
        }
    }
}
