//! ASCII table formatter for paper-style report output.
//!
//! Every bench prints its reproduction of a paper table through this, so
//! the harness output is directly comparable with the rows in the paper
//! (EXPERIMENTS.md embeds these verbatim).

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple column-aligned table with a header row and separator.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Right; headers.len()],
            rows: Vec::new(),
        }
    }

    /// Set per-column alignment (defaults to left).
    pub fn align(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Append a row of string slices.
    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    /// Whether no rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render the table to an ASCII string.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| {
            let mut line = String::from("|");
            for i in 0..ncols {
                let pad = widths[i] - cells[i].chars().count();
                match aligns[i] {
                    Align::Left => {
                        line.push(' ');
                        line.push_str(&cells[i]);
                        line.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        line.push_str(&" ".repeat(pad + 1));
                        line.push_str(&cells[i]);
                        line.push(' ');
                    }
                }
                line.push('|');
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths, &vec![Align::Left; ncols]));
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format microseconds with two decimals, paper-style ("13.72").
pub fn us(v: f64) -> String {
    format!("{v:.2}")
}

/// Format a speedup ratio paper-style ("1.21x").
pub fn speedup(r: f64) -> String {
    format!("{r:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["L_K", "Speedup"]);
        t.row_strs(&["128", "1.00x"]);
        t.row_strs(&["512", "1.21x"]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines same width.
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
        assert!(lines[0].contains("L_K"));
        assert!(lines[3].contains("1.21x"));
    }

    #[test]
    fn left_align() {
        let mut t = Table::new(&["name", "v"]).align(&[Align::Left, Align::Right]);
        t.row_strs(&["ab", "1"]);
        t.row_strs(&["longer", "22"]);
        let out = t.render();
        assert!(out.contains("| ab     |"));
        assert!(out.contains("|  1 |"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(us(13.7234), "13.72");
        assert_eq!(speedup(1.2068), "1.21x");
    }

    #[test]
    fn empty_table_renders_header_only() {
        let t = Table::new(&["x"]);
        assert!(t.is_empty());
        assert_eq!(t.render().lines().count(), 2);
    }
}
