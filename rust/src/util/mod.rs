//! Self-contained utility substrates.
//!
//! The offline build environment only carries the `xla` crate's dependency
//! closure, so the conveniences a networked project would pull from
//! crates.io (serde, clap, rand, criterion, proptest) are implemented here
//! from scratch (DESIGN.md system inventory #19–#23). Each module is small,
//! fully tested, and exactly as featureful as this repo needs.

pub mod alloc_counter;
pub mod cli;
pub mod config;
pub mod json;
pub mod prng;
pub mod proptest_lite;
pub mod stats;
pub mod table;
