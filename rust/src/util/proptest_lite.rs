//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! Seeded random-case generation with greedy shrinking for integer tuples:
//! on failure the runner re-tries with each coordinate halved/decremented
//! toward its lower bound and reports the smallest failing case. It covers
//! what this repo needs — invariants over small integer spaces (shapes,
//! split counts, block accounting) — not general strategy combinators.

use super::prng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed folds in the env override FA3_PROPTEST_SEED when present so
        // failures can be replayed exactly.
        let seed = std::env::var("FA3_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5eed_fa35);
        Config { cases: 256, seed, max_shrink_steps: 400 }
    }
}

/// An inclusive integer range used as a generation domain.
#[derive(Debug, Clone, Copy)]
pub struct Domain {
    pub lo: u64,
    pub hi: u64,
}

impl Domain {
    /// An inclusive `[lo, hi]` domain.
    pub fn new(lo: u64, hi: u64) -> Domain {
        assert!(lo <= hi);
        Domain { lo, hi }
    }

    fn sample(&self, rng: &mut Rng) -> u64 {
        let span = self.hi - self.lo;
        if span == u64::MAX {
            // Full-width domain: `span + 1` would overflow below().
            return rng.next_u64();
        }
        self.lo + rng.below(span + 1)
    }
}

/// Outcome of a failed property including the shrunk counterexample.
#[derive(Debug)]
pub struct Failure {
    pub case: Vec<u64>,
    pub shrunk: Vec<u64>,
    pub message: String,
}

/// Check `prop` over `cases` random points of the cartesian product of
/// `domains`. Panics with the shrunk counterexample on failure.
pub fn check<F>(name: &str, domains: &[Domain], prop: F)
where
    F: Fn(&[u64]) -> Result<(), String>,
{
    check_with(Config::default(), name, domains, prop)
}

/// Like [`check`], with an explicit configuration.
pub fn check_with<F>(cfg: Config, name: &str, domains: &[Domain], prop: F)
where
    F: Fn(&[u64]) -> Result<(), String>,
{
    if let Some(f) = run(&cfg, domains, &prop) {
        panic!(
            "property '{name}' failed\n  original: {:?}\n  shrunk:   {:?}\n  error: {}\n  replay: FA3_PROPTEST_SEED={}",
            f.case, f.shrunk, f.message, cfg.seed
        );
    }
}

/// Non-panicking variant (used to test the framework itself).
pub fn run<F>(cfg: &Config, domains: &[Domain], prop: &F) -> Option<Failure>
where
    F: Fn(&[u64]) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for _ in 0..cfg.cases {
        let case: Vec<u64> = domains.iter().map(|d| d.sample(&mut rng)).collect();
        if let Err(msg) = prop(&case) {
            let (shrunk, message) = shrink(cfg, domains, prop, case.clone(), msg);
            return Some(Failure { case, shrunk, message });
        }
    }
    None
}

fn shrink<F>(
    cfg: &Config,
    domains: &[Domain],
    prop: &F,
    mut best: Vec<u64>,
    mut best_msg: String,
) -> (Vec<u64>, String)
where
    F: Fn(&[u64]) -> Result<(), String>,
{
    let mut steps = 0;
    loop {
        let mut improved = false;
        for i in 0..best.len() {
            // Candidate moves toward the domain floor: halve the distance,
            // then decrement.
            let lo = domains[i].lo;
            let cur = best[i];
            for cand in [lo + (cur - lo) / 2, cur.saturating_sub(1).max(lo)] {
                if cand == cur {
                    continue;
                }
                steps += 1;
                if steps > cfg.max_shrink_steps {
                    return (best, best_msg);
                }
                let mut trial = best.clone();
                trial[i] = cand;
                if let Err(msg) = prop(&trial) {
                    best = trial;
                    best_msg = msg;
                    improved = true;
                    break;
                }
            }
        }
        if !improved {
            return (best, best_msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", &[Domain::new(0, 100), Domain::new(0, 100)], |c| {
            if c[0] + c[1] == c[1] + c[0] {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let cfg = Config { cases: 500, seed: 1, max_shrink_steps: 500 };
        let f = run(&cfg, &[Domain::new(0, 1000)], &|c: &[u64]| {
            if c[0] < 50 {
                Ok(())
            } else {
                Err(format!("{} >= 50", c[0]))
            }
        })
        .expect("property should fail");
        assert_eq!(f.shrunk, vec![50], "should shrink to the minimal failure");
    }

    #[test]
    fn shrink_respects_domain_floor() {
        let cfg = Config { cases: 100, seed: 2, max_shrink_steps: 500 };
        let f = run(&cfg, &[Domain::new(10, 100)], &|_c: &[u64]| {
            Err("always fails".to_string())
        })
        .expect("fails");
        assert_eq!(f.shrunk, vec![10]);
    }

    #[test]
    fn multi_dim_shrink() {
        let cfg = Config { cases: 500, seed: 3, max_shrink_steps: 1000 };
        let f = run(&cfg, &[Domain::new(1, 64), Domain::new(1, 64)], &|c: &[u64]| {
            if c[0] * c[1] < 12 {
                Ok(())
            } else {
                Err("product too big".into())
            }
        })
        .expect("fails");
        assert!(f.shrunk[0] * f.shrunk[1] >= 12);
        // Minimal-ish: decrementing either coordinate should make it pass
        // (greedy local minimum).
        assert!((f.shrunk[0] - 1).max(1) * f.shrunk[1] < 12 || f.shrunk[0] == 1);
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = Config { cases: 50, seed: 7, max_shrink_steps: 10 };
        let run1 = run(&cfg, &[Domain::new(0, 9)], &|c: &[u64]| {
            if c[0] != 7 { Ok(()) } else { Err("hit 7".into()) }
        });
        let run2 = run(&cfg, &[Domain::new(0, 9)], &|c: &[u64]| {
            if c[0] != 7 { Ok(()) } else { Err("hit 7".into()) }
        });
        assert_eq!(run1.map(|f| f.case), run2.map(|f| f.case));
    }
}
