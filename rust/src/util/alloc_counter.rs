//! Counting global allocator: the measurement substrate behind the
//! zero-allocation decode hot-path guarantee.
//!
//! [`CountingAllocator`] wraps [`System`] and bumps a global counter on
//! every `alloc` / `alloc_zeroed` / `realloc` (deallocation is free to
//! ignore: the guarantee is about *acquiring* heap memory on the hot
//! path). It only counts when a binary registers it:
//!
//! ```ignore
//! #[global_allocator]
//! static COUNTER: fa3_split::util::alloc_counter::CountingAllocator =
//!     fa3_split::util::alloc_counter::CountingAllocator;
//! ```
//!
//! `tests/alloc_guard.rs` and `benches/decode_hot_path.rs` register it and
//! assert/report allocations across a measured window of engine steps
//! (warmup sizes every scratch buffer first; `EngineMetrics::
//! reserve_capacity` pre-grows the aggregate sample Vecs). In binaries
//! that don't register it, [`total_allocations`] stays 0 — callers must
//! always measure *deltas* across their window, never absolute values.
//!
//! Measurement discipline: the counter is process-global, so a guarded
//! window must not run concurrently with other allocating threads (the
//! guard test is a single `#[test]` in its own integration binary for
//! exactly that reason).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Heap-acquisition events since process start (0 unless a binary
/// registered [`CountingAllocator`] as its `#[global_allocator]`).
pub fn total_allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// A [`System`]-delegating allocator that counts acquisitions.
pub struct CountingAllocator;

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unregistered_counter_reads_monotonically() {
        // The lib test binary does not register the allocator, so the
        // counter is stable at whatever it was (0); this only checks the
        // read path is sound and non-panicking.
        let a = total_allocations();
        let b = total_allocations();
        assert!(b >= a);
    }
}
