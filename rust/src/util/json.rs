//! Minimal JSON parser + serializer (serde is unavailable offline).
//!
//! Covers the full JSON grammar (RFC 8259) minus some escape exotica we
//! don't emit: good enough to round-trip `artifacts/manifest.json`, config
//! files, and bench reports. Numbers are kept as `f64` with an `i64` fast
//! path preserved through [`Json::as_i64`].

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering
/// (reproducible serialized reports).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
/// A parse error with line/column context.
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value as i64, if integral.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    /// The numeric value as usize, if integral and non-negative.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key-value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns `Json::Null` for missing keys or
    /// non-objects, so lookups chain without panicking.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field helpers (error paths used by the manifest loader).
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    /// A required usize field of an object (error with the key name).
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    /// A required array field of an object (error with the key name).
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }

    // -- construction helpers ----------------------------------------------

    /// Build an object from key-value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array from any Json iterator.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Build an integer value.
    pub fn int(n: i64) -> Json {
        Json::Num(n as f64)
    }

    // -- serialization -----------------------------------------------------

    /// Compact single-line rendering.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed rendering (2-space indent).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: only BMP escapes are emitted by
                            // our serializer; accept lone surrogates as U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (the input is &str, so valid).
                    let rest = std::str::from_utf8(&self.b[self.i..]).unwrap();
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
        assert_eq!(v.get("a").as_arr().unwrap()[1].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"name":"a","shape":[1,8,128],"ok":true}],"version":2}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
        let v2 = Json::parse("\"\\u00e9\"").unwrap();
        assert_eq!(v2.as_str().unwrap(), "é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn integer_precision() {
        let v = Json::parse("123456789012").unwrap();
        assert_eq!(v.as_i64(), Some(123456789012));
        assert_eq!(v.to_string(), "123456789012");
        assert_eq!(Json::parse("1.5").unwrap().as_i64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }

    #[test]
    fn get_chains_safely() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("a").get("b").get("c"), &Json::Null);
        assert!(v.req_str("missing").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap().to_string(), "{}");
        assert_eq!(Json::parse("[ ]").unwrap().to_string(), "[]");
    }
}
