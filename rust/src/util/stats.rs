//! Summary statistics for latency samples (mean, percentiles, CI).
//!
//! Used by the bench harness (criterion substitute), the serving metrics,
//! and the A/B comparisons in the paper-table reproductions.

/// Summary of a sample of observations (e.g. per-iteration latencies in µs).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a sample set (mean, percentiles, spread).
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: sorted[n - 1],
        }
    }

    /// Half-width of the ~95% confidence interval on the mean
    /// (normal approximation; fine for the n >= 30 we use).
    pub fn ci95_half_width(&self) -> f64 {
        1.96 * self.std_dev / (self.n as f64).sqrt()
    }
}

/// Linear-interpolation percentile on a pre-sorted slice, `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Robust central estimate for A/B timing: the median is what the paper's
/// CUDA-Graph-replay methodology effectively reports (it interleaves and
/// discards outliers).
pub fn median(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("NaN"));
    percentile_sorted(&s, 50.0)
}

/// Geometric mean of ratios (the right average for speedups).
pub fn geomean(ratios: &[f64]) -> f64 {
    assert!(!ratios.is_empty());
    let log_sum: f64 = ratios.iter().map(|r| r.ln()).sum();
    (log_sum / ratios.len() as f64).exp()
}

/// Mean and interpolated p99 of an unsorted sample, in one call.
///
/// The serving benches used to hand-roll this pair with a biased
/// nearest-rank index (`v[len * 99 / 100]`), which disagrees with
/// [`Summary::of`] on small samples; they now share this helper so every
/// reported p99 uses the same interpolation as the engine metrics.
pub fn mean_p99(samples: &[f64]) -> (f64, f64) {
    assert!(!samples.is_empty(), "mean_p99 on empty sample");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    (mean, percentile_sorted(&sorted, 99.0))
}

/// Fixed-bucket histogram: ascending upper bounds plus an implicit
/// overflow (+Inf) bucket, with running sum and count.
///
/// This is the storage type behind the observability metrics registry
/// (`obs::MetricsRegistry`): bucket layout is fixed at construction so
/// [`Histogram::observe`] touches no heap — the serving step loop records
/// into pre-registered histograms from inside `no_alloc` regions.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    /// Histogram over explicit ascending upper bounds. A value `v` lands
    /// in the first bucket with `v <= bound`, else in the overflow bucket.
    pub fn new(bounds: Vec<f64>) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        let counts = vec![0u64; bounds.len() + 1];
        Histogram { bounds, counts, sum: 0.0, count: 0 }
    }

    /// `n` buckets of equal `width` starting at `start` (first upper bound
    /// is `start + width`).
    pub fn linear(start: f64, width: f64, n: usize) -> Histogram {
        assert!(width > 0.0 && n > 0);
        Histogram::new((1..=n).map(|i| start + width * i as f64).collect())
    }

    /// `n` buckets growing geometrically from `first` by `factor`.
    pub fn exponential(first: f64, factor: f64, n: usize) -> Histogram {
        assert!(first > 0.0 && factor > 1.0 && n > 0);
        let mut bounds = Vec::with_capacity(n);
        let mut b = first;
        for _ in 0..n {
            bounds.push(b);
            b *= factor;
        }
        Histogram::new(bounds)
    }

    /// Record one observation. Never allocates: bucket layout is fixed at
    /// construction, so this is safe inside measured `no_alloc` windows.
    // pallas-lint: no_alloc
    pub fn observe(&mut self, v: f64) {
        let mut idx = self.bounds.len();
        for (i, b) in self.bounds.iter().enumerate() {
            if v <= *b {
                idx = i;
                break;
            }
        }
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean of all observations, if any.
    pub fn mean(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum / self.count as f64)
    }

    /// Bucket upper bounds (the overflow bucket is implicit).
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts; the final entry is the overflow bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Quantile estimate `q` in [0, 1] by linear interpolation within the
    /// containing bucket (Prometheus `histogram_quantile` semantics; the
    /// overflow bucket clamps to its lower edge).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        assert!((0.0..=1.0).contains(&q));
        if self.count == 0 {
            return None;
        }
        let target = q * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let prev = cum;
            cum += c;
            if (cum as f64) >= target && c > 0 {
                if i == self.bounds.len() {
                    // Overflow bucket has no upper edge; clamp to its floor.
                    return Some(self.bounds.last().copied().unwrap_or(0.0));
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let frac = (target - prev as f64) / c as f64;
                return Some(lo + (hi - lo) * frac.clamp(0.0, 1.0));
            }
        }
        self.bounds.last().copied()
    }

    /// Merge another histogram recorded over the same bucket layout
    /// (fleet-level pooling of per-replica histograms).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "merging histograms with different buckets");
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.p50, 7.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.std_dev, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
        let sorted = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile_sorted(&sorted, 90.0) - 3.7).abs() < 1e-12);
    }

    #[test]
    fn median_unsorted_input() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[1.21, 1.24, 1.0]);
        assert!((g - (1.21f64 * 1.24 * 1.0).powf(1.0 / 3.0)).abs() < 1e-12);
        assert_eq!(geomean(&[2.0]), 2.0);
    }

    #[test]
    fn ordering_insensitive() {
        let a = Summary::of(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        let b = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn mean_p99_matches_summary() {
        let samples: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let (mean, p99) = mean_p99(&samples);
        let s = Summary::of(&samples);
        assert!((mean - s.mean).abs() < 1e-12);
        assert!((p99 - s.p99).abs() < 1e-12);
    }

    #[test]
    fn histogram_bucketing_and_mean() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        for v in [0.5, 1.0, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        // v <= bound lands in that bucket: 0.5 and 1.0 in le=1.
        assert_eq!(h.counts(), &[2, 1, 1, 1]);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 106.0).abs() < 1e-12);
        assert!((h.mean().unwrap() - 21.2).abs() < 1e-12);
    }

    #[test]
    fn histogram_constructors() {
        let lin = Histogram::linear(0.0, 0.25, 4);
        assert_eq!(lin.bounds(), &[0.25, 0.5, 0.75, 1.0]);
        let exp = Histogram::exponential(100.0, 2.0, 3);
        assert_eq!(exp.bounds(), &[100.0, 200.0, 400.0]);
    }

    #[test]
    fn histogram_quantile_interpolates() {
        let mut h = Histogram::linear(0.0, 10.0, 10);
        for i in 0..100 {
            h.observe(i as f64 + 0.5);
        }
        assert_eq!(h.quantile(0.5), Some(50.0));
        assert_eq!(h.quantile(0.9), Some(90.0));
        assert_eq!(Histogram::linear(0.0, 1.0, 2).quantile(0.5), None);
    }

    #[test]
    fn histogram_overflow_clamps() {
        let mut h = Histogram::new(vec![1.0]);
        h.observe(50.0);
        h.observe(60.0);
        assert_eq!(h.quantile(0.99), Some(1.0));
        assert_eq!(h.counts(), &[0, 2]);
    }

    #[test]
    fn histogram_merge_pools() {
        let mut a = Histogram::linear(0.0, 1.0, 3);
        let mut b = Histogram::linear(0.0, 1.0, 3);
        a.observe(0.5);
        b.observe(1.5);
        b.observe(2.5);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.counts(), &[1, 1, 1, 0]);
        assert!((a.sum() - 4.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn histogram_merge_rejects_mismatched_buckets() {
        let mut a = Histogram::linear(0.0, 1.0, 3);
        a.merge(&Histogram::linear(0.0, 2.0, 3));
    }
}
