//! Summary statistics for latency samples (mean, percentiles, CI).
//!
//! Used by the bench harness (criterion substitute), the serving metrics,
//! and the A/B comparisons in the paper-table reproductions.

/// Summary of a sample of observations (e.g. per-iteration latencies in µs).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a sample set (mean, percentiles, spread).
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: sorted[n - 1],
        }
    }

    /// Half-width of the ~95% confidence interval on the mean
    /// (normal approximation; fine for the n >= 30 we use).
    pub fn ci95_half_width(&self) -> f64 {
        1.96 * self.std_dev / (self.n as f64).sqrt()
    }
}

/// Linear-interpolation percentile on a pre-sorted slice, `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Robust central estimate for A/B timing: the median is what the paper's
/// CUDA-Graph-replay methodology effectively reports (it interleaves and
/// discards outliers).
pub fn median(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("NaN"));
    percentile_sorted(&s, 50.0)
}

/// Geometric mean of ratios (the right average for speedups).
pub fn geomean(ratios: &[f64]) -> f64 {
    assert!(!ratios.is_empty());
    let log_sum: f64 = ratios.iter().map(|r| r.ln()).sum();
    (log_sum / ratios.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.p50, 7.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.std_dev, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
        let sorted = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile_sorted(&sorted, 90.0) - 3.7).abs() < 1e-12);
    }

    #[test]
    fn median_unsorted_input() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[1.21, 1.24, 1.0]);
        assert!((g - (1.21f64 * 1.24 * 1.0).powf(1.0 / 3.0)).abs() < 1e-12);
        assert_eq!(geomean(&[2.0]), 2.0);
    }

    #[test]
    fn ordering_insensitive() {
        let a = Summary::of(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        let b = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        Summary::of(&[]);
    }
}
