//! Minimal INI/TOML-subset config loader (toml/serde unavailable offline).
//!
//! Supports `[section]` headers, `key = value` lines, `#`/`;` comments,
//! and typed accessors. Used to load alternate simulator calibrations and
//! engine settings without recompiling (`--config` flags).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed config: section -> key -> raw value string.
#[derive(Debug, Clone, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    /// Parse INI-style text (sections, `key = value`, `#`/`;` comments).
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{line}'", lineno + 1);
            };
            let value = value.trim().trim_matches('"').to_string();
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(cfg)
    }

    /// Parse a config file from disk.
    pub fn load(path: &std::path::Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Config::parse(&text)
    }

    /// A raw value, if the section and key exist.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// A required float value.
    pub fn f64(&self, section: &str, key: &str) -> Result<f64> {
        let v = self
            .get(section, key)
            .with_context(|| format!("missing [{section}] {key}"))?;
        v.parse().with_context(|| format!("[{section}] {key} = '{v}' is not a number"))
    }

    /// A float value with a default.
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("[{section}] {key} = '{v}' is not a number")),
        }
    }

    /// A usize value with a default.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("[{section}] {key} = '{v}' is not an integer")),
        }
    }

    /// The section names, in file order.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

// The `[calibration]`-section overlay loader used to live here as
// `calibration_from`, but that gave util/ (the bottom layer) an upward
// dependency on sim/. It is now `crate::sim::Calibration::from_config`,
// which points the edge the right way (sim/ -> util/).

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# simulator calibration overrides
[calibration]
t_launch_us = 7.0
noise_rel_std = 0.01

[engine]
max_batch = 8
"#;

    #[test]
    fn parses_sections_and_values() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("calibration", "t_launch_us"), Some("7.0"));
        assert_eq!(c.usize_or("engine", "max_batch", 4).unwrap(), 8);
        assert_eq!(c.usize_or("engine", "missing", 4).unwrap(), 4);
        assert!(c.f64("nope", "x").is_err());
        assert_eq!(c.sections().count(), 2);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("key_without_equals").is_err());
        assert!(Config::parse("[ok]\nx = 1").is_ok());
        assert!(Config::parse("# only comments\n\n").is_ok());
    }

    #[test]
    fn quoted_values_unquoted() {
        let c = Config::parse("[s]\nname = \"H100\"").unwrap();
        assert_eq!(c.get("s", "name"), Some("H100"));
    }
}
