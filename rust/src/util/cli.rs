//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string. Each
//! binary declares its options up front so `--help` is accurate. Help and
//! default strings are owned (`Into<String>`), so callers can compose them
//! at runtime — e.g. `--policy` help listing the names registered in
//! `planner::PolicyRegistry` instead of a hardcoded copy.

use std::collections::BTreeMap;

/// Declared option for usage/help rendering and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: String,
    pub takes_value: bool,
    pub default: Option<String>,
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    about: &'static str,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declarative argument parser (the offline `clap` stand-in).
pub struct Parser {
    about: &'static str,
    specs: Vec<OptSpec>,
}

impl Parser {
    /// A parser with only `--help` registered.
    pub fn new(about: &'static str) -> Parser {
        Parser { about, specs: Vec::new() }
    }

    /// Register a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: impl Into<String>) -> Parser {
        self.specs.push(OptSpec { name, help: help.into(), takes_value: false, default: None });
        self
    }

    /// Register an optional `--name <value>` with a default.
    pub fn opt(
        mut self,
        name: &'static str,
        default: impl Into<String>,
        help: impl Into<String>,
    ) -> Parser {
        self.specs.push(OptSpec {
            name,
            help: help.into(),
            takes_value: true,
            default: Some(default.into()),
        });
        self
    }

    /// Register a required `--name <value>`.
    pub fn opt_req(mut self, name: &'static str, help: impl Into<String>) -> Parser {
        self.specs.push(OptSpec { name, help: help.into(), takes_value: true, default: None });
        self
    }

    /// Parse from process args; prints usage and exits on `--help` / error.
    pub fn parse(self) -> Args {
        let argv: Vec<String> = std::env::args().collect();
        match self.parse_from(&argv) {
            Ok(args) => args,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    /// Parse an argv slice (element 0 is the program name).
    pub fn parse_from(self, argv: &[String]) -> Result<Args, String> {
        let program = argv.first().cloned().unwrap_or_default();
        let mut args = Args {
            program,
            about: self.about,
            specs: self.specs,
            values: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        };
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(args.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = args
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", args.usage()))?
                    .clone();
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    args.values.insert(name, value);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    /// The generated `--help` text.
    pub fn usage(&self) -> String {
        let mut out = format!("{}\n\nUsage: {} [options] [args]\n\nOptions:\n", self.about, self.program);
        for s in &self.specs {
            let left = if s.takes_value {
                format!("  --{} <value>", s.name)
            } else {
                format!("  --{}", s.name)
            };
            let default = s
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("{left:28} {}{default}\n", s.help));
        }
        out
    }

    /// Whether a flag was passed.
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// An option's raw value, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .or_else(|| self.spec_default(name))
    }

    fn spec_default(&self, name: &str) -> Option<&str> {
        self.specs.iter().find(|s| s.name == name).and_then(|s| s.default.as_deref())
    }

    /// An option's value as a string (panics if undeclared).
    pub fn str(&self, name: &str) -> String {
        self.get(name)
            .unwrap_or_else(|| panic!("missing required option --{name}"))
            .to_string()
    }

    /// An option's value parsed as `usize` (exits with a message on garbage).
    pub fn usize(&self, name: &str) -> usize {
        let v = self.str(name);
        v.parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
    }

    /// An option's value parsed as `u64` (exits with a message on garbage).
    pub fn u64(&self, name: &str) -> u64 {
        let v = self.str(name);
        v.parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
    }

    /// An option's value parsed as `f64` (exits with a message on garbage).
    pub fn f64(&self, name: &str) -> f64 {
        let v = self.str(name);
        v.parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'"))
    }

    /// Positional (non-flag) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.iter().map(|x| x.to_string()))
            .collect()
    }

    fn parser() -> Parser {
        Parser::new("test tool")
            .flag("verbose", "talk more")
            .opt("steps", "100", "how many steps")
            .opt_req("name", "required name")
    }

    #[test]
    fn parses_flags_and_values() {
        let a = parser()
            .parse_from(&argv(&["--verbose", "--steps", "5", "--name=x", "pos1"]))
            .unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.usize("steps"), 5);
        assert_eq!(a.str("name"), "x");
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parser().parse_from(&argv(&["--name", "y"])).unwrap();
        assert_eq!(a.usize("steps"), 100);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parser().parse_from(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parser().parse_from(&argv(&["--steps"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parser().parse_from(&argv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = parser().parse_from(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--steps"));
        assert!(err.contains("default: 100"));
    }

    #[test]
    #[should_panic]
    fn missing_required_panics_on_access() {
        let a = parser().parse_from(&argv(&[])).unwrap();
        a.str("name");
    }

    #[test]
    fn equals_syntax() {
        let a = parser().parse_from(&argv(&["--steps=42", "--name=n"])).unwrap();
        assert_eq!(a.usize("steps"), 42);
    }

    #[test]
    fn runtime_composed_help() {
        // Owned help strings let callers inject runtime-registered values
        // (the policy registry's names) into usage text.
        let names = ["standard", "sequence-aware"].join("|");
        let p = Parser::new("tool").opt("policy", "standard", format!("split policy: {names}"));
        let err = p.parse_from(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("split policy: standard|sequence-aware"));
    }
}
