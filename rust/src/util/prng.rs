//! Deterministic PRNG: xoshiro256** (rand is unavailable offline).
//!
//! Used by the workload generators, the evolutionary search, and the
//! property-testing mini-framework. Deterministic seeding keeps every
//! experiment in EXPERIMENTS.md exactly reproducible.

/// xoshiro256** 1.0 by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// The next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift with rejection for
    /// unbiased results.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi, "range({lo}, {hi})");
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Fork an independent stream (for parallel workers) — distinct seeds
    /// derived from the parent state.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..5_000 {
            let v = r.range(5, 8);
            assert!((5..=8).contains(&v));
            lo_seen |= v == 5;
            hi_seen |= v == 8;
        }
        assert!(lo_seen && hi_seen);
        assert_eq!(r.range(4, 4), 4); // degenerate range
    }

    #[test]
    fn f64_in_unit_interval_mean_half() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(23);
        let mut a = parent.fork();
        let mut b = parent.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
