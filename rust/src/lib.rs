//! # fa3-split
//!
//! Reproduction stack for *"Sequence-Aware Split Heuristic to Mitigate SM
//! Underutilization in FlashAttention-3 Low-Head-Count Decoding"*.
//!
//! Layer 3 of the three-layer architecture (see DESIGN.md): a rust serving
//! coordinator that loads AOT-compiled JAX/Pallas artifacts via PJRT and
//! makes the paper's split-scheduling decision on the request path, plus
//! the substrates the reproduction needs — a calibrated H100 SM-level
//! latency simulator, both split heuristics, an evolutionary-search
//! harness (the OpenEvolve analog of §3), workload generators, and the
//! bench harnesses that regenerate every table and figure in the paper.
//!
//! Python never runs at request time: `make artifacts` lowers the model
//! and kernels once, and everything here is self-contained after that.

pub mod bench_harness;
pub mod coordinator;
pub mod evolve;
pub mod heuristics;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
