//! # fa3-split
//!
//! Reproduction stack for *"Sequence-Aware Split Heuristic to Mitigate SM
//! Underutilization in FlashAttention-3 Low-Head-Count Decoding"*.
//!
//! Layer 3 of the three-layer architecture (see DESIGN.md): a rust serving
//! coordinator that loads AOT-compiled JAX/Pallas artifacts via PJRT and
//! makes the paper's split-scheduling decision on the request path, plus
//! the substrates the reproduction needs — a calibrated H100 SM-level
//! latency simulator, the split heuristics, an evolutionary-search
//! harness (the OpenEvolve analog of §3), workload generators, and the
//! bench harnesses that regenerate every table and figure in the paper.
//!
//! ## Split planning: one façade
//!
//! All split planning flows through [`planner`] — the analog of FA3's
//! single `get_scheduler_metadata()` contract:
//!
//! ```
//! use fa3_split::heuristics::tiles::DecodeShape;
//! use fa3_split::planner::{DeviceProfile, PolicyRegistry};
//!
//! // Configure once: policy + device + launch knobs.
//! let mut planner = PolicyRegistry::builtin()
//!     .builder("sequence-aware").unwrap()
//!     .device(DeviceProfile::H100_SXM)
//!     .sm_margin(0)
//!     .build();
//! // Query per decode step (LRU shape-bucket cached).
//! let plan = planner.plan(&DecodeShape::llama70b_tp8(1, 512));
//! assert_eq!(plan.num_splits(), 3); // the paper's boundary override
//! ```
//!
//! [`heuristics`] keeps the pure decision functions (`SplitPolicy` and
//! the ported upstream/patched heuristics); [`coordinator`], [`sim`],
//! [`evolve`], the benches, and the CLI all consume plans from
//! [`planner::Planner`] — nothing else constructs scheduler metadata.
//!
//! ## Serving: one execution contract
//!
//! Execution mirrors planning: all serving flows through the
//! [`backend::ExecutionBackend`] trait ([`backend::SimBackend`],
//! [`backend::PjrtBackend`], [`backend::ReplayBackend`]) — no module
//! outside [`backend`] knows sim from PJRT. The engine is built via
//! `Engine::builder(Box<dyn ExecutionBackend>)`, and
//! `Engine::submit` returns a [`coordinator::RequestHandle`] carrying a
//! streaming token channel with per-request cancellation and deadlines;
//! admission runs behind a bounded-queue
//! [`coordinator::AdmissionController`] with priority classes and an
//! explicit [`coordinator::Backpressure`] rejection outcome
//! (DESIGN.md §Serving engine).
//!
//! ```
//! use fa3_split::backend::{AttnGeometry, SimBackend};
//! use fa3_split::coordinator::{Engine, Request, StreamEvent};
//! use fa3_split::planner::Planner;
//!
//! let mut engine = Engine::builder(Box::new(SimBackend::h100()))
//!     .planner(Planner::sequence_aware())
//!     .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
//!     .available_splits(vec![1, 3])
//!     .build()
//!     .unwrap();
//! let handle = engine.submit(Request::new(1, vec![7; 100], 4)).unwrap();
//! engine.run_until_idle().unwrap();
//! let tokens: Vec<i32> = std::iter::from_fn(|| handle.try_event())
//!     .filter_map(|ev| match ev {
//!         StreamEvent::Token { token, .. } => Some(token),
//!         _ => None,
//!     })
//!     .collect();
//! assert_eq!(tokens.len(), 4);
//! ```
//!
//! ## Cluster: TP sharding + fleet routing
//!
//! The level above one engine lives in [`cluster`]: a
//! [`cluster::ClusterTopology`] derives the per-shard geometry from a
//! tensor-parallel degree (TP is how production serving *enters* the
//! paper's low-head-count regime — a TP-8 shard of an 8-KV-head model
//! decodes with `H_KV = 1` per device), and a [`cluster::Fleet`] fans a
//! chat stream across replicas behind a [`cluster::Router`]
//! (round-robin / least-loaded / session-affinity):
//!
//! ```
//! use fa3_split::backend::AttnGeometry;
//! use fa3_split::cluster::{ClusterTopology, Fleet, FleetConfig, RoundRobin, TpConfig};
//! use fa3_split::planner::DeviceProfile;
//! use fa3_split::workload::ChatWorkload;
//!
//! let topology =
//!     ClusterTopology::builder(AttnGeometry { h_q: 64, h_kv: 8, d: 128, max_seq: 1024 })
//!         .tp(TpConfig::new(8)) // per-shard H_KV = 1: the paper's regime
//!         .replicas(2, DeviceProfile::H100_SXM)
//!         .build()
//!         .unwrap();
//! let mut fleet =
//!     Fleet::new(topology, Box::new(RoundRobin::new()), FleetConfig::default()).unwrap();
//! let stream = ChatWorkload { n_requests: 4, ..Default::default() }.generate();
//! let report = fleet.run(&stream).unwrap();
//! assert_eq!(report.finished.len(), 4);
//! ```
//!
//! Python never runs at request time: `make artifacts` lowers the model
//! and kernels once, and everything here is self-contained after that.
//!
//! ## Prefix sharing
//!
//! The KV cache behind admission is a prefix-sharing paged block manager
//! ([`coordinator::BlockManager`]): full prompt blocks are keyed by a
//! rolling hash chain and shared across requests by refcount, partial
//! tails fork copy-on-write at the first generated token, and freed
//! prefixes stay matchable on an LRU evictable list until recycled.
//! Shared system prompts therefore cost one physical prefix per fleet of
//! chats — admission charges only the private remainder, prefill skips
//! the cached tokens (TTFT), and decode seeds at the full shared `L_K`,
//! exactly the long-context low-head-count regime the sequence-aware
//! split policy targets. See `docs/` for the full reader-facing tour and
//! DESIGN.md §Prefix sharing for the invariants.
//!
//! ## Continuous batching
//!
//! Step composition lives in [`schedule`]: a [`schedule::StepComposer`]
//! decides each step which rows run and how much prompt each may ingest.
//! The default [`schedule::ChunkPolicy::Monolithic`] reproduces the
//! legacy prefill-first schedule byte-for-byte; bounding it
//! ([`schedule::ChunkPolicy::Bounded`], the CLI's `--chunk-tokens`)
//! splits long prompts into chunks that share *mixed* steps with decode
//! rows under a per-step [`schedule::TokenBudget`] (`--max-batch-tokens`)
//! — Sarathi-style chunked prefill, which keeps TTFT and TPOT bounded
//! under open-loop load and puts `q_len > 1` rows in the same wave as
//! decode for the first time (the split heuristic's mixed-wave regime).
//! See DESIGN.md §Continuous batching.
//!
//! ## Observability
//!
//! [`obs`] is the cross-cutting tracing/metrics layer: a zero-allocation
//! [`obs::FlightRecorder`] (fixed-capacity ring of `Copy` events on the
//! engine's virtual clock), per-request span timelines reconstructed
//! from the ring, a Chrome trace-event exporter
//! (`chrome://tracing`/Perfetto; `--trace-out` on `serve`/`cluster`),
//! and a histogram-capable [`obs::MetricsRegistry`] with Prometheus text
//! exposition (`--metrics-out`). `EngineMetrics` records occupancy and
//! latency distributions through the registry. See docs/observability.md.
//!
//! ## Static analysis
//!
//! The invariants above are machine-checked by [`analysis`] (pallas-lint,
//! run as `fa3-split lint`): a self-hosted source linter (layering DAG,
//! planner-façade exclusivity, `no_alloc` hot regions, struct-ripple,
//! bench-manifest wiring) plus a plan-space model checker that
//! exhaustively enumerates the bucketed decode-shape domain and proves,
//! among other invariants, that sequence-aware occupancy never regresses
//! below standard for `H_KV <= 4`. See docs/analysis.md.

// The docs ARE a deliverable of this crate (the reproduction is read as
// much as it is run): surface any public item that loses its docs.
#![warn(missing_docs)]

pub mod analysis;
pub mod backend;
pub mod bench_harness;
pub mod cluster;
pub mod coordinator;
pub mod evolve;
pub mod heuristics;
pub mod obs;
pub mod planner;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod util;
pub mod workload;
