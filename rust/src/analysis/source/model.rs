//! The module model: everything the source passes need, extracted from
//! one lexed file.
//!
//! A [`FileModel`] records, per file: the top-level module it belongs to,
//! the non-test `crate::<module>` use edges (for layering), struct/enum
//! definitions with their field lists and struct-literal/pattern sites
//! (for struct-ripple and the `SchedulerMetadata` exclusivity rule),
//! function body spans (so `no_alloc` markers attach to the right
//! region), and the raw `pallas-lint` directives.
//!
//! Heuristics, and why they are sound for this tree (each was debugged
//! against all of `rust/src/**` — see `tests/static_analysis.rs` for the
//! self-clean gate that keeps them honest):
//!
//! * **Test regions**: an `#[cfg(test)]` or `#[test]` attribute marks the
//!   next item's brace-delimited body as test-only; layering ignores use
//!   edges inside them (tests may reach across layers), while
//!   struct-ripple still checks their literal sites.
//! * **Struct-literal sites**: a type-like path followed by `{` is a
//!   site, unless the token *before the whole path* (after absorbing
//!   `&`/`mut` and lowercase path segments) is one of
//!   `impl for dyn mod struct enum union trait -> where as use fn`, which
//!   are type positions (`-> &crate::x::Foo {` is a return type, not a
//!   construction). `where`-clauses suppress detection until their `{`.
//!   Unknown type names are skipped by struct-ripple, so consts and
//!   foreign types cannot false-positive.
//! * **Field extraction**: at nesting depth 0 inside the braces, an
//!   identifier followed by `:`, `,` or `}` is a field (shorthand
//!   included); `..` marks the site non-exhaustive (membership check
//!   only). This covers literals *and* patterns — both must name real
//!   fields.

use std::collections::BTreeMap;

use super::lexer::{lex, Directive, Tok};

/// One `crate::<target>` dependency edge out of a file.
#[derive(Debug, Clone, PartialEq)]
pub struct UseEdge {
    /// Top-level module the path enters (`planner` in `crate::planner::X`).
    pub target: String,
    /// 1-based line of the edge.
    pub line: usize,
    /// Whether the edge sits inside a `#[cfg(test)]` / `#[test]` region.
    pub in_test: bool,
}

/// A struct definition (or enum struct-variant) with named fields.
#[derive(Debug, Clone, PartialEq)]
pub struct StructDef {
    /// `Name` for structs, `Enum::Variant` for enum struct-variants.
    pub name: String,
    /// Declared field names.
    pub fields: Vec<String>,
    /// 1-based line of the definition.
    pub line: usize,
}

/// A function body span in the token stream.
#[derive(Debug, Clone, PartialEq)]
pub struct FnSpan {
    /// Function name.
    pub name: String,
    /// 1-based line of the `fn` keyword.
    pub line: usize,
    /// Token index of the body's opening `{`.
    pub body_start: usize,
    /// Token index of the matching `}`.
    pub body_end: usize,
}

/// A struct-literal or struct-pattern site.
#[derive(Debug, Clone, PartialEq)]
pub struct LiteralSite {
    /// The path as written, segments joined with `::`.
    pub path: String,
    /// 1-based line of the opening `{`.
    pub line: usize,
    /// Field names used at the site.
    pub fields: Vec<String>,
    /// Whether a `..` rest/base was present (non-exhaustive site).
    pub has_rest: bool,
    /// Whether the site sits inside a test region.
    pub in_test: bool,
}

/// Everything the passes need to know about one source file.
#[derive(Debug, Clone, Default)]
pub struct FileModel {
    /// Path relative to the source root, e.g. `planner/cursor.rs`.
    pub path: String,
    /// Top-level module: first path component, or the file stem for files
    /// directly in the root (`lib.rs` → `lib`, `main.rs` → `main`).
    pub module: String,
    /// Dependency edges (`use crate::…` declarations and inline paths).
    pub uses: Vec<UseEdge>,
    /// Struct definitions and enum struct-variants.
    pub struct_defs: Vec<StructDef>,
    /// Function body spans, in source order.
    pub fn_spans: Vec<FnSpan>,
    /// Struct-literal/pattern sites.
    pub literal_sites: Vec<LiteralSite>,
    /// Raw `pallas-lint` directives.
    pub directives: Vec<Directive>,
    /// The stripped token stream (the `no_alloc` pass re-scans fn bodies).
    pub toks: Vec<Tok>,
}

/// The top-level module a source-root-relative path belongs to.
pub fn module_of(path: &str) -> String {
    match path.split_once('/') {
        Some((first, _)) => first.to_string(),
        None => path.strip_suffix(".rs").unwrap_or(path).to_string(),
    }
}

/// Index of the `}` matching the `{` at `open` (or `]`/`)` via the
/// open/close pair). Returns the last token index if unbalanced.
pub fn find_matching(toks: &[Tok], open: usize, open_ch: &str, close_ch: &str) -> usize {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is(open_ch) {
            depth += 1;
        } else if t.is(close_ch) {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Build the model for one file.
pub fn build_model(path: &str, src: &str) -> FileModel {
    let lexed = lex(src);
    let toks = lexed.toks;
    let n = toks.len();
    let mut fm = FileModel {
        path: path.to_string(),
        module: module_of(path),
        directives: lexed.directives,
        ..FileModel::default()
    };

    let test_spans = collect_test_spans(&toks);
    let in_test = |idx: usize| test_spans.iter().any(|&(a, b)| a <= idx && idx <= b);

    collect_uses(&toks, &in_test, &mut fm);
    collect_defs(&toks, &mut fm);
    collect_fn_spans(&toks, &mut fm);
    collect_literal_sites(&toks, &in_test, &mut fm);

    fm.toks = toks;
    fm
}

/// Token spans of items annotated `#[cfg(test)]` or `#[test]`.
fn collect_test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let n = toks.len();
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < n {
        if toks[i].is("#") && i + 1 < n && toks[i + 1].is("[") {
            let close = find_matching(toks, i + 1, "[", "]");
            let attr: Vec<&str> = toks[i + 2..close].iter().map(|t| t.text.as_str()).collect();
            let is_test_attr = attr.first() == Some(&"test")
                || (attr.first() == Some(&"cfg") && attr.contains(&"test"));
            if is_test_attr {
                // Skip any further attributes, then span the item body.
                let mut j = close + 1;
                while j + 1 < n && toks[j].is("#") && toks[j + 1].is("[") {
                    j = find_matching(toks, j + 1, "[", "]") + 1;
                }
                let mut k = j;
                while k < n && !toks[k].is("{") && !toks[k].is(";") {
                    k += 1;
                }
                if k < n && toks[k].is("{") {
                    spans.push((i, find_matching(toks, k, "{", "}")));
                }
            }
            i = close + 1;
        } else {
            i += 1;
        }
    }
    spans
}

fn collect_uses(toks: &[Tok], in_test: &dyn Fn(usize) -> bool, fm: &mut FileModel) {
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if toks[i].is_ident() && toks[i].is("use") {
            // Walk the whole decl (handles `use crate::{a::x, b::y};`),
            // collecting every `crate :: <ident>` top segment within it.
            let start = i;
            let mut depth = 0i64;
            let mut j = i + 1;
            while j < n && !(toks[j].is(";") && depth == 0) {
                if toks[j].is("{") {
                    depth += 1;
                }
                if toks[j].is("}") {
                    depth -= 1;
                }
                if toks[j].is("crate") && j + 2 < n && toks[j + 1].is("::") {
                    if toks[j + 2].is_ident() {
                        fm.uses.push(UseEdge {
                            target: toks[j + 2].text.clone(),
                            line: toks[j].line,
                            in_test: in_test(start),
                        });
                    } else if toks[j + 2].is("{") {
                        // `use crate::{a::X, b::Y}`: one edge per group
                        // item's first segment.
                        let gend = find_matching(toks, j + 2, "{", "}");
                        let mut gdepth = 0i64;
                        let mut head = true;
                        for g in j + 2..gend {
                            if toks[g].is("{") {
                                gdepth += 1;
                            } else if toks[g].is("}") {
                                gdepth -= 1;
                            } else if toks[g].is(",") && gdepth == 1 {
                                head = true;
                            } else if head && gdepth == 1 && toks[g].is_ident() {
                                fm.uses.push(UseEdge {
                                    target: toks[g].text.clone(),
                                    line: toks[g].line,
                                    in_test: in_test(start),
                                });
                                head = false;
                            }
                        }
                    }
                }
                j += 1;
            }
            i = j + 1;
            continue;
        }
        // Inline `crate::x` path outside a use decl (`pub(crate)` has `(`
        // before the keyword and no `::` after, so it never matches).
        if toks[i].is("crate") && i + 2 < n && toks[i + 1].is("::") && toks[i + 2].is_ident() {
            fm.uses.push(UseEdge {
                target: toks[i + 2].text.clone(),
                line: toks[i].line,
                in_test: in_test(i),
            });
        }
        i += 1;
    }
}

fn collect_defs(toks: &[Tok], fm: &mut FileModel) {
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        let kw_struct = toks[i].is_ident() && toks[i].is("struct");
        let kw_enum = toks[i].is_ident() && toks[i].is("enum");
        if (kw_struct || kw_enum) && i + 1 < n && toks[i + 1].is_ident() {
            let name = toks[i + 1].text.clone();
            let line = toks[i].line;
            let mut j = skip_generics(toks, i + 2);
            while j < n && !toks[j].is("{") && !toks[j].is("(") && !toks[j].is(";") {
                j += 1;
            }
            if j < n && toks[j].is("{") {
                let end = find_matching(toks, j, "{", "}");
                if kw_struct {
                    fm.struct_defs.push(StructDef {
                        name,
                        fields: parse_def_fields(toks, j, end),
                        line,
                    });
                } else {
                    collect_enum_variants(toks, j, end, &name, fm);
                }
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
}

/// Skip a `<…>` generic parameter list starting at `j`, if present.
fn skip_generics(toks: &[Tok], j: usize) -> usize {
    if j >= toks.len() || !toks[j].is("<") {
        return j;
    }
    let mut depth = 0i64;
    let mut k = j;
    while k < toks.len() {
        if toks[k].is("<") {
            depth += 1;
        }
        if toks[k].is(">") {
            depth -= 1;
        }
        k += 1;
        if depth == 0 {
            break;
        }
    }
    k
}

fn collect_enum_variants(toks: &[Tok], open: usize, end: usize, ename: &str, fm: &mut FileModel) {
    let mut k = open + 1;
    while k < end {
        if toks[k].is("#") && k + 1 < end && toks[k + 1].is("[") {
            k = find_matching(toks, k + 1, "[", "]") + 1;
            continue;
        }
        if toks[k].is_type_like() {
            let vname = toks[k].text.clone();
            let vline = toks[k].line;
            if k + 1 < end && toks[k + 1].is("{") {
                let vend = find_matching(toks, k + 1, "{", "}");
                fm.struct_defs.push(StructDef {
                    name: format!("{ename}::{vname}"),
                    fields: parse_def_fields(toks, k + 1, vend),
                    line: vline,
                });
                k = vend + 1;
                continue;
            }
            if k + 1 < end && toks[k + 1].is("(") {
                k = find_matching(toks, k + 1, "(", ")") + 1;
                continue;
            }
        }
        k += 1;
    }
}

/// Field names of a struct-def body (`{` at `open`, `}` at `end`).
fn parse_def_fields(toks: &[Tok], open: usize, end: usize) -> Vec<String> {
    let mut fields = Vec::new();
    let mut k = open + 1;
    while k < end {
        if toks[k].is("#") && k + 1 < end && toks[k + 1].is("[") {
            k = find_matching(toks, k + 1, "[", "]") + 1;
            continue;
        }
        if toks[k].is("pub") {
            k += 1;
            if k < end && toks[k].is("(") {
                k = find_matching(toks, k, "(", ")") + 1;
            }
            continue;
        }
        if toks[k].is_ident() && k + 1 < end && toks[k + 1].is(":") {
            fields.push(toks[k].text.clone());
            // Skip the type until a top-level `,`.
            k += 2;
            let mut depth = 0i64;
            while k < end {
                let t = &toks[k];
                if t.is("(") || t.is("[") || t.is("{") || t.is("<") {
                    depth += 1;
                } else if t.is(")") || t.is("]") || t.is("}") || t.is(">") {
                    depth -= 1;
                } else if t.is(",") && depth <= 0 {
                    k += 1;
                    break;
                }
                k += 1;
            }
            continue;
        }
        k += 1;
    }
    fields
}

fn collect_fn_spans(toks: &[Tok], fm: &mut FileModel) {
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if toks[i].is_ident() && toks[i].is("fn") && i + 1 < n && toks[i + 1].is_ident() {
            let name = toks[i + 1].text.clone();
            let line = toks[i].line;
            // Find the body `{`: first one at paren depth 0 after the
            // signature; a `;` first means a bodiless trait method.
            let mut j = i + 2;
            let mut pdepth = 0i64;
            let mut body = None;
            while j < n {
                let t = &toks[j];
                if t.is("(") {
                    pdepth += 1;
                } else if t.is(")") {
                    pdepth -= 1;
                } else if t.is(";") && pdepth == 0 {
                    break;
                } else if t.is("{") && pdepth == 0 {
                    body = Some(j);
                    break;
                }
                j += 1;
            }
            if let Some(open) = body {
                fm.fn_spans.push(FnSpan {
                    name,
                    line,
                    body_start: open,
                    body_end: find_matching(toks, open, "{", "}"),
                });
            }
        }
        i += 1;
    }
}

/// Tokens that, found immediately before a type-like path + `{`, mark a
/// type position rather than a construction/pattern site.
const SITE_EXCLUDE_PREV: &[&str] = &[
    "impl", "for", "dyn", "mod", "struct", "enum", "union", "trait", "->", "where", "as", "use",
    "fn",
];

fn collect_literal_sites(toks: &[Tok], in_test: &dyn Fn(usize) -> bool, fm: &mut FileModel) {
    let n = toks.len();
    let mut i = 0usize;
    let mut where_active = false;
    while i < n {
        let t = &toks[i];
        if t.is_ident() && t.is("where") {
            where_active = true;
        } else if where_active && (t.is("{") || t.is(";")) {
            where_active = false;
        }
        if where_active {
            i += 1;
            continue;
        }
        if t.is_type_like() && !t.is("Self") {
            // Extend the path forward over `:: TypeLike` segments.
            let mut j = i;
            let mut path = toks[j].text.clone();
            while j + 2 < n && toks[j + 1].is("::") && toks[j + 2].is_type_like() {
                j += 2;
                path.push_str("::");
                path.push_str(&toks[j].text);
            }
            // Optional turbofish.
            let mut k = j + 1;
            if k + 1 < n && toks[k].is("::") && toks[k + 1].is("<") {
                k = skip_generics(toks, k + 1);
            }
            if k < n && toks[k].is("{") && !is_type_position(toks, i) {
                let end = find_matching(toks, k, "{", "}");
                let (fields, has_rest) = parse_literal_fields(toks, k, end);
                fm.literal_sites.push(LiteralSite {
                    path,
                    line: toks[k].line,
                    fields,
                    has_rest,
                    in_test: in_test(i),
                });
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
}

/// Whether the path whose first segment starts at token `i` sits in a
/// type position: walk backward over the full path prefix (lowercase
/// segments, `crate`/`super`/`self`) and any `&`/`mut`, then test the
/// preceding token against [`SITE_EXCLUDE_PREV`].
fn is_type_position(toks: &[Tok], i: usize) -> bool {
    let mut p = i;
    while p >= 2 && toks[p - 1].is("::") && toks[p - 2].is_ident() {
        p -= 2;
    }
    while p >= 1 && (toks[p - 1].is("&") || toks[p - 1].is("mut")) {
        p -= 1;
    }
    p >= 1 && SITE_EXCLUDE_PREV.contains(&toks[p - 1].text.as_str())
}

/// Field names used at a literal/pattern site (`{` at `open`).
fn parse_literal_fields(toks: &[Tok], open: usize, end: usize) -> (Vec<String>, bool) {
    let mut fields = Vec::new();
    let mut has_rest = false;
    let mut depth = 0i64;
    let mut expect_field = true;
    let mut k = open + 1;
    while k < end {
        let t = &toks[k];
        if t.is("(") || t.is("[") || t.is("{") {
            depth += 1;
        } else if t.is(")") || t.is("]") || t.is("}") {
            depth -= 1;
        } else if depth == 0 {
            if t.is("..") || t.is("..=") {
                has_rest = true;
                expect_field = false;
            } else if t.is(",") {
                expect_field = true;
            } else if expect_field && t.is_ident() {
                if t.is("ref") || t.is("mut") || t.is("box") {
                    k += 1;
                    continue;
                }
                let next = toks.get(k + 1);
                let terminator = match next {
                    Some(nt) => nt.is(":") || nt.is(",") || nt.is("}"),
                    None => true,
                };
                if terminator {
                    fields.push(t.text.clone());
                }
                expect_field = false;
            }
        }
        k += 1;
    }
    (fields, has_rest)
}

/// A set of analyzed files plus the global definition index.
#[derive(Debug, Default)]
pub struct SourceSet {
    /// Per-file models, in load order.
    pub files: Vec<FileModel>,
}

impl SourceSet {
    /// Build from in-memory `(path, contents)` pairs (fixtures, tests).
    pub fn from_files(files: &[(&str, &str)]) -> SourceSet {
        SourceSet { files: files.iter().map(|(p, s)| build_model(p, s)).collect() }
    }

    /// Walk `src_root` for `.rs` files (sorted, recursive) and build the
    /// model for each, keyed by root-relative path.
    pub fn load_dir(src_root: &std::path::Path) -> std::io::Result<SourceSet> {
        let mut paths = Vec::new();
        walk(src_root, src_root, &mut paths)?;
        paths.sort();
        let mut set = SourceSet::default();
        for rel in paths {
            let src = std::fs::read_to_string(src_root.join(&rel))?;
            set.files.push(build_model(&rel.replace('\\', "/"), &src));
        }
        Ok(set)
    }

    /// Global definition index: struct name (or `Enum::Variant`) → field
    /// lists of every definition carrying that name.
    pub fn def_index(&self) -> BTreeMap<&str, Vec<&StructDef>> {
        let mut idx: BTreeMap<&str, Vec<&StructDef>> = BTreeMap::new();
        for fm in &self.files {
            for d in &fm.struct_defs {
                idx.entry(d.name.as_str()).or_default().push(d);
            }
        }
        idx
    }
}

fn walk(
    root: &std::path::Path,
    dir: &std::path::Path,
    out: &mut Vec<String>,
) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            if let Ok(rel) = path.strip_prefix(root) {
                out.push(rel.to_string_lossy().to_string());
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
use crate::heuristics::tiles::DecodeShape;
use crate::{planner::Planner, util::json::Json};

pub struct Thing {
    pub a: usize,
    b: Vec<(usize, usize)>,
}

pub enum Kind {
    Unit,
    Tuple(usize),
    Fields { x: usize, y: usize },
}

fn build(t: &Thing) -> Thing {
    let k = Kind::Fields { x: 1, y: 2 };
    let _ = k;
    Thing { a: 1, ..*t }
}

#[cfg(test)]
mod tests {
    use crate::coordinator::Engine;
    #[test]
    fn t() {
        let Thing { a, .. } = make();
    }
}
"#;

    #[test]
    fn module_naming() {
        assert_eq!(module_of("planner/cursor.rs"), "planner");
        assert_eq!(module_of("lib.rs"), "lib");
        assert_eq!(module_of("main.rs"), "main");
    }

    #[test]
    fn extracts_use_edges_with_testness() {
        let fm = build_model("planner/x.rs", SAMPLE);
        let non_test: Vec<&str> =
            fm.uses.iter().filter(|u| !u.in_test).map(|u| u.target.as_str()).collect();
        assert_eq!(non_test, vec!["heuristics", "planner", "util"]);
        let test: Vec<&str> =
            fm.uses.iter().filter(|u| u.in_test).map(|u| u.target.as_str()).collect();
        assert_eq!(test, vec!["coordinator"]);
    }

    #[test]
    fn extracts_defs_including_enum_variants() {
        let fm = build_model("planner/x.rs", SAMPLE);
        let names: Vec<&str> = fm.struct_defs.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(names, vec!["Thing", "Kind::Fields"]);
        assert_eq!(fm.struct_defs[0].fields, vec!["a", "b"]);
        assert_eq!(fm.struct_defs[1].fields, vec!["x", "y"]);
    }

    #[test]
    fn extracts_literal_sites_and_patterns() {
        let fm = build_model("planner/x.rs", SAMPLE);
        let paths: Vec<(&str, bool)> =
            fm.literal_sites.iter().map(|s| (s.path.as_str(), s.has_rest)).collect();
        assert_eq!(
            paths,
            vec![("Kind::Fields", false), ("Thing", true), ("Thing", true)]
        );
        // The test-module pattern site is marked as test code.
        assert!(fm.literal_sites[2].in_test);
    }

    #[test]
    fn return_types_are_not_literal_sites() {
        let fm = build_model("a/x.rs", "fn f() -> Foo { g() }\nfn g() -> &'static Bar { h() }");
        assert!(fm.literal_sites.is_empty(), "{:?}", fm.literal_sites);
    }

    #[test]
    fn fn_spans_cover_bodies() {
        let fm = build_model("a/x.rs", "fn one() { inner(); }\nfn two(a: usize) -> usize { a }");
        assert_eq!(fm.fn_spans.len(), 2);
        assert_eq!(fm.fn_spans[0].name, "one");
        assert_eq!(fm.fn_spans[1].line, 2);
    }
}
