//! No-alloc pass: deny allocating idioms inside `// pallas-lint:
//! no_alloc` regions.
//!
//! A `no_alloc` marker attaches to the next `fn` item at or below it; the
//! region is that function's lexical body. The pass is the static
//! counterpart of the runtime `tests/alloc_guard.rs` counter: the guard
//! proves the steady-state decode loop performs zero heap allocations at
//! run time, this pass points at the exact line that would break it at
//! review time. Both cover the same hot path (cursor plan-hit, engine
//! step loop, decode scheduler, sim backend execute).
//!
//! The deny list targets idioms that *construct or copy heap state*:
//! fresh containers (`Vec::new`, `vec![…]`, `Box::new`, `String::new` /
//! `from` / `with_capacity`), clones (`.clone()` / `.cloned()` /
//! `.to_vec()` / `.to_owned()` / `.to_string()`), iterator
//! materialization (`.collect()`), and formatting (`format!`). Amortized
//! growth of *caller-owned reused* buffers (`push` / `extend` /
//! `reserve` into scratch) is deliberately not denied — that is exactly
//! the pattern the scratch discipline prescribes, and the runtime guard
//! proves it settles to zero.
//!
//! Suppression: `// pallas-lint: allow(no_alloc): <justification>` on the
//! offending line or the line above. An empty justification is itself a
//! finding — the point is a reviewed, documented exception (the one in
//! the tree today: a capacity-0 `Vec::new` placeholder field, which never
//! touches the heap).

use std::collections::BTreeSet;

use crate::analysis::report::Finding;

use super::model::{FileModel, SourceSet};

/// Pass name in findings.
pub const PASS: &str = "no_alloc";

/// `Path::segment` pairs that always allocate (or signal a fresh
/// container entering the hot path).
const DENY_PATHS: &[(&str, &str)] = &[
    ("Vec", "new"),
    ("Vec", "with_capacity"),
    ("Box", "new"),
    ("String", "new"),
    ("String", "from"),
    ("String", "with_capacity"),
];

/// Macros that allocate.
const DENY_MACROS: &[&str] = &["vec", "format"];

/// Methods that allocate or clone heap state.
const DENY_METHODS: &[&str] =
    &["collect", "clone", "cloned", "to_string", "to_vec", "to_owned"];

/// Outcome counters for the pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoAllocStats {
    /// Marked regions checked.
    pub regions: usize,
    /// Findings silenced by justified suppressions.
    pub suppressed: usize,
}

/// Run the pass over every file.
pub fn check(set: &SourceSet, findings: &mut Vec<Finding>) -> NoAllocStats {
    let mut stats = NoAllocStats::default();
    for fm in &set.files {
        check_file(fm, findings, &mut stats);
    }
    stats
}

fn check_file(fm: &FileModel, findings: &mut Vec<Finding>, stats: &mut NoAllocStats) {
    // Suppressions: allow(no_alloc) with a justification covers its own
    // line and the next one.
    let mut suppressed_lines: BTreeSet<usize> = BTreeSet::new();
    for d in &fm.directives {
        if let Some(rest) = d.text.strip_prefix("allow(") {
            let Some((pass, tail)) = rest.split_once(')') else {
                findings.push(Finding::error(
                    PASS,
                    fm.path.as_str(),
                    d.line,
                    format!("malformed suppression directive: `{}`", d.text),
                ));
                continue;
            };
            if pass != PASS {
                continue; // another pass's suppression
            }
            let justification = tail.trim_start_matches(':').trim();
            if justification.is_empty() {
                findings.push(Finding::error(
                    PASS,
                    fm.path.as_str(),
                    d.line,
                    "allow(no_alloc) without a justification (write \
                     `allow(no_alloc): <reason>`)",
                ));
                continue;
            }
            suppressed_lines.insert(d.line);
            suppressed_lines.insert(d.line + 1);
        } else if d.text != "no_alloc" {
            findings.push(Finding::error(
                "directive",
                fm.path.as_str(),
                d.line,
                format!("unknown pallas-lint directive: `{}`", d.text),
            ));
        }
    }

    for d in &fm.directives {
        if d.text != "no_alloc" {
            continue;
        }
        // Attach to the first fn whose `fn` keyword is at/after the marker.
        let Some(span) = fm.fn_spans.iter().filter(|f| f.line >= d.line).min_by_key(|f| f.line)
        else {
            findings.push(Finding::error(
                PASS,
                fm.path.as_str(),
                d.line,
                "no_alloc marker with no following fn item",
            ));
            continue;
        };
        stats.regions += 1;
        scan_region(fm, span.body_start, span.body_end, &span.name, &suppressed_lines, findings, stats);
    }
}

fn scan_region(
    fm: &FileModel,
    start: usize,
    end: usize,
    fn_name: &str,
    suppressed: &BTreeSet<usize>,
    findings: &mut Vec<Finding>,
    stats: &mut NoAllocStats,
) {
    let toks = &fm.toks;
    for k in start..=end.min(toks.len().saturating_sub(1)) {
        let t = &toks[k];
        let mut hit: Option<String> = None;
        if t.is_ident() && k + 2 <= end && toks[k + 1].is("::") && toks[k + 2].is_ident() {
            let pair = (t.text.as_str(), toks[k + 2].text.as_str());
            if DENY_PATHS.contains(&pair) {
                hit = Some(format!("{}::{}", pair.0, pair.1));
            }
        }
        if hit.is_none()
            && t.is_ident()
            && DENY_MACROS.contains(&t.text.as_str())
            && k + 1 <= end
            && toks[k + 1].is("!")
        {
            hit = Some(format!("{}!", t.text));
        }
        if hit.is_none()
            && t.is(".")
            && k + 1 <= end
            && toks[k + 1].is_ident()
            && DENY_METHODS.contains(&toks[k + 1].text.as_str())
        {
            hit = Some(format!(".{}()", toks[k + 1].text));
        }
        if let Some(idiom) = hit {
            if suppressed.contains(&t.line) {
                stats.suppressed += 1;
            } else {
                findings.push(Finding::error(
                    PASS,
                    fm.path.as_str(),
                    t.line,
                    format!("allocating idiom `{idiom}` inside no_alloc region `fn {fn_name}`"),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str) -> (Vec<Finding>, NoAllocStats) {
        let set = SourceSet::from_files(&[("backend/hot.rs", src)]);
        let mut findings = Vec::new();
        let stats = check(&set, &mut findings);
        (findings, stats)
    }

    #[test]
    fn denied_idioms_fire_only_inside_marked_regions() {
        let src = "\
// pallas-lint: no_alloc
fn hot(xs: &[usize]) {
    let v: Vec<usize> = xs.iter().cloned().collect();
    let s = format!(\"x\");
}
fn cold() { let q = vec![1]; let b = Box::new(2); }
";
        let (findings, stats) = run(src);
        assert_eq!(stats.regions, 1);
        let idioms: Vec<&str> = findings.iter().map(|f| f.message.as_str()).collect();
        assert_eq!(findings.len(), 3, "{idioms:?}");
        assert!(idioms.iter().any(|m| m.contains(".cloned()")));
        assert!(idioms.iter().any(|m| m.contains(".collect()")));
        assert!(idioms.iter().any(|m| m.contains("format!")));
    }

    #[test]
    fn justified_suppression_silences_and_counts() {
        let src = "\
// pallas-lint: no_alloc
fn hot() {
    // pallas-lint: allow(no_alloc): capacity-0 placeholder, never allocates
    let v: Vec<usize> = Vec::new();
}
";
        let (findings, stats) = run(src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(stats.suppressed, 1);
    }

    #[test]
    fn unjustified_suppression_is_a_finding() {
        let src = "\
// pallas-lint: no_alloc
fn hot() {
    // pallas-lint: allow(no_alloc):
    let v: Vec<usize> = Vec::new();
}
";
        let (findings, _) = run(src);
        // The bare allow is one finding; the Vec::new it failed to cover
        // is another.
        assert_eq!(findings.len(), 2, "{findings:?}");
        assert!(findings[0].message.contains("without a justification"));
    }

    #[test]
    fn dangling_marker_and_unknown_directive_fire() {
        let (findings, _) = run("// pallas-lint: no_alloc\nconst X: usize = 1;\n");
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("no following fn"));

        let (findings, _) = run("// pallas-lint: no_allocc\nfn f() {}\n");
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("unknown pallas-lint directive"));
    }
}
