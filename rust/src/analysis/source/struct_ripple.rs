//! Struct-ripple pass: every struct-literal (and struct-pattern) site is
//! checked against the definition's field list.
//!
//! This automates the manual "ripple scan" from earlier PRs: when a
//! struct gains or loses a field, every construction site must be
//! revisited. rustc does this too, of course — but only when the
//! toolchain runs; in the offline container this pass is the first line
//! of defense, and it additionally covers *patterns* uniformly.
//!
//! Semantics, per site:
//! * With a `..` rest/base: every named field must exist (membership
//!   check).
//! * Without `..`: the named fields must cover the definition exactly —
//!   valid for literals (rustc requires exhaustive construction) and for
//!   patterns (rustc requires `..` on non-exhaustive matches).
//! * Unknown type names are skipped — foreign and std types are not in
//!   the model, and skipping kills false positives (a const followed by a
//!   block would otherwise look like a site).
//! * If several definitions share a name, matching *any* of them passes
//!   (module resolution is out of scope for a lexer-level model).

use crate::analysis::report::Finding;

use super::model::SourceSet;

/// Pass name in findings.
pub const PASS: &str = "struct_ripple";

/// Run the pass. Returns the number of sites actually checked against a
/// known definition.
pub fn check(set: &SourceSet, findings: &mut Vec<Finding>) -> usize {
    let defs = set.def_index();
    let mut checked = 0usize;
    for fm in &set.files {
        for site in &fm.literal_sites {
            let segs: Vec<&str> = site.path.split("::").collect();
            let last = segs[segs.len() - 1];
            let two = if segs.len() >= 2 {
                Some(format!("{}::{}", segs[segs.len() - 2], last))
            } else {
                None
            };
            let candidates = two
                .as_deref()
                .and_then(|k| defs.get(k))
                .or_else(|| defs.get(last));
            let Some(candidates) = candidates else {
                continue;
            };
            checked += 1;
            let mut first_reason = String::new();
            let ok = candidates.iter().any(|def| {
                let unknown: Vec<&String> =
                    site.fields.iter().filter(|f| !def.fields.contains(f)).collect();
                let missing: Vec<&String> =
                    def.fields.iter().filter(|f| !site.fields.contains(f)).collect();
                let matches = if site.has_rest {
                    unknown.is_empty()
                } else {
                    unknown.is_empty() && missing.is_empty()
                };
                if !matches && first_reason.is_empty() {
                    first_reason = format!(
                        "unknown fields {unknown:?}, missing fields {missing:?} \
                         (vs `{}` defined at line {})",
                        def.name, def.line
                    );
                }
                matches
            });
            if !ok {
                findings.push(Finding::error(
                    PASS,
                    fm.path.as_str(),
                    site.line,
                    format!("site `{} {{ .. }}` does not match its definition: {first_reason}", site.path),
                ));
            }
        }
    }
    checked
}

#[cfg(test)]
mod tests {
    use super::*;

    const DEF: &str = "pub struct Thing { pub a: usize, pub b: usize }\n";

    fn run(site_src: &str) -> Vec<Finding> {
        let set =
            SourceSet::from_files(&[("planner/def.rs", DEF), ("planner/site.rs", site_src)]);
        let mut findings = Vec::new();
        check(&set, &mut findings);
        findings
    }

    #[test]
    fn exact_sites_pass_and_partial_sites_fail() {
        assert!(run("fn f() { let t = Thing { a: 1, b: 2 }; }").is_empty());
        let missing = run("fn f() { let t = Thing { a: 1 }; }");
        assert_eq!(missing.len(), 1);
        assert!(missing[0].message.contains("missing fields"));
    }

    #[test]
    fn unknown_field_fails_even_with_rest() {
        let f = run("fn f(t: Thing) { let u = Thing { c: 3, ..t }; }");
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("unknown fields"));
        assert!(run("fn f(t: Thing) { let u = Thing { a: 3, ..t }; }").is_empty());
    }

    #[test]
    fn patterns_are_checked_too() {
        assert!(run("fn f(t: Thing) { let Thing { a, .. } = t; }").is_empty());
        let bad = run("fn f(t: Thing) { let Thing { z, .. } = t; }");
        assert_eq!(bad.len(), 1);
    }

    #[test]
    fn unknown_types_are_skipped() {
        assert!(run("fn f() { let m = SomeForeignType { whatever: 1 }; }").is_empty());
    }

    #[test]
    fn enum_struct_variants_resolve_by_two_segments() {
        let set = SourceSet::from_files(&[(
            "planner/e.rs",
            "pub enum Kind { Fields { x: usize } }\n\
             fn f() { let k = Kind::Fields { x: 1 }; let b = Kind::Fields { y: 2 }; }",
        )]);
        let mut findings = Vec::new();
        assert_eq!(check(&set, &mut findings), 2);
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("Kind::Fields"));
    }
}
