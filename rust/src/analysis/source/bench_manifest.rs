//! Bench-manifest pass: every checked-in `BENCH_*.json` must map to a
//! bench binary that writes it, a row in `docs/experiments.md`, and a CI
//! job that regenerates it — and vice versa, every manifest a bench
//! emits must be checked in.
//!
//! This closes the loop `docs/experiments.md` documents by hand: a bench
//! renamed without its manifest (or a manifest committed without a CI
//! job) is a silent drift between what the repo *claims* is measured and
//! what CI *actually* regenerates. CI emits `.ci.json` variants next to
//! the committed targets, so the CI check matches on the `BENCH_<stem>`
//! prefix rather than the exact filename.
//!
//! Manifests still carrying `"measured": false` (targets-only, written
//! without a toolchain) are reported as warnings, not errors: the gate
//! must start green in the offline container, but the drift stays
//! visible in every findings report until a real `cargo bench` run
//! replaces them.

use crate::analysis::report::Finding;
use crate::util::json::Json;

/// Pass name in findings.
pub const PASS: &str = "bench_manifest";

/// The pass inputs, decoupled from the filesystem so fixtures can seed
/// violations ([`load`] gathers them from a real repo root).
///
/// [`load`]: BenchManifestInputs::load
#[derive(Debug, Clone, Default)]
pub struct BenchManifestInputs {
    /// Checked-in `(file_name, contents)` of repo-root `BENCH_*.json`.
    pub bench_jsons: Vec<(String, String)>,
    /// `(file_name, contents)` of `rust/benches/*.rs`.
    pub bench_sources: Vec<(String, String)>,
    /// `docs/experiments.md` contents.
    pub experiments_md: String,
    /// `.github/workflows/ci.yml` contents.
    pub ci_yaml: String,
}

impl BenchManifestInputs {
    /// Gather the inputs from a repo root.
    pub fn load(repo_root: &std::path::Path) -> std::io::Result<BenchManifestInputs> {
        let mut inputs = BenchManifestInputs::default();
        for entry in std::fs::read_dir(repo_root)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                inputs.bench_jsons.push((name, std::fs::read_to_string(&path)?));
            }
        }
        let benches = repo_root.join("rust").join("benches");
        if benches.is_dir() {
            for entry in std::fs::read_dir(&benches)? {
                let path = entry?.path();
                if path.extension().is_some_and(|e| e == "rs") {
                    let name =
                        path.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
                    inputs.bench_sources.push((name, std::fs::read_to_string(&path)?));
                }
            }
        }
        inputs.bench_jsons.sort();
        inputs.bench_sources.sort();
        inputs.experiments_md =
            std::fs::read_to_string(repo_root.join("docs").join("experiments.md"))
                .unwrap_or_default();
        inputs.ci_yaml = std::fs::read_to_string(
            repo_root.join(".github").join("workflows").join("ci.yml"),
        )
        .unwrap_or_default();
        Ok(inputs)
    }
}

/// Run the pass. Returns the number of manifests examined.
pub fn check(inputs: &BenchManifestInputs, findings: &mut Vec<Finding>) -> usize {
    // Forward: every checked-in manifest must be written, documented, and
    // regenerated.
    for (name, contents) in &inputs.bench_jsons {
        let stem = name.strip_suffix(".json").unwrap_or(name);
        let writer = inputs.bench_sources.iter().find(|(_, src)| src.contains(name.as_str()));
        if writer.is_none() {
            findings.push(Finding::error(
                PASS,
                name.as_str(),
                0,
                "no bench under rust/benches/ writes this manifest (orphaned target file)",
            ));
        }
        if !inputs.experiments_md.contains(stem) {
            findings.push(Finding::error(
                PASS,
                name.as_str(),
                0,
                "manifest is not documented in docs/experiments.md",
            ));
        }
        if !inputs.ci_yaml.contains(stem) {
            findings.push(Finding::error(
                PASS,
                name.as_str(),
                0,
                "no CI job in .github/workflows/ci.yml regenerates this manifest",
            ));
        }
        match Json::parse(contents) {
            Ok(doc) => {
                if doc.get("measured").as_bool() != Some(true) {
                    findings.push(Finding::warning(
                        PASS,
                        name.as_str(),
                        0,
                        "manifest carries modeled targets (\"measured\" != true): \
                         regenerate on real hardware when a toolchain is available",
                    ));
                }
            }
            Err(e) => findings.push(Finding::error(
                PASS,
                name.as_str(),
                0,
                format!("manifest is not valid JSON: {e}"),
            )),
        }
    }
    // Reverse: every manifest name a bench source mentions must exist.
    for (src_name, src) in &inputs.bench_sources {
        for referenced in extract_manifest_names(src) {
            let exists = inputs.bench_jsons.iter().any(|(n, _)| *n == referenced);
            if !exists {
                findings.push(Finding::error(
                    PASS,
                    src_name.as_str(),
                    0,
                    format!(
                        "bench writes `{referenced}` but no such manifest is checked in \
                         at the repo root"
                    ),
                ));
            }
        }
    }
    inputs.bench_jsons.len()
}

/// All `BENCH_<stem>.json` literals in a bench source (CI `.ci.json`
/// variants excluded — those are derived artifacts, not targets).
fn extract_manifest_names(src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = src.as_bytes();
    let mut i = 0usize;
    while let Some(pos) = src[i..].find("BENCH_") {
        let start = i + pos;
        let mut end = start;
        while end < bytes.len()
            && (bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_' || bytes[end] == b'.')
        {
            end += 1;
        }
        let cand = &src[start..end];
        if cand.ends_with(".json") && !cand.ends_with(".ci.json") && !out.contains(&cand.to_string())
        {
            out.push(cand.to_string());
        }
        i = end.max(start + 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs() -> BenchManifestInputs {
        BenchManifestInputs {
            bench_jsons: vec![(
                "BENCH_ok.json".to_string(),
                "{\"measured\": true, \"x\": 1}".to_string(),
            )],
            bench_sources: vec![(
                "ok.rs".to_string(),
                "const OUT: &str = \"BENCH_ok.json\";".to_string(),
            )],
            experiments_md: "| BENCH_ok | cargo bench ok |".to_string(),
            ci_yaml: "run: cargo bench ok # BENCH_ok.ci.json".to_string(),
        }
    }

    #[test]
    fn fully_wired_manifest_is_clean() {
        let mut findings = Vec::new();
        assert_eq!(check(&inputs(), &mut findings), 1);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn orphaned_manifest_fires_three_ways() {
        let mut inp = inputs();
        inp.bench_jsons.push(("BENCH_orphan.json".to_string(), "{}".to_string()));
        let mut findings = Vec::new();
        check(&inp, &mut findings);
        let about_orphan: Vec<_> =
            findings.iter().filter(|f| f.file == "BENCH_orphan.json").collect();
        // no writer, undocumented, no CI job, plus the measured warning.
        assert_eq!(about_orphan.len(), 4, "{about_orphan:?}");
    }

    #[test]
    fn bench_writing_a_missing_manifest_fires() {
        let mut inp = inputs();
        inp.bench_sources
            .push(("stray.rs".to_string(), "let p = \"BENCH_missing.json\";".to_string()));
        let mut findings = Vec::new();
        check(&inp, &mut findings);
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("BENCH_missing.json"));
    }

    #[test]
    fn modeled_targets_warn_but_do_not_gate() {
        let mut inp = inputs();
        inp.bench_jsons[0].1 = "{\"measured\": false}".to_string();
        let mut findings = Vec::new();
        check(&inp, &mut findings);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].severity, crate::analysis::report::Severity::Warning);
    }

    #[test]
    fn ci_json_variants_are_not_targets() {
        assert_eq!(
            extract_manifest_names("\"BENCH_a.json\" \"BENCH_b.ci.json\" BENCH_a.json"),
            vec!["BENCH_a.json".to_string()]
        );
    }
}
