//! Source half of pallas-lint: lexer → module model → four passes.
//!
//! * [`lexer`] — hand-rolled token stream with comments/strings stripped
//!   and `pallas-lint` directives harvested.
//! * [`model`] — per-file [`model::FileModel`]: use-graph edges, struct
//!   definitions + fields, function spans, literal sites, directives.
//! * [`layering`] — allowed inter-module dependency DAG + the
//!   `SchedulerMetadata` façade-exclusivity rule.
//! * [`no_alloc`] — allocating idioms denied inside marked hot regions.
//! * [`struct_ripple`] — literal/pattern sites vs definition field lists.
//! * [`bench_manifest`] — `BENCH_*.json` ↔ bench binary ↔ docs ↔ CI
//!   wiring.
//!
//! Everything here is plain `std`: no proc macros, no syn, no external
//! crates — the tool must run in the same offline container as the rest
//! of the repo.

pub mod bench_manifest;
pub mod layering;
pub mod lexer;
pub mod model;
pub mod no_alloc;
pub mod struct_ripple;

use crate::analysis::report::{Finding, SourceStats};

pub use model::SourceSet;

/// Run the three source-tree passes (layering, no-alloc, struct-ripple)
/// over `set`, appending findings and returning scan counters. The
/// bench-manifest pass has different inputs — run it separately via
/// [`bench_manifest::check`].
pub fn run_source_passes(set: &SourceSet, findings: &mut Vec<Finding>) -> SourceStats {
    let use_edges = layering::check(set, findings);
    let alloc = no_alloc::check(set, findings);
    let literal_sites = struct_ripple::check(set, findings);
    SourceStats {
        files_scanned: set.files.len(),
        struct_defs: set.files.iter().map(|f| f.struct_defs.len()).sum(),
        literal_sites,
        use_edges,
        no_alloc_regions: alloc.regions,
        suppressed: alloc.suppressed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_set_reports_counts_without_findings() {
        let set = SourceSet::from_files(&[(
            "planner/good.rs",
            "use crate::heuristics::tiles::DecodeShape;\n\
             pub struct P { pub a: usize }\n\
             // pallas-lint: no_alloc\n\
             fn hot(p: &mut P) { p.a += 1; }\n\
             fn make() -> P { P { a: 0 } }\n",
        )]);
        let mut findings = Vec::new();
        let stats = run_source_passes(&set, &mut findings);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(stats.files_scanned, 1);
        assert_eq!(stats.struct_defs, 1);
        assert_eq!(stats.literal_sites, 1);
        assert_eq!(stats.use_edges, 1);
        assert_eq!(stats.no_alloc_regions, 1);
    }
}
