//! Hand-rolled Rust lexer for the source passes.
//!
//! This is not a compiler front end: it produces the *token stream the
//! lint passes need* — identifiers, numbers, and punctuation with line
//! numbers — with comments, string literals, char literals, and lifetimes
//! stripped, so the passes never match text inside a comment or a string.
//! The one thing comments carry out of the lexer is `// pallas-lint:`
//! marker [`Directive`]s (doc comments are excluded: `///`-rendered
//! examples must not plant live markers).
//!
//! Multi-character operators the passes care about (`::`, `->`, `=>`,
//! `..`, `..=`) are fused into single tokens; everything else is emitted
//! one character at a time. Nested block comments, raw strings
//! (`r"…"`/`r#"…"#`/byte variants), and escaped char literals are handled;
//! lifetimes (`'a`) are distinguished from char literals (`'a'`) by the
//! trailing quote.

/// Token class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (the passes treat keywords by name).
    Ident,
    /// Numeric literal (value never inspected, only skipped).
    Num,
    /// Punctuation: single char, or one of the fused operators.
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone, PartialEq)]
pub struct Tok {
    /// Class of the token.
    pub kind: TokKind,
    /// Exact source text.
    pub text: String,
    /// 1-based line number.
    pub line: usize,
}

impl Tok {
    /// Whether this token's text equals `s` (kind-agnostic convenience).
    pub fn is(&self, s: &str) -> bool {
        self.text == s
    }

    /// Whether this is an identifier token.
    pub fn is_ident(&self) -> bool {
        self.kind == TokKind::Ident
    }

    /// Whether this identifier starts with an uppercase letter (type-like).
    pub fn is_type_like(&self) -> bool {
        self.kind == TokKind::Ident
            && self.text.chars().next().is_some_and(|c| c.is_ascii_uppercase())
    }
}

/// A `// pallas-lint:` comment directive (text after the colon, trimmed).
#[derive(Debug, Clone, PartialEq)]
pub struct Directive {
    /// 1-based line the comment sits on.
    pub line: usize,
    /// Directive body, e.g. `no_alloc` or `allow(no_alloc): reason`.
    pub text: String,
}

/// The lexer's output: the stripped token stream plus marker directives.
#[derive(Debug, Clone, Default)]
pub struct LexOutput {
    /// Tokens with comments/strings/chars/lifetimes removed.
    pub toks: Vec<Tok>,
    /// `pallas-lint` directives harvested from ordinary `//` comments.
    pub directives: Vec<Directive>,
}

const MARKER: &str = "pallas-lint:";

/// Lex `src` into tokens + directives. Never fails: unterminated
/// constructs are consumed to end-of-input (the passes then simply see a
/// shorter stream; rustc owns real syntax-error reporting).
pub fn lex(src: &str) -> LexOutput {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = LexOutput::default();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // Line comment (and directive harvesting).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let body: String = chars[start..i].iter().collect();
            let is_doc = body.starts_with("///") || body.starts_with("//!");
            if !is_doc {
                if let Some(pos) = body.find(MARKER) {
                    out.directives.push(Directive {
                        line,
                        text: body[pos + MARKER.len()..].trim().to_string(),
                    });
                }
            }
            continue;
        }
        // Block comment, nested.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"…" / r#"…"# (and br variants).
        if let Some(skip) = raw_string_open(&chars, i) {
            let hashes = skip;
            i += hashes + 1 + if chars[i] == 'b' { 2 } else { 1 }; // past r#*"
            loop {
                if i >= n {
                    break;
                }
                if chars[i] == '\n' {
                    line += 1;
                }
                if chars[i] == '"' && closes_raw(&chars, i, hashes) {
                    i += 1 + hashes;
                    break;
                }
                i += 1;
            }
            continue;
        }
        // Plain or byte string.
        if c == '"' || (c == 'b' && i + 1 < n && chars[i + 1] == '"') {
            i += if c == 'b' { 2 } else { 1 };
            while i < n {
                if chars[i] == '\\' {
                    i += 2;
                    continue;
                }
                if chars[i] == '\n' {
                    line += 1;
                }
                if chars[i] == '"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                // Escaped char literal: consume to closing quote.
                i += 2;
                while i < n && chars[i] != '\'' {
                    i += 1;
                }
                i += 1;
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' {
                i += 3; // 'x'
                continue;
            }
            // Lifetime: consume the label, emit nothing.
            i += 1;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_' || chars[i] == '.') {
                // `0..4` is a range, not part of the number.
                if chars[i] == '.' && i + 1 < n && chars[i + 1] == '.' {
                    break;
                }
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Num,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Fused operators first, then single chars.
        let mut emitted = false;
        for op in ["::", "->", "=>", "..=", ".."] {
            if matches_at(&chars, i, op) {
                out.toks.push(Tok { kind: TokKind::Punct, text: op.to_string(), line });
                i += op.len();
                emitted = true;
                break;
            }
        }
        if !emitted {
            out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
            i += 1;
        }
    }
    out
}

/// If position `i` opens a raw string (`r"`, `r#"`, `br#"` …), return the
/// number of hashes; else None.
fn raw_string_open(chars: &[char], i: usize) -> Option<usize> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(hashes)
    } else {
        None
    }
}

/// Whether the `"` at position `i` is followed by `hashes` `#` chars.
fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'))
}

fn matches_at(chars: &[char], i: usize, op: &str) -> bool {
    op.chars().enumerate().all(|(k, c)| chars.get(i + k) == Some(&c))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strips_comments_strings_chars_lifetimes() {
        let src = r##"
// comment with Foo { bar }
/* block /* nested */ still comment */
let s = "string with } and \" escape";
let r = r#"raw " string"#;
let c = 'x'; let esc = '\n';
fn f<'a>(x: &'a str) {}
"##;
        let t = texts(src);
        assert!(!t.contains(&"Foo".to_string()), "{t:?}");
        assert!(!t.contains(&"string".to_string()));
        assert!(!t.contains(&"raw".to_string()));
        assert!(!t.contains(&"a".to_string()), "lifetime label leaked: {t:?}");
        assert!(t.contains(&"fn".to_string()));
    }

    #[test]
    fn fuses_multichar_operators() {
        let t = texts("a::b -> c => 0..4 ..=");
        assert_eq!(t, vec!["a", "::", "b", "->", "c", "=>", "0", "..", "4", "..="]);
    }

    #[test]
    fn tracks_lines_through_skipped_regions() {
        let out = lex("let a = \"x\ny\";\n/* c\nc */ b");
        let b = out.toks.last().unwrap();
        assert_eq!(b.text, "b");
        assert_eq!(b.line, 4);
    }

    #[test]
    fn harvests_directives_but_not_from_doc_comments() {
        let src = "\
// pallas-lint: no_alloc
fn hot() {}
/// pallas-lint: no_alloc  (doc comment: inert)
fn cold() {}
// pallas-lint: allow(no_alloc): justified
";
        let out = lex(src);
        let d: Vec<(usize, &str)> =
            out.directives.iter().map(|d| (d.line, d.text.as_str())).collect();
        assert_eq!(d, vec![(1, "no_alloc"), (5, "allow(no_alloc): justified")]);
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let t = texts("for i in 1..=16 { 4.0 }");
        assert!(t.contains(&"1".to_string()));
        assert!(t.contains(&"..=".to_string()));
        assert!(t.contains(&"4.0".to_string()));
    }
}
