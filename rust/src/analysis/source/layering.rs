//! Layering pass: the allowed inter-module dependency DAG, plus the PR-1
//! façade invariant that nothing outside `planner/` constructs
//! [`crate::heuristics::SchedulerMetadata`].
//!
//! The architecture stacks (DESIGN.md §Static analysis draws the full
//! picture): `util` and `heuristics` at the bottom with no internal
//! dependencies, `obs` (the tracing/metrics layer) directly above `util`
//! so every layer may record into it, `planner` above `heuristics`,
//! `sim` above both, the
//! serving stack (`runtime` → `backend` → `coordinator` → `workload`)
//! above those, and `evolve` / `bench_harness` / `cluster` / `analysis`
//! at the top. Two *documented back-edges* exist and are part of the
//! allowed set but excluded from the acyclicity order:
//!
//! * `planner → sim` — the registry's `extended` factory tunes its table
//!   against the target device's simulator.
//! * `planner → evolve` — `PlanSource::Genome` embeds the evolved rule
//!   DSL.
//!
//! Test regions (`#[cfg(test)]` / `#[test]`) are exempt: tests routinely
//! reach across layers to assert end-to-end behavior. The façade rule has
//! no test exemption — even tests must build `SchedulerMetadata` through
//! a [`crate::planner::Planner`] — with the single exception of the
//! defining file and `planner/` itself.

use crate::analysis::report::Finding;

use super::model::SourceSet;

/// Pass name in findings.
pub const PASS: &str = "layering";

/// Modules in bottom-up order. The position is the topological rank used
/// by the self-check: every allowed edge (minus documented back-edges)
/// must point from a higher-ranked module to a lower-ranked one.
pub const MODULE_ORDER: &[&str] = &[
    "util",
    "obs",
    "heuristics",
    "planner",
    "sim",
    "runtime",
    "backend",
    "schedule",
    "coordinator",
    "workload",
    "evolve",
    "bench_harness",
    "cluster",
    "analysis",
];

/// Allowed dependency edges `(from, to…)`. Modules absent from the list
/// (`lib`, `main`) are unrestricted: `lib.rs` only declares the tree and
/// the binary crate addresses it as `fa3_split::`, not `crate::`.
pub const ALLOWED: &[(&str, &[&str])] = &[
    ("util", &[]),
    // obs is the cross-cutting tracing/metrics layer: it sits just above
    // util (its only dependency) so that every layer of the serving
    // stack may record into it without creating a cycle.
    ("obs", &["util"]),
    ("heuristics", &[]),
    ("runtime", &["util"]),
    ("planner", &["heuristics", "obs", "util", "sim", "evolve"]),
    ("sim", &["heuristics", "planner", "util"]),
    ("evolve", &["heuristics", "planner", "sim", "util", "workload"]),
    ("workload", &["coordinator", "heuristics", "obs", "util"]),
    ("backend", &["heuristics", "obs", "planner", "runtime", "sim", "util"]),
    ("schedule", &["obs", "util"]),
    ("coordinator", &["backend", "heuristics", "obs", "planner", "schedule", "sim", "util"]),
    // `sim` joined the list for disaggregation: the fleet prices
    // cross-pool KV handoffs with `sim::HostTransferModel` (via
    // `Interconnect::transfer_model`), a plain downward edge.
    (
        "cluster",
        &["backend", "coordinator", "heuristics", "obs", "planner", "sim", "util", "workload"],
    ),
    ("bench_harness", &["evolve", "heuristics", "obs", "planner", "sim", "util", "workload"]),
    ("analysis", &["heuristics", "planner", "util"]),
];

/// The documented back-edges: allowed, but exempt from the topological
/// self-check (each carries a design justification above).
pub const BACK_EDGES: &[(&str, &str)] = &[("planner", "sim"), ("planner", "evolve")];

/// The façade type and where constructing it is legal: `planner/` (the
/// façade) and the defining file's own impl/combinators.
const FACADE_TYPE: &str = "SchedulerMetadata";
const FACADE_ALLOWED_PREFIX: &str = "planner/";
const FACADE_DEFINING_FILE: &str = "heuristics/metadata.rs";

fn allowed_targets(module: &str) -> Option<&'static [&'static str]> {
    ALLOWED.iter().find(|(m, _)| *m == module).map(|(_, t)| *t)
}

/// Run the pass. Returns the number of non-test use edges examined.
pub fn check(set: &SourceSet, findings: &mut Vec<Finding>) -> usize {
    // Self-check: a config edit that turns the allowed set cyclic (minus
    // documented back-edges) is itself a finding, so the DAG stays a DAG.
    for &(from, targets) in ALLOWED {
        for &to in targets {
            if BACK_EDGES.contains(&(from, to)) {
                continue;
            }
            let rank = |m: &str| MODULE_ORDER.iter().position(|x| *x == m);
            match (rank(from), rank(to)) {
                (Some(rf), Some(rt)) if rf > rt => {}
                _ => findings.push(Finding::error(
                    PASS,
                    "analysis/source/layering.rs",
                    0,
                    format!(
                        "allowed edge {from} -> {to} is not downward in MODULE_ORDER \
                         (add a documented back-edge or reorder)"
                    ),
                )),
            }
        }
    }

    let mut edges = 0usize;
    for fm in &set.files {
        let Some(targets) = allowed_targets(&fm.module) else {
            continue; // lib/main: unrestricted
        };
        for u in &fm.uses {
            if u.in_test || u.target == fm.module {
                continue;
            }
            // Only module names are layering edges; `crate::SomeItem`
            // (a root re-export) is not a module dependency.
            if !MODULE_ORDER.contains(&u.target.as_str()) {
                continue;
            }
            edges += 1;
            if !targets.contains(&u.target.as_str()) {
                findings.push(Finding::error(
                    PASS,
                    fm.path.as_str(),
                    u.line,
                    format!(
                        "dependency edge {} -> {} is not in the allowed layering DAG",
                        fm.module, u.target
                    ),
                ));
            }
        }
        // Façade exclusivity: SchedulerMetadata literals outside planner/.
        for site in &fm.literal_sites {
            let last = site.path.rsplit("::").next().unwrap_or(&site.path);
            if last == FACADE_TYPE
                && !fm.path.starts_with(FACADE_ALLOWED_PREFIX)
                && fm.path != FACADE_DEFINING_FILE
            {
                findings.push(Finding::error(
                    PASS,
                    fm.path.as_str(),
                    site.line,
                    format!(
                        "{FACADE_TYPE} constructed outside the planner facade \
                         (build plans via crate::planner::Planner)"
                    ),
                ));
            }
        }
    }
    edges
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowed_set_is_a_dag_modulo_documented_back_edges() {
        let set = SourceSet::from_files(&[]);
        let mut findings = Vec::new();
        check(&set, &mut findings);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn cross_layer_edge_fires() {
        let set = SourceSet::from_files(&[(
            "heuristics/bad.rs",
            "use crate::coordinator::Engine;\n",
        )]);
        let mut findings = Vec::new();
        check(&set, &mut findings);
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("heuristics -> coordinator"));
    }

    #[test]
    fn test_regions_are_exempt() {
        let set = SourceSet::from_files(&[(
            "heuristics/ok.rs",
            "#[cfg(test)]\nmod tests {\n    use crate::coordinator::Engine;\n}\n",
        )]);
        let mut findings = Vec::new();
        check(&set, &mut findings);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn facade_exclusivity_fires_outside_planner() {
        let bad = "fn f() { let m = SchedulerMetadata { shape, num_splits: 1 }; }\n";
        let set = SourceSet::from_files(&[("sim/bad.rs", bad)]);
        let mut findings = Vec::new();
        check(&set, &mut findings);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("facade"));

        // Same construction inside planner/ is the façade's own right.
        let set = SourceSet::from_files(&[("planner/mod.rs", bad)]);
        let mut findings = Vec::new();
        check(&set, &mut findings);
        assert!(findings.is_empty(), "{findings:?}");
    }
}
