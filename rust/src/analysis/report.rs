//! Findings: the shared currency of every pallas-lint pass.
//!
//! Each pass appends [`Finding`]s to a caller-owned vector; the CLI
//! aggregates them into a [`LintReport`] whose JSON form is the
//! `static-analysis` CI artifact. Findings are plain data — file, line,
//! pass name, message — so the report stays diffable and greppable.

use crate::util::json::Json;

/// How bad a finding is. `Error` findings fail the build (non-zero CLI
/// exit); `Warning`s are surfaced but do not gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Violation of an enforced invariant: fails `lint`.
    Error,
    /// Advisory (e.g. a bench manifest still carrying modeled numbers).
    Warning,
}

impl Severity {
    /// Lower-case label used in the JSON report.
    pub fn label(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One diagnostic from one pass, anchored to a file and line.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Pass that produced it: `layering`, `no_alloc`, `struct_ripple`,
    /// `bench_manifest`, `modelcheck`, or `directive`.
    pub pass: &'static str,
    /// Repo-relative path (`rust/src/...`), or a symbolic location for
    /// model-checker findings (the offending shape, printed).
    pub file: String,
    /// 1-based line; 0 when the finding has no meaningful line.
    pub line: usize,
    /// Human-readable description of the violation.
    pub message: String,
    /// Gate or advisory.
    pub severity: Severity,
}

impl Finding {
    /// A gating finding.
    pub fn error(pass: &'static str, file: impl Into<String>, line: usize, message: impl Into<String>) -> Finding {
        Finding { pass, file: file.into(), line, message: message.into(), severity: Severity::Error }
    }

    /// An advisory finding.
    pub fn warning(pass: &'static str, file: impl Into<String>, line: usize, message: impl Into<String>) -> Finding {
        Finding { pass, file: file.into(), line, message: message.into(), severity: Severity::Warning }
    }

    /// `file:line: [pass] message` — the human-readable form.
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}: {}", self.file, self.line, self.severity.label(), self.pass, self.message)
    }

    /// JSON object for the findings report.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("pass", Json::str(self.pass)),
            ("file", Json::str(&self.file)),
            ("line", Json::int(self.line as i64)),
            ("severity", Json::str(self.severity.label())),
            ("message", Json::str(&self.message)),
        ])
    }
}

/// Scan-size counters from the source passes, reported alongside the
/// findings so "0 findings" is distinguishable from "0 files scanned".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SourceStats {
    /// `.rs` files analyzed.
    pub files_scanned: usize,
    /// Struct definitions (incl. enum struct-variants) in the model.
    pub struct_defs: usize,
    /// Struct-literal / struct-pattern sites checked by struct-ripple.
    pub literal_sites: usize,
    /// Non-test inter-module use edges checked by layering.
    pub use_edges: usize,
    /// `no_alloc` regions checked.
    pub no_alloc_regions: usize,
    /// Findings silenced by justified `allow(...)` directives.
    pub suppressed: usize,
}

impl SourceStats {
    /// JSON object for the findings report.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("files_scanned", Json::int(self.files_scanned as i64)),
            ("struct_defs", Json::int(self.struct_defs as i64)),
            ("literal_sites", Json::int(self.literal_sites as i64)),
            ("use_edges", Json::int(self.use_edges as i64)),
            ("no_alloc_regions", Json::int(self.no_alloc_regions as i64)),
            ("suppressed", Json::int(self.suppressed as i64)),
        ])
    }
}

/// The complete lint run: source-pass findings + model-checker findings
/// plus the counters that make the gate auditable.
#[derive(Debug, Clone)]
pub struct LintReport {
    /// All findings, source passes first, model checker after.
    pub findings: Vec<Finding>,
    /// Source-scan counters ([`SourceStats::default`] if source passes
    /// were skipped).
    pub source: SourceStats,
    /// Model-check domain summary (None when `--no-modelcheck`).
    pub modelcheck: Option<Json>,
}

impl LintReport {
    /// Number of gating findings.
    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    /// Number of advisory findings.
    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    /// Whether the tree passes the gate (zero errors; warnings allowed).
    pub fn clean(&self) -> bool {
        self.errors() == 0
    }

    /// The findings-report JSON (the CI artifact).
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self.findings.iter().map(Finding::to_json).collect();
        let mut fields = vec![
            ("errors", Json::int(self.errors() as i64)),
            ("warnings", Json::int(self.warnings() as i64)),
            ("findings", Json::Arr(findings)),
            ("source", self.source.to_json()),
        ];
        if let Some(mc) = &self.modelcheck {
            fields.push(("modelcheck", mc.clone()));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_json_roundtrip_the_fields() {
        let f = Finding::error("layering", "a/b.rs", 7, "bad edge");
        assert_eq!(f.render(), "a/b.rs:7: [error] layering: bad edge");
        let j = f.to_json().to_string_pretty();
        assert!(j.contains("\"pass\": \"layering\""));
        assert!(j.contains("\"line\": 7"));
    }

    #[test]
    fn report_counts_severities() {
        let report = LintReport {
            findings: vec![
                Finding::error("layering", "x.rs", 1, "e"),
                Finding::warning("bench_manifest", "y.json", 0, "w"),
            ],
            source: SourceStats::default(),
            modelcheck: None,
        };
        assert_eq!(report.errors(), 1);
        assert_eq!(report.warnings(), 1);
        assert!(!report.clean());
        assert!(report.to_json().to_string_pretty().contains("\"errors\": 1"));
    }
}
