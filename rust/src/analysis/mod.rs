//! pallas-lint: self-hosted static analysis + plan-space invariant
//! verifier.
//!
//! The repo has accumulated invariants that rustc cannot see and that
//! review keeps re-deriving by hand: the module layering DAG, the PR-1
//! rule that only `planner/` constructs `SchedulerMetadata`, the PR-4
//! zero-allocation decode hot path, the bench-manifest ↔ docs ↔ CI
//! wiring, and the paper's own occupancy claims. This subsystem makes
//! them machine-checked, with zero external dependencies (the offline
//! container has no crates.io):
//!
//! * [`source`] — a hand-rolled lexer + module model over `rust/src/**`
//!   feeding four passes: `layering`, `no_alloc`, `struct_ripple`,
//!   `bench_manifest`.
//! * [`modelcheck`] — bounded-exhaustive enumeration of the decode-shape
//!   domain proving split-bounds, occupancy-bounds, the sequence-aware
//!   no-regression inequality, and cursor-horizon soundness for every
//!   registered policy on every device preset.
//! * [`fixtures`] — seeded-violation corpus verifying each pass still
//!   fires (and only on its own violation).
//! * [`report`] — findings, counters, and the JSON artifact CI uploads.
//!
//! Entry point: `fa3-split lint` (see `main.rs`), or [`run`] from tests.

pub mod fixtures;
pub mod modelcheck;
pub mod report;
pub mod source;

pub use modelcheck::{ModelCheckConfig, ModelCheckReport};
pub use report::{Finding, LintReport, Severity, SourceStats};
pub use source::SourceSet;

use std::path::{Path, PathBuf};

/// What a lint run should cover.
#[derive(Debug, Clone)]
pub struct LintOptions {
    /// Root of the Rust source tree to scan (`rust/src`).
    pub src_dir: PathBuf,
    /// Repo root, for the bench-manifest pass (`BENCH_*.json`, docs, CI).
    pub repo_root: PathBuf,
    /// Model-checker domain; `None` skips the model checker.
    pub modelcheck: Option<ModelCheckConfig>,
}

impl LintOptions {
    /// Options rooted at a repo checkout, full model-check domain.
    pub fn at_repo_root(repo_root: &Path) -> LintOptions {
        LintOptions {
            src_dir: repo_root.join("rust").join("src"),
            repo_root: repo_root.to_path_buf(),
            modelcheck: Some(ModelCheckConfig::full()),
        }
    }
}

/// Run every pass per `opts` and assemble the report.
pub fn run(opts: &LintOptions) -> std::io::Result<LintReport> {
    let mut findings = Vec::new();

    let set = SourceSet::load_dir(&opts.src_dir)?;
    let stats = source::run_source_passes(&set, &mut findings);

    let inputs = source::bench_manifest::BenchManifestInputs::load(&opts.repo_root)?;
    source::bench_manifest::check(&inputs, &mut findings);

    let modelcheck = opts.modelcheck.as_ref().map(|cfg| {
        let mc = modelcheck::check(cfg);
        let summary = mc.domain_json(cfg);
        findings.extend(mc.findings);
        summary
    });

    Ok(LintReport { findings, source: stats, modelcheck })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_point_at_the_conventional_layout() {
        let opts = LintOptions::at_repo_root(Path::new("/r"));
        assert_eq!(opts.src_dir, Path::new("/r/rust/src"));
        assert!(opts.modelcheck.is_some());
    }
}
