//! Plan-space model checker: bounded-exhaustive verification of the
//! planner's output invariants over the full decode-shape domain.
//!
//! The policy space of this repo is small — closed-form occupancy
//! arithmetic over `(nblk, tiles, device)` — which makes *checking it
//! exhaustively* cheaper than arguing about it. Split decisions are
//! bucket-pure in `l_k` (constant within a 128-token KV-block bucket), so
//! enumerating both edges of every bucket IS the full domain for the
//! bucketed axes; the checker reports exactly how much it enumerated.
//!
//! Four theorem families, per registered policy on every device preset:
//!
//! 1. **Split bounds** — `1 ≤ num_splits ≤ device.max_splits`, and the
//!    effective (work-receiving) split count never exceeds the commanded
//!    count or the block count.
//! 2. **Occupancy bounds** — `0 < occupancy ≤ 1`, `waves ≥ 1`,
//!    `grid_ctas ≥ 1`, including under an `sm_margin` larger than the
//!    device (the saturating-budget underflow regime).
//! 3. **No-regression** (the paper's §5.2 claim, machine-checked): for
//!    every low-head-count shape (`h_kv ≤ 4`), sequence-aware first-wave
//!    occupancy ≥ standard occupancy — with *strict* improvement required
//!    on the boundary bucket (`nblk == 4`, `tiles <`
//!    [`crate::heuristics::sequence_aware::LOW_TILE_THRESHOLD`]) whenever
//!    the standard policy left headroom.
//! 4. **Cursor-horizon soundness** — for every `l_k` in an exhaustive
//!    sweep, a [`crate::planner::PlanCursor`]-served plan equals a fresh
//!    planner's plan exactly (`LaunchPlan: PartialEq`, element-wise), for
//!    every policy including the evolved genome whose validity windows
//!    are clipped by rule edges rather than bucket edges.

use crate::heuristics::sequence_aware::LOW_TILE_THRESHOLD;
use crate::heuristics::tiles::{DecodeShape, KV_BLOCK};
use crate::planner::{DeviceProfile, PolicyRegistry};
use crate::util::json::Json;

use super::report::Finding;

/// Pass name in findings.
pub const PASS: &str = "modelcheck";

/// Registry policies the checker verifies, in ladder order.
pub const POLICIES: &[&str] = &["standard", "sequence-aware", "extended", "evolved-genome"];

/// The enumerated domain. Both presets keep `l_k` coverage exhaustive in
/// bucket space up to `exhaustive_nblk` buckets (both edges of every
/// bucket) and sample higher buckets explicitly listed in
/// `sampled_nblks` — the report states both, so nothing is silently
/// truncated.
#[derive(Debug, Clone)]
pub struct ModelCheckConfig {
    /// KV head counts to enumerate.
    pub h_kvs: Vec<usize>,
    /// Batch sizes to enumerate.
    pub batches: Vec<usize>,
    /// Every bucket `1..=exhaustive_nblk` contributes both `l_k` edges.
    pub exhaustive_nblk: usize,
    /// Additional bucket indices beyond the exhaustive range (both edges).
    pub sampled_nblks: Vec<usize>,
    /// Device presets to check.
    pub devices: Vec<DeviceProfile>,
    /// SM margins, including one larger than any preset's SM count to
    /// exercise the saturating-budget underflow path.
    pub sm_margins: Vec<usize>,
    /// Cursor soundness: sweep `l_k` from 1 to this, inclusive.
    pub cursor_lk_max: usize,
    /// Cursor soundness: `(batch, h_kv)` trajectories to sweep.
    pub cursor_pairs: Vec<(usize, usize)>,
    /// Query heads per KV head (GQA group; the paper's Llama-70B/TP8
    /// slice has 8 query heads per KV head).
    pub gqa_group: usize,
}

impl ModelCheckConfig {
    /// The CI domain: h_kv 1..=16, batch 1..=64, every bucket edge to 8Ki
    /// tokens plus sampled buckets to 128Ki, all four device presets,
    /// three margin regimes. Several million planner invocations —
    /// seconds in release, too slow for debug test runs (use [`quick`]).
    ///
    /// [`quick`]: ModelCheckConfig::quick
    pub fn full() -> ModelCheckConfig {
        ModelCheckConfig {
            h_kvs: (1..=16).collect(),
            batches: (1..=64).collect(),
            exhaustive_nblk: 64,
            sampled_nblks: vec![96, 128, 192, 256, 384, 512, 768, 1024],
            devices: DeviceProfile::presets().to_vec(),
            sm_margins: vec![0, 16, 1000],
            cursor_lk_max: 128 * 1024,
            cursor_pairs: vec![(1, 1), (2, 1), (8, 4), (64, 16)],
            gqa_group: 8,
        }
    }

    /// A reduced domain for debug-mode tests: same theorem set, smaller
    /// enumeration.
    pub fn quick() -> ModelCheckConfig {
        ModelCheckConfig {
            h_kvs: vec![1, 2, 4, 16],
            batches: vec![1, 2, 64],
            exhaustive_nblk: 8,
            sampled_nblks: vec![16, 64, 1024],
            devices: vec![DeviceProfile::H100_SXM, DeviceProfile::A100_SXM],
            sm_margins: vec![0, 1000],
            cursor_lk_max: 1536,
            cursor_pairs: vec![(1, 1), (4, 2)],
            gqa_group: 8,
        }
    }

    /// The `l_k` evaluation points: both edges of every covered bucket.
    pub fn lk_points(&self) -> Vec<usize> {
        let mut pts = Vec::new();
        let mut nblks: Vec<usize> = (1..=self.exhaustive_nblk).collect();
        nblks.extend(self.sampled_nblks.iter().copied().filter(|n| *n > self.exhaustive_nblk));
        for nblk in nblks {
            pts.push((nblk - 1) * KV_BLOCK + 1);
            pts.push(nblk * KV_BLOCK);
        }
        pts
    }
}

/// What the checker enumerated and what it found.
#[derive(Debug, Clone)]
pub struct ModelCheckReport {
    /// Plans checked for the bounds theorems (T1/T2).
    pub bounds_plans: u64,
    /// `(shape, device, margin)` tuples compared for no-regression (T3).
    pub no_regression_domain: u64,
    /// Of those, boundary-bucket shapes where strict improvement was
    /// additionally required.
    pub strict_improvements: u64,
    /// Cursor-vs-fresh plan comparisons (T4).
    pub cursor_plans: u64,
    /// Violations (empty on a healthy tree).
    pub findings: Vec<Finding>,
}

impl ModelCheckReport {
    /// Total enumerated domain size across all theorem families.
    pub fn total_domain(&self) -> u64 {
        self.bounds_plans + self.no_regression_domain + self.cursor_plans
    }

    /// The domain summary embedded in the findings JSON (the acceptance
    /// criterion: the no-regression inequality is proved over an
    /// enumerated domain whose size the report states).
    pub fn domain_json(&self, cfg: &ModelCheckConfig) -> Json {
        Json::obj(vec![
            ("policies", Json::arr(POLICIES.iter().map(|p| Json::str(*p)))),
            ("devices", Json::arr(cfg.devices.iter().map(|d| Json::str(d.name)))),
            ("sm_margins", Json::arr(cfg.sm_margins.iter().map(|m| Json::int(*m as i64)))),
            ("h_kvs", Json::int(cfg.h_kvs.len() as i64)),
            ("batches", Json::int(cfg.batches.len() as i64)),
            ("l_k_points", Json::int(cfg.lk_points().len() as i64)),
            ("l_k_max", Json::int((cfg.exhaustive_nblk.max(
                cfg.sampled_nblks.iter().copied().max().unwrap_or(0),
            ) * KV_BLOCK) as i64)),
            ("bounds_plans", Json::int(self.bounds_plans as i64)),
            ("no_regression_domain", Json::int(self.no_regression_domain as i64)),
            ("strict_improvements", Json::int(self.strict_improvements as i64)),
            ("cursor_plans", Json::int(self.cursor_plans as i64)),
            ("total_domain", Json::int(self.total_domain() as i64)),
            ("violations", Json::int(self.findings.len() as i64)),
        ])
    }
}

fn shape_of(cfg: &ModelCheckConfig, batch: usize, l_k: usize, h_kv: usize) -> DecodeShape {
    DecodeShape::decode(batch, l_k, cfg.gqa_group * h_kv, h_kv, 128)
}

fn violation(file: String, message: String) -> Finding {
    Finding::error(PASS, file, 0, message)
}

/// Run the model checker over `cfg`'s domain.
pub fn check(cfg: &ModelCheckConfig) -> ModelCheckReport {
    let registry = PolicyRegistry::builtin();
    let mut report = ModelCheckReport {
        bounds_plans: 0,
        no_regression_domain: 0,
        strict_improvements: 0,
        cursor_plans: 0,
        findings: Vec::new(),
    };
    let lk_points = cfg.lk_points();

    for device in &cfg.devices {
        for &margin in &cfg.sm_margins {
            // One planner per policy for this (device, margin); a large
            // LRU keeps the enumeration fast without touching decisions.
            let mut planners: Vec<(&str, crate::planner::Planner)> = POLICIES
                .iter()
                .map(|name| {
                    let planner = registry
                        .builder_for(name, device)
                        .expect("builtin policy")
                        .sm_margin(margin)
                        .cache_capacity(4096)
                        .build();
                    (*name, planner)
                })
                .collect();
            for &h_kv in &cfg.h_kvs {
                for &batch in &cfg.batches {
                    for &l_k in &lk_points {
                        let shape = shape_of(cfg, batch, l_k, h_kv);
                        let mut occ_std = None;
                        let mut occ_seq = None;
                        for (name, planner) in planners.iter_mut() {
                            let plan = planner.plan(&shape);
                            report.bounds_plans += 1;
                            check_bounds(name, device, margin, &shape, &plan, &mut report);
                            if *name == "standard" {
                                occ_std = Some(plan.occupancy);
                            } else if *name == "sequence-aware" {
                                occ_seq = Some(plan.occupancy);
                            }
                        }
                        if h_kv <= 4 {
                            if let (Some(std_o), Some(seq_o)) = (occ_std, occ_seq) {
                                no_regression(device, margin, &shape, std_o, seq_o, &mut report);
                            }
                        }
                    }
                }
            }
        }
    }

    cursor_soundness(cfg, &registry, &mut report);
    report
}

fn check_bounds(
    name: &str,
    device: &DeviceProfile,
    margin: usize,
    shape: &DecodeShape,
    plan: &crate::planner::LaunchPlan,
    report: &mut ModelCheckReport,
) {
    let at = || format!("{name}@{} margin={margin}", device.name);
    let here = |msg: String| violation(at(), format!("{msg} (shape {shape:?})"));
    let s = plan.num_splits();
    if s < 1 || s > device.max_splits {
        report.findings.push(here(format!(
            "num_splits {s} outside [1, {}]",
            device.max_splits
        )));
    }
    if plan.effective_splits < 1
        || plan.effective_splits > s
        || plan.effective_splits > shape.nblk()
    {
        report.findings.push(here(format!(
            "effective_splits {} outside [1, min(num_splits {s}, nblk {})]",
            plan.effective_splits,
            shape.nblk()
        )));
    }
    if !(plan.occupancy > 0.0 && plan.occupancy <= 1.0) {
        report.findings.push(here(format!("occupancy {} outside (0, 1]", plan.occupancy)));
    }
    let md_occ = plan.metadata.occupancy();
    if !(md_occ > 0.0 && md_occ.is_finite()) {
        report.findings.push(here(format!(
            "metadata occupancy {md_occ} non-positive or non-finite (sm_margin underflow?)"
        )));
    }
    if plan.waves < 1 {
        report.findings.push(here(format!("waves {} < 1", plan.waves)));
    }
    if plan.grid_ctas < 1 {
        report.findings.push(here(format!("grid_ctas {} < 1", plan.grid_ctas)));
    }
}

fn no_regression(
    device: &DeviceProfile,
    margin: usize,
    shape: &DecodeShape,
    occ_std: f64,
    occ_seq: f64,
    report: &mut ModelCheckReport,
) {
    report.no_regression_domain += 1;
    let at = || format!("sequence-aware-vs-standard@{} margin={margin}", device.name);
    if occ_seq < occ_std - 1e-12 {
        report.findings.push(violation(
            at(),
            format!(
                "no-regression violated: sequence-aware occupancy {occ_seq} < \
                 standard {occ_std} (shape {shape:?})"
            ),
        ));
    }
    // The paper's win, stated strictly: on the boundary bucket with few
    // tiles, the override must *raise* occupancy whenever standard left
    // headroom (occupancy below 1 means idle SMs existed to reclaim).
    let tiles = shape.total_mblocks(true);
    if shape.nblk() == 4 && tiles < LOW_TILE_THRESHOLD && occ_std < 1.0 - 1e-12 {
        report.strict_improvements += 1;
        if occ_seq <= occ_std + 1e-12 {
            report.findings.push(violation(
                at(),
                format!(
                    "boundary bucket not improved: sequence-aware occupancy \
                     {occ_seq} vs standard {occ_std} (shape {shape:?})"
                ),
            ));
        }
    }
}

fn cursor_soundness(
    cfg: &ModelCheckConfig,
    registry: &PolicyRegistry,
    report: &mut ModelCheckReport,
) {
    // Full-range sweep at margin 0, plus a capped sweep in the underflow
    // regime when the config carries an oversized margin.
    let mut regimes = vec![(0usize, cfg.cursor_lk_max)];
    if let Some(&m) = cfg.sm_margins.iter().find(|&&m| m > 0) {
        regimes.push((m, cfg.cursor_lk_max.min(2048)));
    }
    for device in &cfg.devices {
        for &(margin, lk_max) in &regimes {
            for name in POLICIES {
                let build = || {
                    registry
                        .builder_for(name, device)
                        .expect("builtin policy")
                        .sm_margin(margin)
                        .cache_capacity(4096)
                        .build()
                };
                let mut planner = build();
                let mut oracle = build();
                for &(batch, h_kv) in &cfg.cursor_pairs {
                    let mut cursor = planner.cursor();
                    for l_k in 1..=lk_max {
                        let shape = shape_of(cfg, batch, l_k, h_kv);
                        let via_cursor = cursor.plan(&mut planner, &shape);
                        let fresh = oracle.plan(&shape);
                        report.cursor_plans += 1;
                        if via_cursor != fresh {
                            report.findings.push(violation(
                                format!("{name}@{} margin={margin}", device.name),
                                format!(
                                    "cursor plan diverges from fresh plan at {shape:?}: \
                                     cursor splits {} vs fresh {}",
                                    via_cursor.num_splits(),
                                    fresh.num_splits()
                                ),
                            ));
                            break; // one finding per trajectory is enough
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::sequence_aware::BOUNDARY_SPLIT;

    #[test]
    fn quick_domain_holds_all_theorems() {
        let cfg = ModelCheckConfig::quick();
        let report = check(&cfg);
        for f in &report.findings {
            eprintln!("{}", f.render());
        }
        assert!(report.findings.is_empty());
        assert!(report.no_regression_domain > 0);
        assert!(report.strict_improvements > 0, "boundary bucket must be exercised");
        assert!(report.cursor_plans > 0);
        let j = report.domain_json(&cfg).to_string_pretty();
        assert!(j.contains("no_regression_domain"));
        assert!(j.contains("\"violations\": 0"));
    }

    #[test]
    fn known_good_triples_pin_the_planner() {
        // Spot pins: (shape, policy) -> (splits, occupancy) on H100 SXM
        // (132 SMs, margin 0). These are the paper's headline cells; if
        // any drifts, the model checker's substrate changed.
        let registry = PolicyRegistry::builtin();
        let h100 = DeviceProfile::H100_SXM;
        let cases: &[(&str, usize, usize, usize, f64)] = &[
            // (policy, batch, l_k, expected splits, expected occupancy)
            // B=1 H_KV=1 L_K=512: standard hits the premature guard.
            ("standard", 1, 512, 1, 1.0 / 132.0),
            // sequence-aware overrides to s=3 -> 2 effective CTAs.
            ("sequence-aware", 1, 512, BOUNDARY_SPLIT, 2.0 / 132.0),
            // Long sequence: both split via the efficiency loop.
            ("standard", 1, 8192, 64, 64.0 / 132.0),
            ("sequence-aware", 1, 8192, 64, 64.0 / 132.0),
        ];
        for &(policy, batch, l_k, splits, occ) in cases {
            let mut p = registry.builder_for(policy, &h100).unwrap().build();
            let shape = DecodeShape::llama70b_tp8(batch, l_k);
            let plan = p.plan(&shape);
            assert_eq!(plan.num_splits(), splits, "{policy} B={batch} L_K={l_k}");
            assert!(
                (plan.occupancy - occ).abs() < 1e-12,
                "{policy} B={batch} L_K={l_k}: occupancy {} vs expected {occ}",
                plan.occupancy
            );
        }
    }

    #[test]
    fn lk_points_cover_both_edges_of_every_bucket() {
        let cfg = ModelCheckConfig::quick();
        let pts = cfg.lk_points();
        assert!(pts.contains(&1) && pts.contains(&128), "bucket 1 edges");
        assert!(pts.contains(&((8 - 1) * 128 + 1)) && pts.contains(&(8 * 128)));
        assert!(pts.contains(&(1024 * 128)), "top sampled bucket reaches 128Ki");
    }
}
