//! Seeded-violation fixture corpus: the lint's own regression suite.
//!
//! Each fixture is a tiny in-memory source tree carrying exactly one
//! deliberate violation; [`verify`] runs the real passes over it and
//! demands (a) at least one finding from the expected pass whose message
//! contains the expected fragment, and (b) zero findings from any other
//! pass (a fixture that trips a *different* pass means a false positive
//! crept in). A final clean fixture must produce no findings at all.
//!
//! `lint --fixtures` runs this corpus in CI and the integration tests
//! reuse it verbatim, so "does each pass still fire?" is checked by the
//! same code path everywhere.

use crate::analysis::report::Finding;
use crate::analysis::source::{bench_manifest, run_source_passes, SourceSet};

/// One seeded-violation case over the source passes.
pub struct Fixture {
    /// Corpus-unique label, reported on failure.
    pub name: &'static str,
    /// The pass expected to fire (`""` for the clean fixture).
    pub pass: &'static str,
    /// Fragment the finding's message must contain.
    pub expect: &'static str,
    /// The in-memory tree: `(path, contents)`.
    pub files: &'static [(&'static str, &'static str)],
}

/// The source-pass corpus. Kept small and surgical: one violation per
/// fixture, everything else legal.
pub fn corpus() -> Vec<Fixture> {
    vec![
        Fixture {
            name: "layering_back_edge",
            pass: "layering",
            expect: "heuristics -> planner",
            files: &[(
                "heuristics/bad.rs",
                "use crate::planner::DeviceProfile;\n\
                 pub fn f() -> usize { DeviceProfile::H100_SXM.num_sms }\n",
            )],
        },
        Fixture {
            name: "facade_escape",
            pass: "layering",
            expect: "outside the planner facade",
            files: &[(
                "backend/bad.rs",
                "fn forge() { let md = SchedulerMetadata { num_splits: 1, .. base }; }\n",
            )],
        },
        Fixture {
            name: "obs_layering_escape",
            pass: "layering",
            expect: "obs -> coordinator",
            files: &[(
                // obs is the bottom tracing layer (obs -> util only): an
                // import of the engine from inside obs inverts the DAG.
                "obs/bad.rs",
                "use crate::coordinator::Engine;\n\
                 pub fn peek(e: &Engine) -> usize { e.metrics.steps }\n",
            )],
        },
        Fixture {
            name: "cluster_upward_edge",
            pass: "layering",
            expect: "cluster -> analysis",
            files: &[(
                // cluster's allow-list grew a `sim` edge for the
                // disaggregation transfer model; this fixture proves the
                // widened list still rejects a genuinely upward import.
                "cluster/bad.rs",
                "use crate::analysis::report::Finding;\n\
                 pub fn peek(f: &Finding) -> usize { f.line }\n",
            )],
        },
        Fixture {
            name: "no_alloc_violation",
            pass: "no_alloc",
            expect: "allocating idiom `vec!`",
            files: &[(
                "coordinator/bad.rs",
                "// pallas-lint: no_alloc\n\
                 fn hot() { let xs = vec![1usize, 2]; drop(xs); }\n",
            )],
        },
        Fixture {
            name: "struct_ripple_mismatch",
            pass: "struct_ripple",
            expect: "does not match its definition",
            files: &[
                (
                    "planner/def.rs",
                    "pub struct Knobs { pub alpha: f64, pub beta: f64 }\n",
                ),
                (
                    "sim/bad.rs",
                    "fn build() -> Knobs { Knobs { alpha: 1.0 } }\n",
                ),
            ],
        },
        Fixture {
            name: "clean_tree",
            pass: "",
            expect: "",
            files: &[(
                "planner/good.rs",
                "use crate::heuristics::tiles::DecodeShape;\n\
                 pub struct P { pub splits: usize }\n\
                 // pallas-lint: no_alloc\n\
                 pub fn hot(p: &mut P) { p.splits += 1; }\n\
                 pub fn make() -> P { P { splits: 1 } }\n",
            )],
        },
    ]
}

/// The bench-manifest seeded violation (different input shape from the
/// source fixtures, so it gets its own constructor).
pub fn bench_fixture() -> bench_manifest::BenchManifestInputs {
    bench_manifest::BenchManifestInputs {
        bench_jsons: vec![("BENCH_orphan.json".to_string(), "{\"measured\": true}".to_string())],
        bench_sources: vec![],
        experiments_md: String::new(),
        ci_yaml: String::new(),
    }
}

/// Run the whole corpus. Appends one meta-finding (pass `fixtures`) per
/// violated expectation and returns the number of fixtures checked.
pub fn verify(findings: &mut Vec<Finding>) -> usize {
    let mut checked = 0usize;
    for fx in corpus() {
        checked += 1;
        let set = SourceSet::from_files(fx.files);
        let mut got = Vec::new();
        run_source_passes(&set, &mut got);
        check_expectation(fx.name, fx.pass, fx.expect, &got, findings);
    }

    checked += 1;
    let mut got = Vec::new();
    bench_manifest::check(&bench_fixture(), &mut got);
    check_expectation(
        "bench_manifest_orphan",
        "bench_manifest",
        "orphaned target file",
        &got,
        findings,
    );
    checked
}

fn check_expectation(
    name: &str,
    pass: &str,
    expect: &str,
    got: &[Finding],
    findings: &mut Vec<Finding>,
) {
    let fail = |msg: String| Finding::error("fixtures", format!("fixture:{name}"), 0, msg);
    if pass.is_empty() {
        if !got.is_empty() {
            findings.push(fail(format!(
                "clean fixture produced {} finding(s), first: {}",
                got.len(),
                got[0].render()
            )));
        }
        return;
    }
    let (hits, others): (Vec<&Finding>, Vec<&Finding>) =
        got.iter().partition(|f| f.pass == pass);
    if !hits.iter().any(|f| f.message.contains(expect)) {
        findings.push(fail(format!(
            "expected a `{pass}` finding containing {expect:?}; got {} finding(s) \
             from that pass",
            hits.len()
        )));
    }
    if let Some(stray) = others.first() {
        findings.push(fail(format!(
            "unrelated pass fired on this fixture (false positive): {}",
            stray.render()
        )));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_fixture_meets_its_expectation() {
        let mut findings = Vec::new();
        let checked = verify(&mut findings);
        assert_eq!(checked, corpus().len() + 1);
        assert!(findings.is_empty(), "{findings:#?}");
    }

    #[test]
    fn a_broken_expectation_is_reported() {
        // Sanity for the harness itself: a clean tree checked against a
        // wrong expectation must produce a fixtures finding.
        let mut findings = Vec::new();
        check_expectation("bogus", "layering", "never appears", &[], &mut findings);
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("expected a `layering` finding"));
    }
}
