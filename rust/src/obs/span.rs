//! Per-request span timelines reconstructed from the event ring.
//!
//! The recorder stores flat lifecycle transitions; this module folds them
//! back into one [`RequestSpan`] per request (queued → admitted → prefill
//! chunks → first token → finished/cancelled). Span-derived TTFT/TPOT use
//! *exactly* the arithmetic of `coordinator::RequestTiming`, so a span
//! timeline and the engine metrics agree to the microsecond — that
//! equivalence is a tested acceptance criterion, not an aspiration.
//!
//! Reconstruction is export-time code: it allocates freely and tolerates
//! truncated histories (a wrapped ring may have lost a request's early
//! events; such spans simply have `None` for the lost timestamps).

use std::collections::HashMap;

use super::event::{EventKind, Phase, ReqId, TraceEvent};

/// One request's reconstructed timeline. All timestamps are the engine
/// clock in µs; `None` means the event fell out of the ring (or never
/// happened — a cancelled request has no `finished_us`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequestSpan {
    pub request: ReqId,
    /// Batch slot, once admitted.
    pub slot: Option<u32>,
    pub queued_us: Option<u64>,
    pub admitted_us: Option<u64>,
    pub first_token_us: Option<u64>,
    pub finished_us: Option<u64>,
    pub cancelled_us: Option<u64>,
    /// Output tokens at completion (from the `Finished` event).
    pub n_generated: u32,
    /// Prefill chunks ingested (chunked-prefill runs only).
    pub chunks: u32,
    /// Prompt tokens served by the prefix cache at admission.
    pub cached_prompt_tokens: u32,
    /// This request triggered a copy-on-write fork at first divergence.
    pub cow_forked: bool,
}

impl RequestSpan {
    /// Time to first token from arrival (matches
    /// `RequestTiming::ttft_us`). `None` until both endpoints are known.
    pub fn ttft_us(&self) -> Option<u64> {
        Some(self.first_token_us?.saturating_sub(self.queued_us?))
    }

    /// Time per output token after the first (matches
    /// `RequestTiming::tpot_us`): zero if fewer than 2 tokens.
    pub fn tpot_us(&self) -> Option<f64> {
        let (first, done) = (self.first_token_us?, self.finished_us?);
        if self.n_generated < 2 {
            return Some(0.0);
        }
        Some(done.saturating_sub(first) as f64 / (self.n_generated - 1) as f64)
    }

    /// Queueing delay before entering the running batch.
    pub fn queue_us(&self) -> Option<u64> {
        Some(self.admitted_us?.saturating_sub(self.queued_us?))
    }

    /// End-to-end latency from arrival to completion.
    pub fn e2e_us(&self) -> Option<u64> {
        Some(self.finished_us?.saturating_sub(self.queued_us?))
    }

    /// True when the request ran to natural completion.
    pub fn finished(&self) -> bool {
        self.finished_us.is_some()
    }
}

/// Fold an event stream (oldest → newest) into per-request spans, in
/// order of first appearance. Non-lifecycle events that carry a request
/// id (chunks, prefix probes, COW forks) enrich the span they belong to.
pub fn reconstruct<'a>(events: impl Iterator<Item = &'a TraceEvent>) -> Vec<RequestSpan> {
    let mut spans: Vec<RequestSpan> = Vec::new();
    let mut index: HashMap<ReqId, usize> = HashMap::new();
    let mut span_for = |spans: &mut Vec<RequestSpan>, id: ReqId| -> usize {
        *index.entry(id).or_insert_with(|| {
            spans.push(RequestSpan { request: id, ..RequestSpan::default() });
            spans.len() - 1
        })
    };
    for ev in events {
        match ev.kind {
            EventKind::Lifecycle { request, phase } => {
                let i = span_for(&mut spans, request);
                let s = &mut spans[i];
                match phase {
                    Phase::Queued => s.queued_us = Some(ev.t_us),
                    Phase::Admitted { slot } => {
                        s.admitted_us = Some(ev.t_us);
                        s.slot = Some(slot);
                    }
                    Phase::FirstToken => s.first_token_us = Some(ev.t_us),
                    Phase::Finished { n_generated } => {
                        s.finished_us = Some(ev.t_us);
                        s.n_generated = n_generated;
                    }
                    Phase::Cancelled => s.cancelled_us = Some(ev.t_us),
                }
            }
            EventKind::ChunkIngested { request, .. } => {
                let i = span_for(&mut spans, request);
                spans[i].chunks += 1;
            }
            EventKind::PrefixProbe { request, hit_tokens, .. } => {
                let i = span_for(&mut spans, request);
                spans[i].cached_prompt_tokens = hit_tokens;
            }
            EventKind::KvCowFork { request } => {
                let i = span_for(&mut spans, request);
                spans[i].cow_forked = true;
            }
            _ => {}
        }
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lc(t: u64, request: ReqId, phase: Phase) -> TraceEvent {
        TraceEvent { t_us: t, kind: EventKind::Lifecycle { request, phase } }
    }

    #[test]
    fn full_lifecycle_reconstructs() {
        let events = [
            lc(100, 7, Phase::Queued),
            lc(150, 7, Phase::Admitted { slot: 2 }),
            TraceEvent {
                t_us: 200,
                kind: EventKind::ChunkIngested { request: 7, slot: 2, start: 0, len: 128 },
            },
            TraceEvent {
                t_us: 210,
                kind: EventKind::ChunkIngested { request: 7, slot: 2, start: 128, len: 64 },
            },
            lc(400, 7, Phase::FirstToken),
            lc(1400, 7, Phase::Finished { n_generated: 11 }),
        ];
        let spans = reconstruct(events.iter());
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!(s.request, 7);
        assert_eq!(s.slot, Some(2));
        assert_eq!(s.chunks, 2);
        // Matches RequestTiming on the same numbers (timing_derivations
        // test in coordinator/metrics.rs).
        assert_eq!(s.ttft_us(), Some(300));
        assert_eq!(s.queue_us(), Some(50));
        assert_eq!(s.e2e_us(), Some(1300));
        assert!((s.tpot_us().unwrap() - 100.0).abs() < 1e-9);
        assert!(s.finished());
    }

    #[test]
    fn interleaved_requests_separate() {
        let events = [
            lc(0, 1, Phase::Queued),
            lc(5, 2, Phase::Queued),
            lc(10, 2, Phase::Admitted { slot: 0 }),
            lc(20, 1, Phase::Admitted { slot: 1 }),
            lc(30, 2, Phase::Cancelled),
        ];
        let spans = reconstruct(events.iter());
        assert_eq!(spans.len(), 2);
        // Order of first appearance.
        assert_eq!(spans[0].request, 1);
        assert_eq!(spans[1].request, 2);
        assert_eq!(spans[1].cancelled_us, Some(30));
        assert!(!spans[1].finished());
        assert_eq!(spans[0].slot, Some(1));
    }

    #[test]
    fn truncated_history_yields_partial_span() {
        // Ring wrapped: the Queued/Admitted events are gone.
        let events = [lc(400, 9, Phase::FirstToken), lc(900, 9, Phase::Finished { n_generated: 6 })];
        let spans = reconstruct(events.iter());
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].queued_us, None);
        assert_eq!(spans[0].ttft_us(), None);
        assert!((spans[0].tpot_us().unwrap() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn single_token_tpot_is_zero() {
        let events = [
            lc(0, 1, Phase::Queued),
            lc(10, 1, Phase::FirstToken),
            lc(10, 1, Phase::Finished { n_generated: 1 }),
        ];
        let spans = reconstruct(events.iter());
        assert_eq!(spans[0].tpot_us(), Some(0.0));
    }
}
