//! Histogram-capable metrics registry with Prometheus text exposition.
//!
//! Instruments are registered once at setup time (names and label sets
//! are rendered to their final exposition strings *then*), handed back as
//! index handles ([`CounterId`] / [`GaugeId`] / [`HistId`]), and updated
//! through the handles on the hot path — `inc` / `set` / `observe` are
//! array indexing plus arithmetic, no hashing, no strings, no heap. The
//! step loop records from inside `no_alloc` regions; exposition
//! ([`MetricsRegistry::render`]) is export-time code and allocates
//! freely.
//!
//! The exposition format follows the Prometheus text format (0.0.4):
//! `# HELP` / `# TYPE` headers, cumulative `_bucket{le="…"}` series with
//! a terminal `+Inf` bucket, `_sum` and `_count`.

use crate::util::stats::Histogram;

/// Handle to a registered monotonic counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterId(usize);

/// Handle to a registered gauge (a settable level).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaugeId(usize);

/// Handle to a registered histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistId(usize);

#[derive(Debug, Clone)]
struct Series {
    /// Metric family name (`fa3_steps_total`).
    name: String,
    /// Help line for the family header.
    help: String,
    /// Pre-rendered `{label="v",…}` suffix ("" when unlabeled).
    labels: String,
}

impl Series {
    fn new(name: &str, help: &str, labels: &[(&str, &str)]) -> Series {
        let rendered = if labels.is_empty() {
            String::new()
        } else {
            // Sort by key so the exposition is deterministic regardless
            // of registration order.
            let mut body: Vec<String> =
                labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
            body.sort();
            format!("{{{}}}", body.join(","))
        };
        Series { name: name.to_string(), help: help.to_string(), labels: rendered }
    }
}

#[derive(Debug, Clone)]
struct CounterSlot {
    series: Series,
    value: u64,
}

#[derive(Debug, Clone)]
struct GaugeSlot {
    series: Series,
    value: f64,
}

#[derive(Debug, Clone)]
struct HistSlot {
    series: Series,
    hist: Histogram,
}

/// The registry: owns every instrument, renders the exposition snapshot.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: Vec<CounterSlot>,
    gauges: Vec<GaugeSlot>,
    hists: Vec<HistSlot>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Register a counter. Setup-time only; label values are rendered
    /// here so hot-path updates never touch strings.
    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)]) -> CounterId {
        self.counters.push(CounterSlot { series: Series::new(name, help, labels), value: 0 });
        CounterId(self.counters.len() - 1)
    }

    /// Register a gauge.
    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)]) -> GaugeId {
        self.gauges.push(GaugeSlot { series: Series::new(name, help, labels), value: 0.0 });
        GaugeId(self.gauges.len() - 1)
    }

    /// Register a histogram over a pre-built bucket layout.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        hist: Histogram,
    ) -> HistId {
        self.hists.push(HistSlot { series: Series::new(name, help, labels), hist });
        HistId(self.hists.len() - 1)
    }

    /// Add to a counter. Hot-path safe.
    // pallas-lint: no_alloc
    #[inline]
    pub fn inc(&mut self, id: CounterId, by: u64) {
        self.counters[id.0].value += by;
    }

    /// Overwrite a counter with an externally-tracked total (the
    /// mirror-by-copy discipline: `EngineMetrics` keeps its public
    /// counter fields as the source of truth and syncs them into the
    /// registry at exposition time).
    pub fn set_counter(&mut self, id: CounterId, value: u64) {
        self.counters[id.0].value = value;
    }

    /// Set a gauge level. Hot-path safe.
    // pallas-lint: no_alloc
    #[inline]
    pub fn set(&mut self, id: GaugeId, value: f64) {
        self.gauges[id.0].value = value;
    }

    /// Record one histogram observation. Hot-path safe.
    // pallas-lint: no_alloc
    #[inline]
    pub fn observe(&mut self, id: HistId, value: f64) {
        self.hists[id.0].hist.observe(value);
    }

    /// Read a counter's current value.
    pub fn counter_value(&self, id: CounterId) -> u64 {
        self.counters[id.0].value
    }

    /// Read a histogram (tests and report paths).
    pub fn hist(&self, id: HistId) -> &Histogram {
        &self.hists[id.0].hist
    }

    /// Render the Prometheus text exposition of every instrument.
    ///
    /// Families sharing a name emit their `# HELP`/`# TYPE` header once
    /// (labeled series of one family are registered consecutively).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut last_header = String::new();
        for c in &self.counters {
            push_header(&mut out, &mut last_header, &c.series, "counter");
            out.push_str(&format!("{}{} {}\n", c.series.name, c.series.labels, c.value));
        }
        for g in &self.gauges {
            push_header(&mut out, &mut last_header, &g.series, "gauge");
            out.push_str(&format!("{}{} {}\n", g.series.name, g.series.labels, fmt_f64(g.value)));
        }
        for h in &self.hists {
            push_header(&mut out, &mut last_header, &h.series, "histogram");
            let base = h.series.labels.trim_start_matches('{').trim_end_matches('}');
            let with_le = |le: &str| {
                if base.is_empty() {
                    format!("{{le=\"{le}\"}}")
                } else {
                    format!("{{{base},le=\"{le}\"}}")
                }
            };
            let mut cum = 0u64;
            for (i, count) in h.hist.counts().iter().enumerate() {
                cum += count;
                let le = if i == h.hist.bounds().len() {
                    "+Inf".to_string()
                } else {
                    fmt_f64(h.hist.bounds()[i])
                };
                out.push_str(&format!("{}_bucket{} {}\n", h.series.name, with_le(&le), cum));
            }
            out.push_str(&format!(
                "{}_sum{} {}\n",
                h.series.name,
                h.series.labels,
                fmt_f64(h.hist.sum())
            ));
            out.push_str(&format!("{}_count{} {}\n", h.series.name, h.series.labels, h.hist.count()));
        }
        out
    }
}

/// Emit the `# HELP`/`# TYPE` header once per metric family (labeled
/// series of one family are registered consecutively).
fn push_header(out: &mut String, last: &mut String, s: &Series, kind: &str) {
    if *last != s.name {
        out.push_str(&format!("# HELP {} {}\n# TYPE {} {}\n", s.name, s.help, s.name, kind));
        last.clear();
        last.push_str(&s.name);
    }
}

/// Prometheus-friendly float rendering: integral values lose the
/// trailing `.0` (matches bucket `le` conventions like `le="128"`).
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_render() {
        let mut r = MetricsRegistry::new();
        let c = r.counter("fa3_steps_total", "Engine steps executed.", &[]);
        let g = r.gauge("fa3_kv_used_blocks", "KV blocks in use.", &[("replica", "0")]);
        r.inc(c, 3);
        r.inc(c, 2);
        r.set(g, 17.0);
        assert_eq!(r.counter_value(c), 5);
        let text = r.render();
        assert!(text.contains("# TYPE fa3_steps_total counter"), "{text}");
        assert!(text.contains("fa3_steps_total 5\n"), "{text}");
        assert!(text.contains("fa3_kv_used_blocks{replica=\"0\"} 17\n"), "{text}");
    }

    #[test]
    fn histogram_renders_cumulative_buckets() {
        let mut r = MetricsRegistry::new();
        let h = r.histogram(
            "fa3_occupancy",
            "Planned first-wave SM occupancy.",
            &[("policy", "sequence-aware")],
            Histogram::new(vec![0.25, 0.5, 1.0]),
        );
        for v in [0.1, 0.2, 0.4, 0.9] {
            r.observe(h, v);
        }
        let text = r.render();
        assert!(text.contains("# TYPE fa3_occupancy histogram"), "{text}");
        assert!(text.contains("fa3_occupancy_bucket{policy=\"sequence-aware\",le=\"0.25\"} 2\n"));
        assert!(text.contains("fa3_occupancy_bucket{policy=\"sequence-aware\",le=\"0.5\"} 3\n"));
        assert!(text.contains("fa3_occupancy_bucket{policy=\"sequence-aware\",le=\"1\"} 4\n"));
        assert!(text.contains("fa3_occupancy_bucket{policy=\"sequence-aware\",le=\"+Inf\"} 4\n"));
        assert!(text.contains("fa3_occupancy_count{policy=\"sequence-aware\"} 4\n"));
        assert!(text.contains("fa3_occupancy_sum{policy=\"sequence-aware\"} 1.6"), "{text}");
    }

    #[test]
    fn unlabeled_histogram_gets_bare_le_braces() {
        let mut r = MetricsRegistry::new();
        let h = r.histogram("fa3_step_us", "Step latency µs.", &[], Histogram::linear(0.0, 10.0, 2));
        r.observe(h, 5.0);
        let text = r.render();
        assert!(text.contains("fa3_step_us_bucket{le=\"10\"} 1\n"), "{text}");
        assert!(text.contains("fa3_step_us_bucket{le=\"+Inf\"} 1\n"), "{text}");
    }

    #[test]
    fn shared_family_header_renders_once() {
        let mut r = MetricsRegistry::new();
        let a = r.counter("fa3_rejects_total", "Rejected submissions.", &[("kind", "backpressure")]);
        let b = r.counter("fa3_rejects_total", "Rejected submissions.", &[("kind", "unschedulable")]);
        r.inc(a, 1);
        r.inc(b, 2);
        let text = r.render();
        assert_eq!(text.matches("# TYPE fa3_rejects_total counter").count(), 1, "{text}");
        assert!(text.contains("fa3_rejects_total{kind=\"backpressure\"} 1\n"));
        assert!(text.contains("fa3_rejects_total{kind=\"unschedulable\"} 2\n"));
    }

    #[test]
    fn set_counter_mirrors_external_totals() {
        let mut r = MetricsRegistry::new();
        let c = r.counter("fa3_tokens_total", "Tokens generated.", &[]);
        r.set_counter(c, 41);
        r.set_counter(c, 42);
        assert_eq!(r.counter_value(c), 42);
    }
}
