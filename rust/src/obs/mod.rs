//! Observability: flight-recorder tracing, span timelines, and metrics
//! exposition.
//!
//! The paper's claim is an *occupancy* story — the FA-3 heuristic
//! strands SMs in low-head-count decode — and aggregate means can't tell
//! you **which** steps, shapes, or split decisions produced a win. This
//! module captures that per-decision granularity without giving up the
//! engine's zero-allocation steady state:
//!
//! * [`FlightRecorder`] — a fixed-capacity, overwrite-oldest ring
//!   ([`EventRing`]) of compact `Copy` [`TraceEvent`]s stamped with the
//!   engine's virtual clock. Recording is a branch plus a store; when the
//!   ring is full the oldest event is replaced and a drop counter keeps
//!   the loss honest. It never blocks the step loop.
//! * [`span`] — per-request timelines (queued → admitted → chunks →
//!   first token → finished) folded back out of the ring; span TTFT/TPOT
//!   reproduce `coordinator::RequestTiming` exactly.
//! * [`chrome`] — a Chrome trace-event JSON exporter (one track per
//!   batch slot, one process per fleet replica, counter tracks for SM
//!   occupancy / KV pressure / queue depth) that opens directly in
//!   `chrome://tracing` or Perfetto.
//! * [`MetricsRegistry`] — pre-registered counters/gauges/histograms
//!   (storage is `util::stats::Histogram`) with hot-path updates by index
//!   handle and a Prometheus text exposition; `EngineMetrics` records its
//!   occupancy and latency distributions through it.
//!
//! Layering: `obs` depends only on `util` (everything above may depend
//! on `obs`) — enforced by pallas-lint's layering pass.
//!
//! See `docs/observability.md` for the event schema and exporter formats.

pub mod chrome;
pub mod event;
pub mod recorder;
pub mod registry;
pub mod ring;
pub mod span;

pub use chrome::{engine_trace, fleet_trace, fleet_trace_string, ReplicaTrace};
pub use event::{
    CursorOutcome, EventKind, Phase, PolicyId, PreemptClass, ReqId, StepClass, TraceEvent, WaveKind,
};
pub use recorder::FlightRecorder;
pub use registry::{CounterId, GaugeId, HistId, MetricsRegistry};
pub use ring::EventRing;
pub use span::{reconstruct, RequestSpan};
