//! Chrome trace-event JSON exporter.
//!
//! Renders one or many flight recorders into the Trace Event Format that
//! `chrome://tracing` and Perfetto open directly (JSON object form,
//! `traceEvents` array). Track layout:
//!
//! * **pid** = replica index (one process group per replica; its label
//!   names the device).
//! * **tid 0** = the engine track: step composition instants, plan
//!   decisions, evictions, admission rejects.
//! * **tid `slot + 1`** = one track per batch slot: a `wait` span
//!   (queued → admitted), the request's residency span (admitted →
//!   finished/cancelled), first-token instants, chunk-ingest instants.
//! * **Counter tracks** (`ph: "C"`): planned SM occupancy per wave kind,
//!   KV-block pressure, and admission queue depth.
//!
//! Timestamps pass through unscaled: the engine clock is already µs,
//! which is exactly the unit the trace format expects.

use crate::util::json::Json;

use super::event::{EventKind, WaveKind};
use super::recorder::FlightRecorder;
use super::span;

/// One replica's contribution to a merged fleet trace.
pub struct ReplicaTrace<'a> {
    /// Process id in the trace (the fleet replica index).
    pub pid: u32,
    /// Process label (e.g. `"replica 0 (h100-sxm)"`).
    pub name: String,
    pub recorder: &'a FlightRecorder,
}

/// Export a standalone engine's recorder (single-process trace).
pub fn engine_trace(recorder: &FlightRecorder, name: &str) -> Json {
    fleet_trace(&[ReplicaTrace { pid: recorder.replica(), name: name.to_string(), recorder }])
}

/// Export one merged trace over any number of replica recorders.
pub fn fleet_trace(replicas: &[ReplicaTrace]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let mut total_dropped = 0u64;
    for r in replicas {
        total_dropped += r.recorder.dropped();
        emit_replica(r, &mut events);
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("generator", Json::str("fa3-split flight recorder")),
                ("dropped_events", Json::int(total_dropped as i64)),
            ]),
        ),
    ])
}

/// `fleet_trace` rendered to a compact JSON string (what `--trace-out`
/// writes).
pub fn fleet_trace_string(replicas: &[ReplicaTrace]) -> String {
    fleet_trace(replicas).to_string()
}

fn meta(pid: u32, tid: u32, what: &str, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", Json::int(pid as i64)),
        ("tid", Json::int(tid as i64)),
        ("name", Json::str(what)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

fn instant(pid: u32, tid: u32, ts: u64, name: &str, args: Vec<(&str, Json)>) -> Json {
    Json::obj(vec![
        ("ph", Json::str("i")),
        ("s", Json::str("t")),
        ("pid", Json::int(pid as i64)),
        ("tid", Json::int(tid as i64)),
        ("ts", Json::int(ts as i64)),
        ("name", Json::str(name)),
        ("args", Json::obj(args)),
    ])
}

fn complete(pid: u32, tid: u32, ts: u64, dur: u64, name: &str, args: Vec<(&str, Json)>) -> Json {
    Json::obj(vec![
        ("ph", Json::str("X")),
        ("pid", Json::int(pid as i64)),
        ("tid", Json::int(tid as i64)),
        ("ts", Json::int(ts as i64)),
        ("dur", Json::int(dur as i64)),
        ("name", Json::str(name)),
        ("args", Json::obj(args)),
    ])
}

fn counter(pid: u32, ts: u64, name: &str, series: &str, value: f64) -> Json {
    Json::obj(vec![
        ("ph", Json::str("C")),
        ("pid", Json::int(pid as i64)),
        ("ts", Json::int(ts as i64)),
        ("name", Json::str(name)),
        ("args", Json::obj(vec![(series, Json::num(value))])),
    ])
}

fn emit_replica(r: &ReplicaTrace, out: &mut Vec<Json>) {
    let rec = r.recorder;
    let pid = r.pid;
    out.push(meta(pid, 0, "process_name", &r.name));
    out.push(meta(pid, 0, "thread_name", "engine"));

    // Per-slot request spans first (they also tell us which slot tracks
    // exist and need thread_name metadata).
    let spans = span::reconstruct(rec.events());
    let mut max_slot: Option<u32> = None;
    for s in &spans {
        let Some(slot) = s.slot else { continue };
        max_slot = Some(max_slot.map_or(slot, |m| m.max(slot)));
        let tid = slot + 1;
        if let (Some(q), Some(a)) = (s.queued_us, s.admitted_us) {
            out.push(complete(pid, tid, q, a.saturating_sub(q), "wait", vec![]));
        }
        let end = s.finished_us.or(s.cancelled_us);
        if let (Some(a), Some(e)) = (s.admitted_us, end) {
            let mut args = vec![
                ("chunks", Json::int(s.chunks as i64)),
                ("cached_prompt_tokens", Json::int(s.cached_prompt_tokens as i64)),
                ("n_generated", Json::int(s.n_generated as i64)),
                ("outcome", Json::str(if s.finished() { "finished" } else { "cancelled" })),
            ];
            if let Some(ttft) = s.ttft_us() {
                args.push(("ttft_us", Json::int(ttft as i64)));
            }
            if let Some(tpot) = s.tpot_us() {
                args.push(("tpot_us", Json::num(tpot)));
            }
            out.push(complete(pid, tid, a, e.saturating_sub(a), &format!("req {}", s.request), args));
        }
        if let Some(ft) = s.first_token_us {
            out.push(instant(pid, tid, ft, "first token", vec![]));
        }
    }
    if let Some(m) = max_slot {
        for slot in 0..=m {
            out.push(meta(pid, slot + 1, "thread_name", &format!("slot {slot}")));
        }
    }

    // Engine-track instants and counter samples, in ring order.
    for ev in rec.events() {
        match ev.kind {
            EventKind::StepComposed { class, chunk_rows, decode_rows, kv_used_blocks, queue_depth, .. } => {
                out.push(counter(pid, ev.t_us, "kv used blocks", "blocks", kv_used_blocks as f64));
                out.push(counter(pid, ev.t_us, "queue depth", "requests", queue_depth as f64));
                out.push(instant(
                    pid,
                    0,
                    ev.t_us,
                    &format!("step:{}", class.label()),
                    vec![
                        ("chunk_rows", Json::int(chunk_rows as i64)),
                        ("decode_rows", Json::int(decode_rows as i64)),
                    ],
                ));
            }
            EventKind::PlanDecision { wave, policy, num_splits, occupancy, batch, max_kv, cursor } => {
                let series = wave.label();
                out.push(counter(pid, ev.t_us, "sm occupancy", series, occupancy as f64));
                out.push(instant(
                    pid,
                    0,
                    ev.t_us,
                    &format!("plan:{series}"),
                    vec![
                        ("policy", Json::str(rec.policy_name(policy))),
                        ("splits", Json::int(num_splits as i64)),
                        ("occupancy", Json::num(occupancy as f64)),
                        ("batch", Json::int(batch as i64)),
                        ("max_kv", Json::int(max_kv as i64)),
                        (
                            "cursor",
                            Json::str(match cursor {
                                super::event::CursorOutcome::Hit => "hit",
                                super::event::CursorOutcome::Refill => "refill",
                            }),
                        ),
                    ],
                ));
            }
            EventKind::WaveCost { wave, rows, elapsed_us } => {
                let name = match wave {
                    WaveKind::Decode => "decode wave µs",
                    WaveKind::Chunk => "chunk wave µs",
                };
                out.push(counter(pid, ev.t_us, name, "us", elapsed_us as f64));
                let _ = rows;
            }
            EventKind::KvEvict { blocks } => {
                out.push(instant(pid, 0, ev.t_us, "kv evict", vec![("blocks", Json::int(blocks as i64))]));
            }
            EventKind::AdmissionReject { class, backpressure } => {
                out.push(instant(
                    pid,
                    0,
                    ev.t_us,
                    "admission reject",
                    vec![
                        ("class", Json::int(class as i64)),
                        ("backpressure", Json::Bool(backpressure)),
                    ],
                ));
            }
            EventKind::ChunkIngested { request, slot, start, len } => {
                out.push(instant(
                    pid,
                    slot + 1,
                    ev.t_us,
                    "chunk",
                    vec![
                        ("request", Json::int(request as i64)),
                        ("start", Json::int(start as i64)),
                        ("len", Json::int(len as i64)),
                    ],
                ));
            }
            EventKind::Preempt { request, slot, blocks, kind } => {
                out.push(instant(
                    pid,
                    slot + 1,
                    ev.t_us,
                    "preempt",
                    vec![
                        ("request", Json::int(request as i64)),
                        ("blocks", Json::int(blocks as i64)),
                        ("kind", Json::str(kind.label())),
                    ],
                ));
            }
            EventKind::Resume { request, slot, kind } => {
                out.push(instant(
                    pid,
                    slot + 1,
                    ev.t_us,
                    "resume",
                    vec![
                        ("request", Json::int(request as i64)),
                        ("kind", Json::str(kind.label())),
                    ],
                ));
            }
            EventKind::Shed { request, class, waited_us } => {
                out.push(instant(
                    pid,
                    0,
                    ev.t_us,
                    "shed",
                    vec![
                        ("request", Json::int(request as i64)),
                        ("class", Json::int(class as i64)),
                        ("waited_us", Json::int(waited_us as i64)),
                    ],
                ));
            }
            EventKind::KvHandoff { request, blocks, wire_us } => {
                out.push(instant(
                    pid,
                    0,
                    ev.t_us,
                    "kv handoff",
                    vec![
                        ("request", Json::int(request as i64)),
                        ("blocks", Json::int(blocks as i64)),
                        ("wire_us", Json::int(wire_us as i64)),
                    ],
                ));
            }
            // Lifecycle / KvAdmit / KvCowFork / PrefixProbe are consumed
            // through the span reconstruction above.
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::{CursorOutcome, Phase as P, PolicyId, StepClass};

    fn sample_recorder() -> FlightRecorder {
        let mut rec = FlightRecorder::with_capacity(64);
        let policy = rec.intern_policy("sequence-aware");
        rec.record(0, EventKind::Lifecycle { request: 1, phase: P::Queued });
        rec.record(10, EventKind::Lifecycle { request: 1, phase: P::Admitted { slot: 0 } });
        rec.record(
            12,
            EventKind::StepComposed {
                class: StepClass::Decode,
                chunk_rows: 0,
                decode_rows: 1,
                step_tokens: 1,
                kv_used_blocks: 4,
                queue_depth: 2,
            },
        );
        rec.record(
            12,
            EventKind::PlanDecision {
                wave: WaveKind::Decode,
                policy,
                batch: 1,
                max_kv: 512,
                num_splits: 3,
                occupancy: 0.18,
                cursor: CursorOutcome::Refill,
            },
        );
        rec.record(40, EventKind::Lifecycle { request: 1, phase: P::FirstToken });
        rec.record(140, EventKind::Lifecycle { request: 1, phase: P::Finished { n_generated: 11 } });
        rec
    }

    #[test]
    fn trace_is_valid_json_with_expected_tracks() {
        let rec = sample_recorder();
        let text = fleet_trace_string(&[ReplicaTrace {
            pid: 0,
            name: "replica 0 (h100-sxm)".to_string(),
            recorder: &rec,
        }]);
        let parsed = Json::parse(&text).expect("exporter must emit valid JSON");
        let events = parsed.get("traceEvents").as_arr().unwrap();
        assert!(!events.is_empty());
        // Every event carries the mandatory fields.
        for ev in events {
            assert!(ev.get("ph").as_str().is_some(), "{ev:?}");
            assert!(ev.get("pid").as_i64().is_some(), "{ev:?}");
        }
        // Process metadata, slot track, occupancy counter, request span.
        let phs: Vec<&str> = events.iter().filter_map(|e| e.get("ph").as_str()).collect();
        assert!(phs.contains(&"M"));
        assert!(phs.contains(&"X"));
        assert!(phs.contains(&"C"));
        let names: Vec<&str> = events.iter().filter_map(|e| e.get("name").as_str()).collect();
        assert!(names.contains(&"sm occupancy"), "{names:?}");
        assert!(names.contains(&"kv used blocks"), "{names:?}");
        assert!(names.contains(&"req 1"), "{names:?}");
        assert!(names.contains(&"slot 0"), "{names:?}");
        assert_eq!(parsed.get("otherData").get("dropped_events").as_i64(), Some(0));
    }

    #[test]
    fn span_args_carry_ttft_and_tpot() {
        let rec = sample_recorder();
        let trace = engine_trace(&rec, "engine");
        let events = trace.get("traceEvents").as_arr().unwrap();
        let req = events.iter().find(|e| e.get("name").as_str() == Some("req 1")).unwrap();
        assert_eq!(req.get("args").get("ttft_us").as_i64(), Some(40));
        assert!((req.get("args").get("tpot_us").as_f64().unwrap() - 10.0).abs() < 1e-9);
        assert_eq!(req.get("ts").as_i64(), Some(10));
        assert_eq!(req.get("dur").as_i64(), Some(130));
    }

    #[test]
    fn plan_decisions_resolve_policy_names() {
        let rec = sample_recorder();
        let trace = engine_trace(&rec, "engine");
        let events = trace.get("traceEvents").as_arr().unwrap();
        let plan = events.iter().find(|e| e.get("name").as_str() == Some("plan:decode")).unwrap();
        assert_eq!(plan.get("args").get("policy").as_str(), Some("sequence-aware"));
        assert_eq!(plan.get("args").get("splits").as_i64(), Some(3));
        assert_eq!(plan.get("args").get("cursor").as_str(), Some("refill"));
    }

    #[test]
    fn merged_fleet_trace_separates_pids() {
        let a = sample_recorder();
        let b = sample_recorder();
        let trace = fleet_trace(&[
            ReplicaTrace { pid: 0, name: "replica 0".to_string(), recorder: &a },
            ReplicaTrace { pid: 1, name: "replica 1".to_string(), recorder: &b },
        ]);
        let events = trace.get("traceEvents").as_arr().unwrap();
        let pids: std::collections::BTreeSet<i64> =
            events.iter().filter_map(|e| e.get("pid").as_i64()).collect();
        assert_eq!(pids.into_iter().collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn unused_phase_variants_do_not_leak_to_engine_track() {
        // Lifecycle events are folded into spans, not duplicated as
        // engine-track instants.
        let mut rec = FlightRecorder::with_capacity(8);
        rec.record(0, EventKind::Lifecycle { request: 5, phase: P::Queued });
        let trace = engine_trace(&rec, "engine");
        let events = trace.get("traceEvents").as_arr().unwrap();
        // Only the two metadata records: a queued-only span emits nothing.
        assert!(events.iter().all(|e| e.get("ph").as_str() == Some("M")), "{events:?}");
    }
}
