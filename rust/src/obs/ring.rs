//! Fixed-capacity event ring: overwrite-oldest, never blocks, never grows.
//!
//! The recorder's storage discipline mirrors hardware trace units
//! (flight recorders): the buffer is sized once, the hot-path `push` is a
//! store plus two index updates, and when the ring is full the *oldest*
//! event is overwritten and a drop counter increments. Keeping the most
//! recent window (rather than refusing new events) is the right bias for
//! postmortems — the interesting steps are the ones just before you
//! stopped the run — and the drop counter keeps the loss honest in every
//! export.

use super::event::TraceEvent;

/// Overwrite-oldest ring of [`TraceEvent`]s. Capacity 0 = recording
/// disabled (every push counts as dropped, nothing is stored).
#[derive(Debug, Clone)]
pub struct EventRing {
    /// Backing store; grows by `push` only up to the pre-reserved
    /// capacity, then is overwritten in place.
    buf: Vec<TraceEvent>,
    /// Index of the oldest event once the ring has wrapped.
    head: usize,
    /// Events lost to overwrite (or to a zero-capacity ring).
    dropped: u64,
    capacity: usize,
}

impl EventRing {
    /// A ring holding at most `capacity` events. The single allocation
    /// happens here; pushes never reallocate.
    pub fn with_capacity(capacity: usize) -> EventRing {
        EventRing { buf: Vec::with_capacity(capacity), head: 0, dropped: 0, capacity }
    }

    /// Record one event.
    ///
    /// Steady-state cost: one bounds-checked store. The `Vec::push` arm
    /// only runs while the ring is filling and stays within the capacity
    /// reserved at construction, so no call ever touches the allocator.
    // pallas-lint: no_alloc
    pub fn push(&mut self, ev: TraceEvent) {
        if self.capacity == 0 {
            self.dropped += 1;
            return;
        }
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head += 1;
            if self.head == self.capacity {
                self.head = 0;
            }
            self.dropped += 1;
        }
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been recorded (or capacity is 0).
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The fixed capacity chosen at construction.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Events lost to overwrite since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Iterate oldest → newest (chronological order even after wrap).
    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }

    /// Forget all events (capacity and allocation are kept).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.dropped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::EventKind;

    fn ev(t: u64) -> TraceEvent {
        TraceEvent { t_us: t, kind: EventKind::KvEvict { blocks: t as u32 } }
    }

    fn times(r: &EventRing) -> Vec<u64> {
        r.iter().map(|e| e.t_us).collect()
    }

    #[test]
    fn fills_then_wraps_keeping_newest() {
        let mut r = EventRing::with_capacity(3);
        for t in 0..3 {
            r.push(ev(t));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 0);
        assert_eq!(times(&r), vec![0, 1, 2]);

        r.push(ev(3)); // overwrites t=0
        r.push(ev(4)); // overwrites t=1
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        assert_eq!(times(&r), vec![2, 3, 4]);
    }

    #[test]
    fn wraps_repeatedly_in_order() {
        let mut r = EventRing::with_capacity(4);
        for t in 0..11 {
            r.push(ev(t));
        }
        assert_eq!(r.dropped(), 7);
        assert_eq!(times(&r), vec![7, 8, 9, 10]);
    }

    #[test]
    fn zero_capacity_drops_everything() {
        let mut r = EventRing::with_capacity(0);
        r.push(ev(1));
        r.push(ev(2));
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 2);
        assert_eq!(times(&r), Vec::<u64>::new());
    }

    #[test]
    fn push_never_exceeds_reserved_capacity() {
        let mut r = EventRing::with_capacity(8);
        let reserved = r.buf.capacity();
        for t in 0..100 {
            r.push(ev(t));
        }
        // The wrap path writes in place: the Vec never regrows.
        assert_eq!(r.buf.capacity(), reserved);
        assert_eq!(r.len(), 8);
    }

    #[test]
    fn clear_resets_but_keeps_allocation() {
        let mut r = EventRing::with_capacity(2);
        r.push(ev(1));
        r.push(ev(2));
        r.push(ev(3));
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
        assert_eq!(r.capacity(), 2);
        r.push(ev(9));
        assert_eq!(times(&r), vec![9]);
    }
}
