//! The flight recorder: the engine-facing capture handle.
//!
//! A [`FlightRecorder`] owns one [`EventRing`] plus the setup-time string
//! interning table (policy names → [`PolicyId`]). The split of
//! responsibilities is strict:
//!
//! * **Setup time** (engine build): `with_capacity`, `intern_policy` —
//!   allocation is fine here.
//! * **Steady state** (inside the step loop's `no_alloc` region):
//!   [`FlightRecorder::record`] — a disabled-check plus a ring store,
//!   nothing else. A disabled recorder costs one branch.
//! * **Export time** (after the run): `events`, the span reconstructor,
//!   and the Chrome exporter read the ring; they may allocate freely.

use super::event::{EventKind, PolicyId, TraceEvent};
use super::ring::EventRing;

/// Per-engine (per-replica, in a fleet) trace capture.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    ring: EventRing,
    /// Interned variable-length strings; index = `PolicyId.0`.
    policies: Vec<String>,
    /// Replica index for fleet exports (0 for a standalone engine).
    replica: u32,
}

impl FlightRecorder {
    /// A recorder that stores nothing (capacity-0 ring). This is the
    /// default for every engine: tracing is strictly opt-in.
    pub fn disabled() -> FlightRecorder {
        FlightRecorder::with_capacity(0)
    }

    /// A recorder with a `capacity`-event ring (0 = disabled).
    pub fn with_capacity(capacity: usize) -> FlightRecorder {
        FlightRecorder { ring: EventRing::with_capacity(capacity), policies: Vec::new(), replica: 0 }
    }

    /// True when events are actually stored.
    pub fn enabled(&self) -> bool {
        self.ring.capacity() > 0
    }

    /// Tag this recorder with its fleet replica index (used as the Chrome
    /// trace `pid`).
    pub fn set_replica(&mut self, replica: u32) {
        self.replica = replica;
    }

    /// The fleet replica index this recorder is tagged with.
    pub fn replica(&self) -> u32 {
        self.replica
    }

    /// Intern a policy (or other label) string, returning its id. Called
    /// at engine build time — repeated names return the existing id.
    pub fn intern_policy(&mut self, name: &str) -> PolicyId {
        if let Some(i) = self.policies.iter().position(|p| p == name) {
            return PolicyId(i as u16);
        }
        assert!(self.policies.len() < u16::MAX as usize, "policy intern table full");
        self.policies.push(name.to_string());
        PolicyId((self.policies.len() - 1) as u16)
    }

    /// Resolve an interned id back to its string (exporters only).
    pub fn policy_name(&self, id: PolicyId) -> &str {
        self.policies.get(id.0 as usize).map(String::as_str).unwrap_or("?")
    }

    /// Record one event at virtual-clock time `t_us`.
    ///
    /// This is the only hot-path entry point: a branch when disabled, a
    /// ring store when enabled. It never blocks and never allocates.
    // pallas-lint: no_alloc
    #[inline]
    pub fn record(&mut self, t_us: u64, kind: EventKind) {
        if self.ring.capacity() == 0 {
            return;
        }
        self.ring.push(TraceEvent { t_us, kind });
    }

    /// Stored events, oldest → newest.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.ring.iter()
    }

    /// Events currently held.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// True when no events are held.
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    /// Events lost to ring overwrite.
    pub fn dropped(&self) -> u64 {
        self.ring.dropped()
    }
}

impl Default for FlightRecorder {
    fn default() -> FlightRecorder {
        FlightRecorder::disabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::{EventKind, Phase};

    #[test]
    fn disabled_recorder_stores_nothing() {
        let mut r = FlightRecorder::disabled();
        assert!(!r.enabled());
        r.record(10, EventKind::KvEvict { blocks: 1 });
        assert!(r.is_empty());
        // Disabled recording isn't data loss — nothing was asked for.
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn records_in_order() {
        let mut r = FlightRecorder::with_capacity(16);
        assert!(r.enabled());
        r.record(5, EventKind::Lifecycle { request: 1, phase: Phase::Queued });
        r.record(9, EventKind::Lifecycle { request: 1, phase: Phase::FirstToken });
        let ts: Vec<u64> = r.events().map(|e| e.t_us).collect();
        assert_eq!(ts, vec![5, 9]);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn interning_is_idempotent() {
        let mut r = FlightRecorder::with_capacity(1);
        let a = r.intern_policy("sequence-aware");
        let b = r.intern_policy("upstream");
        let a2 = r.intern_policy("sequence-aware");
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(r.policy_name(a), "sequence-aware");
        assert_eq!(r.policy_name(b), "upstream");
        assert_eq!(r.policy_name(PolicyId(99)), "?");
    }

    #[test]
    fn replica_tag_round_trips() {
        let mut r = FlightRecorder::with_capacity(1);
        assert_eq!(r.replica(), 0);
        r.set_replica(3);
        assert_eq!(r.replica(), 3);
    }
}
