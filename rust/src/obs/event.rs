//! Compact `Copy` trace events: the flight recorder's wire format.
//!
//! Every event is a fixed-size value — no strings, no heap — so recording
//! one is a couple of stores into the pre-allocated ring. Anything
//! variable-length (policy names, device labels) is interned once at
//! engine build time and referenced here by small integer id
//! ([`PolicyId`]); the exporters resolve ids back to names.
//!
//! Field widths are deliberately narrow (`u32`/`f32`/`u16`) to keep
//! `TraceEvent` small: a 64k-event ring is a few MiB, cheap enough to
//! leave enabled on every replica of a fleet run.

/// Engine-assigned request identifier (mirrors
/// `coordinator::RequestId = u64`; `obs` depends only on `util`, so the
/// alias is restated here rather than imported).
pub type ReqId = u64;

/// Interned policy-name handle, assigned by
/// [`super::FlightRecorder::intern_policy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PolicyId(pub u16);

/// What kind of step the composer produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepClass {
    /// Pure decode: every row emits one token.
    Decode,
    /// Monolithic prefill call(s), no decode rows.
    Prefill,
    /// Chunked-prefill rows interleaved with decode rows.
    Mixed,
}

impl StepClass {
    /// Stable lowercase label used by the exporters.
    pub fn label(self) -> &'static str {
        match self {
            StepClass::Decode => "decode",
            StepClass::Prefill => "prefill",
            StepClass::Mixed => "mixed",
        }
    }
}

/// Which wave of a step a plan/occupancy sample describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaveKind {
    /// The `q_len = 1` decode wave (the paper's starved regime).
    Decode,
    /// A `q_len > 1` chunked-prefill wave inside a mixed step.
    Chunk,
}

impl WaveKind {
    /// Stable lowercase label used by the exporters.
    pub fn label(self) -> &'static str {
        match self {
            WaveKind::Decode => "decode",
            WaveKind::Chunk => "chunk",
        }
    }
}

/// How a preempted request's KV comes back: swapped to host (latency
/// ledger) or recomputed (re-prefill + position-pure regeneration).
/// Restated here rather than imported (`obs` depends only on `util`);
/// `coordinator::ResumeKind` maps onto it via `ResumeKind::tag`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptClass {
    /// KV parked on the modeled host-transfer ledger.
    Swap,
    /// KV discarded; prompt re-prefills and tokens regenerate.
    Recompute,
}

impl PreemptClass {
    /// Stable lowercase label used by the exporters and metric labels.
    pub fn label(self) -> &'static str {
        match self {
            PreemptClass::Swap => "swap",
            PreemptClass::Recompute => "recompute",
        }
    }
}

/// Whether a plan decision was served from the plan cursor's horizon or
/// forced a planner refill (cache-miss analog; see `planner/cursor.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CursorOutcome {
    /// Decision came from the cursor's prefetched horizon.
    Hit,
    /// Decision required re-planning (new shape or horizon exhausted).
    Refill,
}

/// A request's lifecycle transition (the span reconstructor's input).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Accepted by admission control into a bounded class queue. The
    /// event is stamped with the request's *arrival* time so span TTFT
    /// matches `RequestTiming::ttft_us` exactly.
    Queued,
    /// Entered the running batch on `slot`.
    Admitted { slot: u32 },
    /// First output token emitted (prefill complete).
    FirstToken,
    /// Ran to natural completion with `n_generated` output tokens.
    Finished { n_generated: u32 },
    /// Cut short by cancellation, deadline, or shutdown.
    Cancelled,
}

/// One recorded occurrence. `t_us` is the engine's virtual clock (sim
/// backends) or wall µs since engine start (real backends) — the same
/// clock `RequestTiming` uses, so spans and metrics agree by construction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub t_us: u64,
    pub kind: EventKind,
}

/// The event vocabulary. One variant per instrumented site; see
/// `docs/observability.md` for the schema table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// The composer assembled one step: its row mix plus the KV-pressure
    /// and queue-depth gauges sampled at composition time (these feed the
    /// Chrome counter tracks).
    StepComposed {
        class: StepClass,
        chunk_rows: u32,
        decode_rows: u32,
        step_tokens: u32,
        kv_used_blocks: u32,
        queue_depth: u32,
    },
    /// The planner's split decision for one wave: policy, chosen split
    /// count, planned first-wave SM occupancy, and whether the plan
    /// cursor served it without re-planning.
    PlanDecision {
        wave: WaveKind,
        policy: PolicyId,
        batch: u32,
        max_kv: u32,
        num_splits: u32,
        occupancy: f32,
        cursor: CursorOutcome,
    },
    /// Modeled kernel wave cost for one executed step, split by wave kind
    /// (sim backend only; zero when the backend doesn't model it).
    WaveCost { wave: WaveKind, rows: u32, elapsed_us: f32 },
    /// KV blocks granted to a request at admission; `cached_tokens` is
    /// how much of the prompt the prefix cache already held.
    KvAdmit { request: ReqId, slot: u32, cached_tokens: u32 },
    /// A shared block was copy-on-write forked for this request's first
    /// divergent token.
    KvCowFork { request: ReqId },
    /// Evictions of cached prefix blocks since the previous step
    /// (recorded as a delta, not a running total).
    KvEvict { blocks: u32 },
    /// Prefix-cache probe at admission: how many of `prompt_tokens`
    /// prompt tokens were served from cache.
    PrefixProbe { request: ReqId, hit_tokens: u32, prompt_tokens: u32 },
    /// A submission refused by admission control; `backpressure` is true
    /// for a full class queue, false for never-schedulable.
    AdmissionReject { class: u8, backpressure: bool },
    /// Request lifecycle transition.
    Lifecycle { request: ReqId, phase: Phase },
    /// One prefill chunk of `len` prompt tokens starting at offset
    /// `start` was ingested for the request on `slot`.
    ChunkIngested { request: ReqId, slot: u32, start: u32, len: u32 },
    /// A running request was preempted for a higher-priority blocked
    /// head: its `blocks` KV blocks were released from `slot` and it was
    /// re-enqueued at the head of its class.
    Preempt { request: ReqId, slot: u32, blocks: u32, kind: PreemptClass },
    /// A preempted request re-entered the running set on `slot`.
    Resume { request: ReqId, slot: u32, kind: PreemptClass },
    /// A queued request was shed as hopeless: it could no longer meet
    /// its deadline/TTFT SLO, so admission dropped it instead of letting
    /// it burn KV. `waited_us` is how long it sat queued.
    Shed { request: ReqId, class: u8, waited_us: u32 },
    /// A cross-pool KV handoff landed on this (decode) replica: `blocks`
    /// prefix blocks were imported into the block manager after
    /// `wire_us` of modeled interconnect time (cluster disaggregation;
    /// recorded at the import instant on the receiving replica's clock).
    KvHandoff { request: ReqId, blocks: u32, wire_us: u32 },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_copy_and_small() {
        fn assert_copy<T: Copy>() {}
        assert_copy::<TraceEvent>();
        // The ring's footprint budget: a 64k ring stays under 4 MiB.
        assert!(std::mem::size_of::<TraceEvent>() <= 64, "{}", std::mem::size_of::<TraceEvent>());
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(StepClass::Mixed.label(), "mixed");
        assert_eq!(WaveKind::Decode.label(), "decode");
        assert_eq!(WaveKind::Chunk.label(), "chunk");
    }
}
