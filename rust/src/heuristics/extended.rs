//! The paper's stated future work, implemented: "extending the benefit to
//! lower L_K values and learning more configuration-specific num_splits
//! values" (§4.1, §5.2).
//!
//! [`ExtendedPolicy`] generalizes the conservative Figure-2 rule from one
//! override to a *learned table*: for every low-occupancy (nblk, tiles)
//! bucket it stores the split count that minimizes simulated latency,
//! auto-tuned by exhaustive sweep against the H100 model ([`tune`]). The
//! same safety posture is kept — saturated grids and the efficiency-loop
//! region are untouched, and tuning rejects any entry that doesn't beat
//! the upstream choice by a margin (so the table can only win).
//!
//! This is the bridge between the paper's evolved Python (aggressive,
//! shape-specific) and its distilled C++ rule (one bucket): a small table
//! with the C++ rule's safety and most of the evolved policy's reach.

use std::collections::HashMap;

use super::metadata::SplitPolicy;
use super::standard::num_splits_heuristic_upstream;
use super::tiles::DecodeShape;
use super::UPSTREAM_MAX_SPLITS;

/// Key: (nblk bucket, work-tile count) — the two quantities heuristics.h
/// already has in scope, so the table is exactly as upstreamable as the
/// paper's patch.
pub type BucketKey = (usize, usize);

/// A learned split table over low-occupancy buckets.
#[derive(Debug, Clone, Default)]
pub struct ExtendedPolicy {
    table: HashMap<BucketKey, usize>,
}

/// Tuning configuration.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// nblk range to tune (the guard region; beyond it the efficiency
    /// loop already runs).
    pub max_nblk: usize,
    /// Tile counts to tune (low-occupancy regime only).
    pub max_tiles: usize,
    /// Candidate split counts.
    pub candidate_splits: Vec<usize>,
    /// Required relative win over upstream before an entry is accepted
    /// (keeps the table regression-free by construction).
    pub min_win: f64,
    /// SM budget the upstream baseline is evaluated against (take it from
    /// the target `planner::DeviceProfile`).
    pub num_sm: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            max_nblk: 4,
            max_tiles: 16,
            candidate_splits: vec![2, 3, 4, 6, 8, 12, 16],
            min_win: 0.03,
            // H100 SXM SM count, spelled as a literal: heuristics/ sits
            // below planner/ in the layering DAG and must not import the
            // DeviceProfile presets. Callers tuning for another part pass
            // `TuneConfig { num_sm: device.num_sms, .. }` (the registry
            // factory does exactly that).
            num_sm: 132,
        }
    }
}

impl ExtendedPolicy {
    /// Auto-tune the table against a latency oracle.
    ///
    /// `latency(shape, num_splits)` must return the simulated kernel time;
    /// in production that's `Simulator::kernel_us` (kept as a closure here
    /// so heuristics/ stays independent of sim/).
    pub fn tune<F>(cfg: &TuneConfig, mut latency: F) -> ExtendedPolicy
    where
        F: FnMut(&DecodeShape, usize) -> f64,
    {
        let mut table = HashMap::new();
        for nblk in 1..=cfg.max_nblk {
            let l_k = nblk * super::tiles::KV_BLOCK; // representative length
            for tiles in 1..=cfg.max_tiles {
                // Representative shape with that tile count: batch = tiles,
                // H_KV = 1 (tiles = batch x h_kv for packed decode; the
                // latency model depends on the product, not the factors).
                let shape = DecodeShape::decode(tiles, l_k, 8, 1, 128);
                let upstream = num_splits_heuristic_upstream(
                    shape.total_mblocks(true),
                    cfg.num_sm,
                    shape.nblk(),
                    UPSTREAM_MAX_SPLITS,
                );
                let t_up = latency(&shape, upstream);
                let mut best: Option<(usize, f64)> = None;
                for &s in &cfg.candidate_splits {
                    if s == upstream {
                        continue;
                    }
                    let t = latency(&shape, s);
                    if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                        best = Some((s, t));
                    }
                }
                if let Some((s, t)) = best {
                    if t < t_up * (1.0 - cfg.min_win) {
                        table.insert((nblk, tiles), s);
                    }
                }
            }
        }
        ExtendedPolicy { table }
    }

    /// Number of learned table entries.
    pub fn len(&self) -> usize {
        self.table.len()
    }

    /// Whether the table is empty (falls through to upstream everywhere).
    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    /// The learned split count for a `(nblk, tiles)` cell, if any.
    pub fn lookup(&self, nblk: usize, tiles: usize) -> Option<usize> {
        self.table.get(&(nblk, tiles)).copied()
    }

    /// Render as the C++-style table the paper's future work describes.
    pub fn render_cpp(&self) -> String {
        let mut entries: Vec<(&BucketKey, &usize)> = self.table.iter().collect();
        entries.sort();
        let mut out = String::from(
            "// Learned sequence-aware split table (nblk, total_mblocks) -> num_splits\n",
        );
        for ((nblk, tiles), s) in entries {
            out.push_str(&format!(
                "if (num_n_blocks == {nblk} && total_mblocks == {tiles}) {{ return {s}; }}\n"
            ));
        }
        out.push_str("// otherwise: existing heuristic path\n");
        out
    }
}

impl SplitPolicy for ExtendedPolicy {
    fn name(&self) -> &'static str {
        "extended-table"
    }

    fn num_splits(&self, shape: &DecodeShape, num_sm: usize, pack_gqa: bool) -> usize {
        let tiles = shape.total_mblocks(pack_gqa);
        // Same saturated prelude as upstream: never touch busy grids.
        if tiles as f32 >= 0.8 * num_sm as f32 {
            return 1;
        }
        if let Some(s) = self.lookup(shape.nblk(), tiles) {
            return s;
        }
        num_splits_heuristic_upstream(tiles, num_sm, shape.nblk(), UPSTREAM_MAX_SPLITS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::StandardPolicy;
    use crate::planner::{DeviceProfile, Planner, PlannerBuilder};
    use crate::sim::Simulator;

    const H100_SMS: usize = DeviceProfile::H100_SXM.num_sms;

    fn tuned() -> ExtendedPolicy {
        let sim = Simulator::h100();
        let probe = Planner::standard();
        ExtendedPolicy::tune(&TuneConfig::default(), |shape, s| {
            sim.kernel_us(&probe.plan_forced(shape, s).metadata)
        })
    }

    #[test]
    fn learns_the_paper_bucket_and_more() {
        let p = tuned();
        assert!(!p.is_empty());
        // The paper's nblk=4 low-tile bucket must be in the table.
        assert!(p.lookup(4, 1).is_some());
        assert!(p.lookup(4, 2).is_some());
        // Future work realized: lower-L_K buckets (nblk 2..3) with few
        // tiles benefit too once the combine is paid off.
        assert!(
            p.lookup(3, 1).is_some() || p.lookup(2, 1).is_some(),
            "extended policy should reach below L_K=512: {:?}",
            p.render_cpp()
        );
    }

    #[test]
    fn never_loses_to_standard_or_conservative_patch() {
        let sim = Simulator::h100();
        let mut ext = PlannerBuilder::policy(tuned()).build();
        let mut std_p = Planner::standard();
        let mut pat_p = Planner::sequence_aware();
        for batch in [1usize, 2, 4, 8] {
            for l_k in (64..=4096).step_by(64) {
                for h_kv in [1usize, 2, 4, 8] {
                    let shape = DecodeShape::decode(batch, l_k, 8 * h_kv, h_kv, 128);
                    let t_ext = sim.kernel_us(&ext.plan(&shape).metadata);
                    let t_std = sim.kernel_us(&std_p.plan(&shape).metadata);
                    let t_pat = sim.kernel_us(&pat_p.plan(&shape).metadata);
                    assert!(
                        t_ext <= t_std * 1.0000001 && t_ext <= t_pat * 1.0000001,
                        "extended regressed at B={batch} L_K={l_k} H_KV={h_kv}: \
                         ext {t_ext:.3} std {t_std:.3} pat {t_pat:.3}"
                    );
                }
            }
        }
    }

    #[test]
    fn beats_conservative_patch_below_512() {
        // The whole point of the extension: wins at L_K <= 384 that the
        // conservative rule leaves on the table.
        let sim = Simulator::h100();
        let shape = DecodeShape::llama70b_tp8(1, 384);
        let t_ext = sim.kernel_us(&PlannerBuilder::policy(tuned()).build().plan(&shape).metadata);
        let t_pat = sim.kernel_us(&Planner::sequence_aware().plan(&shape).metadata);
        assert!(
            t_ext < t_pat * 0.95,
            "extended {t_ext:.2} should beat conservative {t_pat:.2} at L_K=384"
        );
    }

    #[test]
    fn saturated_grids_untouched() {
        let p = tuned();
        let dense = DecodeShape::decode(16, 512, 256, 32, 128); // 512 tiles
        assert_eq!(p.num_splits(&dense, H100_SMS, true), 1);
    }

    #[test]
    fn cpp_rendering_is_table_shaped() {
        let p = tuned();
        let cpp = p.render_cpp();
        assert!(cpp.contains("num_n_blocks == 4 && total_mblocks == 1"));
        assert!(cpp.contains("return"));
    }

    #[test]
    fn empty_table_is_pure_upstream() {
        let p = ExtendedPolicy::default();
        for l_k in [128usize, 512, 2048] {
            let shape = DecodeShape::llama70b_tp8(1, l_k);
            assert_eq!(
                p.num_splits(&shape, H100_SMS, true),
                StandardPolicy.num_splits(&shape, H100_SMS, true)
            );
        }
    }
}
