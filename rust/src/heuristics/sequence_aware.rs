//! The paper's contribution: the sequence-aware split policy (Figure 2).
//!
//! A conservative, upstreamable modification of `heuristics.h`: keep every
//! existing decision *except* in the low-tile `nblk == 4` boundary bucket
//! (`384 < L_K <= 512`), where the premature guard strands the H100. There,
//! if fewer than 4 work tiles exist (`Batch * H_KV < 4` for packed decode),
//! override to a small split count (`s = 3` on the current stack).
//!
//! Verbatim policy from the paper:
//!
//! ```c
//! // Guard 1: L_K <= 384 (nblk <= 3) - leave shorter contexts unchanged
//! if (num_n_blocks <= 3) { return 1; }
//! // Guard 2: nblk = 4 boundary bucket with enough tiles
//! if (num_n_blocks <= 4 && total_mblocks >= 4) { return 1; }
//! // Low-tile boundary case: demonstrate the idea with one small override
//! if (num_n_blocks == 4 && total_mblocks < 4) { return 3; }
//! // For longer contexts, existing efficiency loop runs (unchanged)
//! ```

use super::metadata::SplitPolicy;
use super::standard::efficiency_loop;
use super::tiles::DecodeShape;

/// Split count the paper's policy uses in the low-tile boundary bucket:
/// "the smallest split that enters the low-latency regime" (§5.2).
pub const BOUNDARY_SPLIT: usize = 3;

/// Tile threshold below which the boundary bucket counts as SM-starved.
pub const LOW_TILE_THRESHOLD: usize = 4;

/// The patched policy.
#[derive(Debug, Clone, Copy, Default)]
pub struct SequenceAwarePolicy;

/// The patched decision function — `heuristics.h` with Figure 2 applied.
pub fn num_splits_heuristic_patched(
    total_mblocks: usize,
    num_sm: usize,
    num_n_blocks: usize,
    max_splits: usize,
) -> usize {
    // Unchanged upstream prelude: saturated grids never split.
    if total_mblocks as f32 >= 0.8 * num_sm as f32 {
        return 1;
    }
    // Guard 1: L_K <= 384 (nblk <= 3) — shorter contexts left unchanged in
    // this initial policy (§4.1 documents wins may exist here; future work).
    if num_n_blocks <= 3 {
        return 1;
    }
    // Guard 2: nblk = 4 boundary bucket with enough tiles — keep s = 1.
    if num_n_blocks <= 4 && total_mblocks >= LOW_TILE_THRESHOLD {
        return 1;
    }
    // Low-tile boundary case (the paper's demonstration): nblk = 4 and the
    // SMs are starved ⇒ small conservative split.
    if num_n_blocks == 4 && total_mblocks < LOW_TILE_THRESHOLD {
        return BOUNDARY_SPLIT;
    }
    // Longer contexts: the pre-existing efficiency loop, unchanged.
    efficiency_loop(total_mblocks, num_sm, num_n_blocks, max_splits)
}

impl SplitPolicy for SequenceAwarePolicy {
    fn name(&self) -> &'static str {
        "sequence-aware"
    }

    fn num_splits(&self, shape: &DecodeShape, num_sm: usize, pack_gqa: bool) -> usize {
        num_splits_heuristic_patched(
            shape.total_mblocks(pack_gqa),
            num_sm,
            shape.nblk(),
            super::UPSTREAM_MAX_SPLITS,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::{SplitPolicy, StandardPolicy};
    use crate::planner::DeviceProfile;

    const H100_SMS: usize = DeviceProfile::H100_SXM.num_sms;

    fn patched(b: usize, l_k: usize, h_kv: usize) -> usize {
        let shape = DecodeShape::decode(b, l_k, 8 * h_kv, h_kv, 128);
        SequenceAwarePolicy.num_splits(&shape, H100_SMS, true)
    }

    fn standard(b: usize, l_k: usize, h_kv: usize) -> usize {
        let shape = DecodeShape::decode(b, l_k, 8 * h_kv, h_kv, 128);
        StandardPolicy.num_splits(&shape, H100_SMS, true)
    }

    #[test]
    fn guard1_short_contexts_unchanged() {
        for l_k in [1, 64, 128, 256, 384] {
            for h_kv in [1, 2, 8] {
                assert_eq!(patched(1, l_k, h_kv), 1, "l_k={l_k} h_kv={h_kv}");
            }
        }
    }

    #[test]
    fn low_tile_boundary_bucket_overrides_to_three() {
        // Table 1's winning cells: L_K = 512, B = 1, H_KV in {1, 2} ⇒ tiles
        // in {1, 2} < 4 ⇒ s = 3.
        assert_eq!(patched(1, 512, 1), BOUNDARY_SPLIT);
        assert_eq!(patched(1, 512, 2), BOUNDARY_SPLIT);
        // Any L_K in the nblk = 4 bucket behaves identically.
        assert_eq!(patched(1, 385, 1), BOUNDARY_SPLIT);
        assert_eq!(patched(1, 448, 1), BOUNDARY_SPLIT);
        // Batch 2 x H_KV 1 = 2 tiles < 4: also covered by the override.
        assert_eq!(patched(2, 512, 1), BOUNDARY_SPLIT);
    }

    #[test]
    fn guard2_saturated_boundary_unchanged() {
        // H_KV >= 4 ⇒ tiles >= 4 ⇒ keep s = 1 (§5.3: "the H_KV in {4, 8, 32}
        // cases remain unchanged because both heuristics resolve to s = 1").
        assert_eq!(patched(1, 512, 4), 1);
        assert_eq!(patched(1, 512, 8), 1);
        assert_eq!(patched(1, 512, 32), 1);
        assert_eq!(patched(4, 512, 1), 1); // Batch*H_KV = 4 tiles
        assert_eq!(patched(8, 512, 8), 1); // dense: would add combine overhead
    }

    #[test]
    fn longer_contexts_fall_through_identically() {
        // Table 1's 2048/4096 controls: patched == standard.
        for l_k in [640, 1024, 2048, 4096, 8192] {
            for h_kv in [1, 2, 8] {
                for b in [1, 2, 8] {
                    assert_eq!(
                        patched(b, l_k, h_kv),
                        standard(b, l_k, h_kv),
                        "b={b} l_k={l_k} h_kv={h_kv}"
                    );
                }
            }
        }
    }

    #[test]
    fn patch_differs_only_in_boundary_bucket() {
        // Exhaustive: the two policies may differ ONLY when nblk == 4 and
        // tiles < 4 — the paper's "no broader policy surface" claim.
        for b in [1, 2, 4, 8, 16] {
            for l_k in (64..=8192).step_by(64) {
                for h_kv in [1, 2, 4, 8, 32] {
                    let shape = DecodeShape::decode(b, l_k, 8 * h_kv, h_kv, 128);
                    let s_std = standard(b, l_k, h_kv);
                    let s_pat = patched(b, l_k, h_kv);
                    if s_std != s_pat {
                        assert_eq!(shape.nblk(), 4, "unexpected diff at l_k={l_k}");
                        assert!(shape.total_mblocks(true) < LOW_TILE_THRESHOLD);
                        assert_eq!(s_std, 1);
                        assert_eq!(s_pat, BOUNDARY_SPLIT);
                    }
                }
            }
        }
    }

    #[test]
    fn saturated_prelude_still_wins() {
        // Even in the boundary bucket, a saturated grid keeps s = 1 via the
        // unchanged 0.8 * SM prelude (tiles >= 106 with nblk = 4 needs
        // batch * h_kv >= 106, e.g. batch 14 x h_kv 8).
        assert_eq!(patched(14, 512, 8), 1);
    }
}
