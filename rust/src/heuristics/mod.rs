//! Split-KV scheduling heuristics — the paper's subject and contribution.
//!
//! FlashAttention-3's Hopper dispatch logic decides, per kernel launch, how
//! many *sequence splits* (`num_splits`, the paper's `s`) to carve the KV
//! reduction into. More splits ⇒ more CTAs ⇒ better SM occupancy, at the
//! cost of a final split-combine reduction. This module contains the
//! *decision functions* only:
//!
//! * [`tiles`]           — the tile/shape arithmetic shared by everything
//!                         (`nblk`, `total_mblocks`, split geometry),
//! * [`standard`]        — a faithful port of the upstream `heuristics.h`
//!                         decision function, including the premature
//!                         `L_K <= 512` guard the paper diagnoses (§2.2),
//! * [`sequence_aware`]  — the paper's conservative patch (Figure 2),
//! * [`extended`]        — the learned (nblk, tiles) table (§5.2 future
//!                         work),
//! * [`metadata`]        — the [`SchedulerMetadata`] launch contract and
//!                         the [`SplitPolicy`] trait.
//!
//! Everything *outward-facing* lives in [`crate::planner`]: policies here
//! answer "how many splits for this shape on this SM budget", while the
//! planner owns device profiles ([`crate::planner::DeviceProfile`] — the
//! successor of the `H100_NUM_SMS` constant that used to live in this
//! module), launch-knob configuration, plan caching, and the only code
//! path that constructs [`SchedulerMetadata`].

pub mod extended;
pub mod metadata;
pub mod sequence_aware;
pub mod standard;
pub mod tiles;

pub use extended::ExtendedPolicy;
pub use metadata::{DispatchPath, SchedulerMetadata, SplitPolicy};
pub use sequence_aware::SequenceAwarePolicy;
pub use standard::StandardPolicy;
pub use tiles::{DecodeShape, SplitGeometry};

/// Upstream FA3 cap on split counts — an algorithmic constant of the
/// ported `heuristics.h` decision functions. The *device-facing* cap lives
/// in [`crate::planner::DeviceProfile::max_splits`]; the planner clamps
/// every plan against it.
pub(crate) const UPSTREAM_MAX_SPLITS: usize = 128;
