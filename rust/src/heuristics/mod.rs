//! Split-KV scheduling heuristics — the paper's subject and contribution.
//!
//! FlashAttention-3's Hopper dispatch logic decides, per kernel launch, how
//! many *sequence splits* (`num_splits`, the paper's `s`) to carve the KV
//! reduction into. More splits ⇒ more CTAs ⇒ better SM occupancy, at the
//! cost of a final split-combine reduction. This module contains:
//!
//! * [`tiles`]           — the tile/shape arithmetic shared by everything
//!                         (`nblk`, `total_mblocks`, split geometry),
//! * [`standard`]        — a faithful port of the upstream `heuristics.h`
//!                         decision function, including the premature
//!                         `L_K <= 512` guard the paper diagnoses (§2.2),
//! * [`sequence_aware`]  — the paper's conservative patch (Figure 2),
//! * [`metadata`]        — the precomputed-scheduler-metadata launch path
//!                         (vLLM-style, §5.1) and the policy trait.

pub mod extended;
pub mod metadata;
pub mod sequence_aware;
pub mod standard;
pub mod tiles;

pub use extended::ExtendedPolicy;
pub use metadata::{DispatchPath, SchedulerMetadata, SplitPolicy};
pub use sequence_aware::SequenceAwarePolicy;
pub use standard::StandardPolicy;
pub use tiles::{DecodeShape, SplitGeometry};

/// H100 SXM5 streaming-multiprocessor count — the hardware constant the
/// whole occupancy argument revolves around (§2.1).
pub const H100_NUM_SMS: usize = 132;

/// Upstream FA3 cap on split counts.
pub const MAX_SPLITS: usize = 128;
