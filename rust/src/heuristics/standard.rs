//! Faithful port of the upstream FlashAttention-3 Hopper split heuristic
//! (`hopper/heuristics.h::num_splits_heuristic`), including the premature
//! short-sequence guard the paper diagnoses (§2.2): `num_n_blocks <= 4`
//! (i.e. `L_K <= 512`) unconditionally returns `num_splits = 1`, no matter
//! how few work tiles exist relative to the 132 H100 SMs.

use super::metadata::SplitPolicy;
use super::tiles::DecodeShape;

/// The unpatched upstream policy.
#[derive(Debug, Clone, Copy, Default)]
pub struct StandardPolicy;

/// Core upstream decision function. Arguments mirror `heuristics.h`:
///
/// * `total_mblocks` — aggregate work-tile count before splitting
///   (`Batch * H_KV` for packed decode),
/// * `num_sm` — SMs available to the grid (132 minus `sm_margin`),
/// * `num_n_blocks` — KV-sequence blocks of 128 (`nblk`),
/// * `max_splits` — upstream cap (128).
///
/// Returns the chosen `num_splits`.
pub fn num_splits_heuristic_upstream(
    total_mblocks: usize,
    num_sm: usize,
    num_n_blocks: usize,
    max_splits: usize,
) -> usize {
    // If we have enough tiles to almost fill the SMs, use 1 split.
    if total_mblocks as f32 >= 0.8 * num_sm as f32 {
        return 1;
    }
    // THE PREMATURE GUARD (§2.2): "an explicit guard in the underlying C++
    // heuristic returns s = 1 if the sequence length L_K <= 512". This is
    // the line the paper's patch replaces.
    if num_n_blocks <= 4 {
        return 1;
    }
    efficiency_loop(total_mblocks, num_sm, num_n_blocks, max_splits)
}

/// The pre-existing wave-quantization efficiency loop that runs for longer
/// contexts (unchanged by the paper's patch — its behavior on
/// `L_K >= 640` is why Table 1's 2048/4096 rows are 1.00x controls).
pub fn efficiency_loop(
    total_mblocks: usize,
    num_sm: usize,
    num_n_blocks: usize,
    max_splits: usize,
) -> usize {
    let max_splits = max_splits.min(num_sm).min(num_n_blocks).max(1);

    // A split count is only *eligible* if it changes the per-split block
    // count: ceil(nblk/s) == ceil(nblk/(s-1)) means s buys nothing over
    // s-1 (it only adds empty splits).
    let ceildiv = |a: usize, b: usize| a.div_ceil(b);
    let eligible = |s: usize| s == 1 || ceildiv(num_n_blocks, s) != ceildiv(num_n_blocks, s - 1);
    let eff = |s: usize| -> f32 {
        if !eligible(s) {
            return 0.0;
        }
        let n_waves = (total_mblocks * s) as f32 / num_sm as f32;
        n_waves / n_waves.ceil()
    };

    // Two passes recomputing eff(s) instead of the upstream per-call
    // efficiency Vec: eff is a handful of flops, and this decision runs on
    // every planner cache miss and cursor refill — the hot path stays
    // allocation-free (the upstream C++ uses a std::vector here; its cost
    // is what the paper's §5.1 setup-overhead numbers include).
    let mut max_efficiency = 0.0_f32;
    for s in 1..=max_splits {
        let e = eff(s);
        if e > max_efficiency {
            max_efficiency = e;
        }
    }
    // Pick the smallest split whose wave efficiency is within 85% of the
    // best achievable.
    for s in 1..=max_splits {
        if eff(s) >= 0.85 * max_efficiency {
            return s;
        }
    }
    1
}

impl SplitPolicy for StandardPolicy {
    fn name(&self) -> &'static str {
        "standard"
    }

    fn num_splits(&self, shape: &DecodeShape, num_sm: usize, pack_gqa: bool) -> usize {
        num_splits_heuristic_upstream(
            shape.total_mblocks(pack_gqa),
            num_sm,
            shape.nblk(),
            super::UPSTREAM_MAX_SPLITS,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::SplitPolicy;
    use crate::planner::DeviceProfile;

    const H100_SMS: usize = DeviceProfile::H100_SXM.num_sms;

    fn splits(b: usize, l_k: usize, h_kv: usize) -> usize {
        let shape = DecodeShape::decode(b, l_k, 8 * h_kv, h_kv, 128);
        StandardPolicy.num_splits(&shape, H100_SMS, true)
    }

    #[test]
    fn premature_guard_forces_one_split_short_contexts() {
        // §2.2: every L_K <= 512 shape resolves to s = 1, even B=1/H_KV=1
        // where only one tile exists for 132 SMs.
        for l_k in [1, 128, 256, 384, 512] {
            for h_kv in [1, 2, 8] {
                assert_eq!(splits(1, l_k, h_kv), 1, "l_k={l_k} h_kv={h_kv}");
            }
        }
    }

    #[test]
    fn saturated_grids_never_split() {
        // 0.8 * 132 ≈ 105.6 tiles ⇒ no splitting even for long contexts.
        assert_eq!(splits(16, 8192, 8), 1); // 128 tiles >= 105.6
        assert_eq!(splits(8, 4096, 32), 1); // 256 tiles
    }

    #[test]
    fn long_low_tile_contexts_do_split() {
        // The existing efficiency loop engages past the guard (nblk > 4):
        // B=1, H_KV=1, L_K=2048 (nblk=16) has 1 tile — splitting is chosen.
        assert!(splits(1, 2048, 1) > 1);
        assert!(splits(1, 4096, 1) > 1);
        assert!(splits(1, 640, 1) > 1); // nblk = 5, just past the guard
    }

    #[test]
    fn efficiency_loop_eligibility() {
        // nblk = 16, 1 tile: eligible split counts change ceil(16/s).
        // The loop returns the smallest split within 85% of max efficiency.
        let s = efficiency_loop(1, H100_SMS, 16, 128);
        assert!(s >= 1 && s <= 16);
        // With one tile and <= 132 SMs, more splits strictly help wave
        // efficiency; the best eligible value is 16 (one block per split).
        assert_eq!(s, 16);
    }

    #[test]
    fn efficiency_loop_respects_caps() {
        assert_eq!(efficiency_loop(1, 4, 1000, 2), 2); // max_splits cap
        let s = efficiency_loop(1, 2, 1000, 128); // SM cap
        assert!(s <= 2);
        // Saturation is handled by the 0.8*SM prelude in the caller, not
        // the loop itself: the full heuristic returns 1 for many tiles.
        assert_eq!(num_splits_heuristic_upstream(200, 132, 100, 128), 1);
    }

    #[test]
    fn boundary_nblk_five_escapes_guard() {
        // L_K = 640 ⇒ nblk = 5: first length past the guard.
        assert_eq!(DecodeShape::llama70b_tp8(1, 640).nblk(), 5);
        assert!(splits(1, 640, 1) > 1);
        assert_eq!(splits(1, 512, 1), 1);
    }
}
