//! Tile/shape arithmetic shared by the heuristics, the simulator, and the
//! coordinator. Mirrors `python/compile/kernels/flash_decode.split_geometry`
//! (tested for agreement in python/tests/test_kernel.py and here).

/// KV-block granularity (FA3 Hopper `kBlockN`): the heuristic counts
/// sequence blocks of 128.
pub const KV_BLOCK: usize = 128;

/// Query-block granularity (FA3 Hopper `kBlockM` for the decode kernel):
/// with `pack_gqa`, the query-head group is folded into the M dimension, so
/// a group of up to this many rows still occupies a single M-block.
pub const Q_BLOCK: usize = 64;

/// One decode-attention launch shape: the paper's tuple
/// `(Batch, L_Q, L_K, H_Q, H_KV, D)` with `L_Q = 1` for autoregressive
/// decoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DecodeShape {
    pub batch: usize,
    pub l_q: usize,
    pub l_k: usize,
    pub h_q: usize,
    pub h_kv: usize,
    pub d: usize,
}

impl DecodeShape {
    /// Decode-step shape (`L_Q = 1`), the regime the paper studies.
    pub fn decode(batch: usize, l_k: usize, h_q: usize, h_kv: usize, d: usize) -> DecodeShape {
        DecodeShape { batch, l_q: 1, l_k, h_q, h_kv, d }
    }

    /// The paper's running example: Llama-3.1-70B under TP-8 ⇒ per-device
    /// `H_Q = 8, H_KV = 1, D = 128` (§5.1).
    pub fn llama70b_tp8(batch: usize, l_k: usize) -> DecodeShape {
        DecodeShape::decode(batch, l_k, 8, 1, 128)
    }

    /// Mixed-wave shape (`L_Q > 1`): chunked-prefill rows — and, later,
    /// speculative multi-token verify steps — put `l_q` query tokens per
    /// row in a step, shifting `m_blocks` (and with it occupancy and the
    /// split decision) away from the pure-decode intuition. `l_q = 1`
    /// reduces to [`DecodeShape::decode`].
    pub fn mixed(
        batch: usize,
        l_q: usize,
        l_k: usize,
        h_q: usize,
        h_kv: usize,
        d: usize,
    ) -> DecodeShape {
        DecodeShape { batch, l_q: l_q.max(1), l_k, h_q, h_kv, d }
    }

    /// GQA group size `H_Q / H_KV`.
    pub fn group_size(&self) -> usize {
        assert!(
            self.h_q % self.h_kv == 0,
            "H_Q={} not divisible by H_KV={}",
            self.h_q,
            self.h_kv
        );
        self.h_q / self.h_kv
    }

    /// Number of KV sequence blocks: the heuristic's `num_n_blocks`.
    /// `nblk = 4` ⇔ `384 < L_K <= 512` — the paper's boundary bucket.
    pub fn nblk(&self) -> usize {
        self.l_k.div_ceil(KV_BLOCK)
    }

    /// M-blocks per (batch, kv-head) unit of work. With `pack_gqa` the
    /// query group rides along the M dimension (`L_Q * group` rows); without
    /// it each query head is its own scheduling unit.
    pub fn m_blocks(&self, pack_gqa: bool) -> usize {
        if pack_gqa {
            (self.l_q * self.group_size()).div_ceil(Q_BLOCK)
        } else {
            self.l_q.div_ceil(Q_BLOCK)
        }
    }

    /// The heuristic's `total_mblocks`: aggregate work-tile count before
    /// splitting. For decode (`L_Q = 1`) with `pack_gqa` this reduces to
    /// `Batch * H_KV` (§4: "the earlier Batch × H_KV intuition").
    pub fn total_mblocks(&self, pack_gqa: bool) -> usize {
        let heads = if pack_gqa { self.h_kv } else { self.h_q };
        self.batch * heads * self.m_blocks(pack_gqa)
    }

    /// Bytes of one KV head's K+V data (f16/bf16 = 2 bytes each of K and V):
    /// `size_one_kv_head` in upstream `heuristics.h`, used by its eligibility
    /// logic and by our simulator's memory model.
    pub fn size_one_kv_head_bytes(&self, dtype_bytes: usize) -> usize {
        2 * self.l_k * self.d * dtype_bytes
    }

    /// The per-device shape under `degree`-way tensor-parallel head
    /// sharding (Megatron-style: Q and KV heads divided evenly across
    /// shards; batch, sequence, and head dim are replicated). This is how
    /// production deployments *enter* the paper's low-head-count regime:
    /// a TP-8 shard of an 8-KV-head model runs `H_KV = 1` per device.
    /// Returns `None` when the heads don't divide evenly — the cluster
    /// topology surfaces that as a build-time error.
    pub fn shard(&self, degree: usize) -> Option<DecodeShape> {
        if degree == 0 || self.h_q % degree != 0 || self.h_kv % degree != 0 {
            return None;
        }
        Some(DecodeShape { h_q: self.h_q / degree, h_kv: self.h_kv / degree, ..*self })
    }
}

/// Static split geometry (mirrors the Python `split_geometry`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitGeometry {
    pub nblk: usize,
    pub blocks_per_split: usize,
    pub split_len: usize,
    pub padded_len: usize,
}

impl SplitGeometry {
    /// Derive the split geometry for a sequence length and split count.
    pub fn of(l_k: usize, num_splits: usize) -> SplitGeometry {
        assert!(l_k >= 1, "l_k must be >= 1");
        assert!(num_splits >= 1, "num_splits must be >= 1");
        let nblk = l_k.div_ceil(KV_BLOCK);
        let blocks_per_split = nblk.div_ceil(num_splits);
        let split_len = blocks_per_split * KV_BLOCK;
        SplitGeometry {
            nblk,
            blocks_per_split,
            split_len,
            padded_len: num_splits * split_len,
        }
    }

    /// Splits that actually receive work (`s > nblk` leaves empty splits —
    /// legal but wasted launches; see Figure 3's plateau).
    pub fn effective_splits(l_k: usize, num_splits: usize) -> usize {
        let g = SplitGeometry::of(l_k, num_splits);
        g.nblk.div_ceil(g.blocks_per_split)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nblk_buckets() {
        // The paper's bucket boundaries (§4 Guard 1/2).
        assert_eq!(DecodeShape::llama70b_tp8(1, 128).nblk(), 1);
        assert_eq!(DecodeShape::llama70b_tp8(1, 384).nblk(), 3);
        assert_eq!(DecodeShape::llama70b_tp8(1, 385).nblk(), 4);
        assert_eq!(DecodeShape::llama70b_tp8(1, 512).nblk(), 4);
        assert_eq!(DecodeShape::llama70b_tp8(1, 513).nblk(), 5);
        assert_eq!(DecodeShape::llama70b_tp8(1, 640).nblk(), 5);
    }

    #[test]
    fn total_mblocks_decode_intuition() {
        // §4: for decode, total_mblocks == Batch * H_KV under pack_gqa.
        for (b, h_kv) in [(1, 1), (1, 2), (2, 4), (8, 8)] {
            let s = DecodeShape::decode(b, 512, 8 * h_kv, h_kv, 128);
            assert_eq!(s.total_mblocks(true), b * h_kv);
        }
        // Without pack_gqa each query head is a tile.
        let s = DecodeShape::decode(1, 512, 8, 1, 128);
        assert_eq!(s.total_mblocks(false), 8);
    }

    #[test]
    fn mixed_shape_scales_mblocks_with_lq() {
        // A 64-token chunk over the paper's TP-8 geometry packs
        // 64 * 8 = 512 query rows: 8 M-blocks of 64 — q_len > 1 leaves
        // the starved Batch * H_KV regime.
        let chunk = DecodeShape::mixed(1, 64, 512, 8, 1, 128);
        assert_eq!(chunk.m_blocks(true), 8);
        assert_eq!(chunk.total_mblocks(true), 8);
        // l_q = 1 reduces exactly to the decode constructor.
        assert_eq!(DecodeShape::mixed(2, 1, 512, 8, 1, 128), DecodeShape::llama70b_tp8(2, 512));
        // l_q = 0 clamps to 1 (an empty wave still shapes as decode).
        assert_eq!(DecodeShape::mixed(1, 0, 512, 8, 1, 128).l_q, 1);
    }

    #[test]
    fn pack_gqa_large_group_spills_mblocks() {
        // A 128-way group (hypothetical) would need 2 M-blocks of 64 rows.
        let s = DecodeShape::decode(1, 512, 128, 1, 128);
        assert_eq!(s.m_blocks(true), 2);
        assert_eq!(s.total_mblocks(true), 2);
    }

    #[test]
    fn geometry_matches_python_oracle() {
        // Mirrors test_split_geometry_basics in python/tests/test_kernel.py.
        assert_eq!(
            SplitGeometry::of(512, 1),
            SplitGeometry { nblk: 4, blocks_per_split: 4, split_len: 512, padded_len: 512 }
        );
        assert_eq!(
            SplitGeometry::of(512, 3),
            SplitGeometry { nblk: 4, blocks_per_split: 2, split_len: 256, padded_len: 768 }
        );
        assert_eq!(
            SplitGeometry::of(512, 64),
            SplitGeometry { nblk: 4, blocks_per_split: 1, split_len: 128, padded_len: 8192 }
        );
        assert_eq!(
            SplitGeometry::of(1, 1),
            SplitGeometry { nblk: 1, blocks_per_split: 1, split_len: 128, padded_len: 128 }
        );
    }

    #[test]
    fn effective_splits_saturate_at_nblk() {
        assert_eq!(SplitGeometry::effective_splits(512, 1), 1);
        assert_eq!(SplitGeometry::effective_splits(512, 3), 2); // ceil(4/2)=2... see below
        assert_eq!(SplitGeometry::effective_splits(512, 4), 4);
        assert_eq!(SplitGeometry::effective_splits(512, 64), 4);
    }

    #[test]
    fn size_one_kv_head() {
        let s = DecodeShape::llama70b_tp8(1, 512);
        // K+V, 512 tokens, D=128, bf16: 2 * 512 * 128 * 2 = 256 KiB.
        assert_eq!(s.size_one_kv_head_bytes(2), 256 * 1024);
    }

    #[test]
    #[should_panic]
    fn indivisible_heads_panic() {
        DecodeShape::decode(1, 128, 8, 3, 64).group_size();
    }

    #[test]
    fn tp_sharding_divides_heads() {
        // Llama-3.1-70B full model: H_Q = 64, H_KV = 8. TP-8 yields the
        // paper's running per-device shape (H_Q = 8, H_KV = 1).
        let full = DecodeShape::decode(1, 512, 64, 8, 128);
        let tp8 = full.shard(8).unwrap();
        assert_eq!(tp8, DecodeShape::llama70b_tp8(1, 512));
        // Group size (and hence pack_gqa M-block packing) is preserved.
        assert_eq!(tp8.group_size(), full.group_size());
        assert_eq!(tp8.m_blocks(true), full.m_blocks(true));
        // Tiles shrink by exactly the TP degree — the regime shift.
        assert_eq!(full.total_mblocks(true), 8);
        assert_eq!(tp8.total_mblocks(true), 1);
        // Identity shard.
        assert_eq!(full.shard(1), Some(full));
    }

    #[test]
    fn tp_sharding_rejects_indivisible() {
        let full = DecodeShape::decode(1, 512, 64, 8, 128);
        assert_eq!(full.shard(0), None);
        assert_eq!(full.shard(3), None); // 8 % 3 != 0
        assert_eq!(full.shard(16), None); // fewer KV heads than shards
        // H_Q divisible but H_KV not: rejected.
        assert_eq!(DecodeShape::decode(1, 512, 64, 4, 128).shard(8), None);
    }
}
