//! Scheduler metadata: the launch-path contract between a serving stack and
//! the attention kernel.
//!
//! §5.1 distinguishes two deployment paths:
//!
//! * **Precomputed metadata** (`get_scheduler_metadata()` + explicit
//!   `num_splits`, the vLLM path): the serving engine decides the split
//!   count *before* launch and passes it explicitly. The full 21–24%
//!   improvement applies here — and this is exactly what our rust
//!   coordinator does (its per-step scheduler asks the
//!   [`crate::planner::Planner`] for a plan each decode step).
//! * **Internal heuristic** (no metadata): the kernel's own dispatch picks
//!   the split late, yielding only ~1.00–1.05x. The simulator models this
//!   as retaining part of the setup overhead (see `sim/kernel_model.rs`).
//!
//! Construction discipline: [`SchedulerMetadata`] is only built by
//! [`crate::planner::Planner`] (and by its own combinator methods below).
//! Call sites that used to assemble it by hand — benches, sweeps, the
//! evolved-genome path — now go through `Planner::plan` /
//! `Planner::plan_forced`, so the device's SM budget travels with the
//! metadata instead of living in a global constant.

use super::tiles::DecodeShape;

/// How the split decision reaches the kernel launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DispatchPath {
    /// vLLM-style: split chosen ahead of launch, combine schedule
    /// specialized. The paper's headline numbers (Table 1).
    PrecomputedMetadata,
    /// Kernel-internal dispatch: late decision, generic combine schedule
    /// (~1.00–1.05x gains per §5.1).
    InternalHeuristic,
}

/// A split-selection policy: standard upstream or the paper's patch (or an
/// auto-tuned table from `extended`). This stays the *inner* decision
/// trait; the outward-facing contract is [`crate::planner::Planner`].
pub trait SplitPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Decide `num_splits` for one launch. `num_sm` is the SM budget the
    /// planner computed from its [`crate::planner::DeviceProfile`] and
    /// `sm_margin`; `pack_gqa` selects the GQA layout.
    fn num_splits(&self, shape: &DecodeShape, num_sm: usize, pack_gqa: bool) -> usize;

    /// Cache contract for the planner's shape-bucket plan cache: return
    /// true (the default) iff `num_splits` depends on `shape` only through
    /// `shape.nblk()` and `shape.total_mblocks(pack_gqa)`. Every built-in
    /// policy satisfies this; a policy keying off exact `L_K` or `D` must
    /// override to false so the planner falls back to exact-shape keys.
    fn shape_bucket_pure(&self) -> bool {
        true
    }

    /// Decode monotonicity contract for the planner's
    /// [`crate::planner::PlanCursor`]: the largest `L_K` (inclusive) for
    /// which the decision made at `shape` is still guaranteed unchanged,
    /// holding every other shape field fixed. Autoregressive decode grows
    /// `L_K` by exactly one per step, so the decision only needs
    /// recomputing when `L_K` crosses this horizon.
    ///
    /// The default is exact for every bucket-pure policy: the decision can
    /// only change at the next `nblk` bucket edge (`nblk * 128`), which is
    /// also the boundary of the extended policy's learned table. Non-pure
    /// policies fall back to `shape.l_k` — no reuse, every step recomputes
    /// — unless they override with a tighter horizon. The planner
    /// additionally clamps to the current nblk bucket (derived launch
    /// geometry such as `effective_splits` is bucket-dependent even when
    /// the split count is not).
    fn decision_horizon(&self, shape: &DecodeShape) -> usize {
        if self.shape_bucket_pure() {
            shape.nblk() * super::tiles::KV_BLOCK
        } else {
            shape.l_k
        }
    }
}

/// Precomputed launch schedule for one decode-attention call — the analog
/// of FA3's `get_scheduler_metadata()` result that inference stacks pass
/// back at launch time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerMetadata {
    pub shape: DecodeShape,
    pub num_splits: usize,
    pub pack_gqa: bool,
    /// SMs reserved for the combine-scheduler CTA (§3.1's `sm_margin` knob).
    pub sm_margin: usize,
    /// Total SMs of the device this schedule targets (before the margin).
    /// Stamped by the planner from its device profile so occupancy math
    /// never consults a global constant.
    pub num_sms: usize,
    pub path: DispatchPath,
}

impl SchedulerMetadata {
    /// Same schedule with a different split count (keeps shape, layout,
    /// margin, and device budget). Used by the simulator to price the
    /// unsplit baseline of the internal-heuristic path.
    pub fn with_splits(mut self, num_splits: usize) -> SchedulerMetadata {
        assert!(num_splits >= 1);
        self.num_splits = num_splits;
        self
    }

    /// This metadata re-routed onto another dispatch path.
    pub fn with_path(mut self, path: DispatchPath) -> SchedulerMetadata {
        self.path = path;
        self
    }

    /// CTAs this launch puts on the GPU: one per (tile, effective split).
    pub fn grid_ctas(&self) -> usize {
        let eff = super::tiles::SplitGeometry::effective_splits(self.shape.l_k, self.num_splits);
        self.shape.total_mblocks(self.pack_gqa) * eff
    }

    /// SM occupancy fraction this grid achieves in its first wave —
    /// the quantity §2.1 shows collapsing to ~6%. Saturating: a margin
    /// larger than the device degrades to a 1-SM budget (the seed
    /// underflowed and panicked in debug builds when `sm_margin` exceeded
    /// the SM count).
    pub fn occupancy(&self) -> f64 {
        let sms = self.num_sms.saturating_sub(self.sm_margin).max(1) as f64;
        (self.grid_ctas() as f64 / sms).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{DeviceProfile, Planner, PlannerBuilder};
    use crate::heuristics::{SequenceAwarePolicy, StandardPolicy};

    #[test]
    fn occupancy_collapse_reproduced() {
        // §2.1: "operating on 8 tiles without sequence splitting translates
        // to an occupancy of ~6%". 8 tiles = e.g. batch 1, H_KV 8.
        let shape = DecodeShape::decode(1, 512, 64, 8, 128);
        let md = Planner::standard().plan(&shape).metadata;
        assert_eq!(md.num_splits, 1);
        assert_eq!(md.grid_ctas(), 8);
        assert_eq!(md.num_sms, DeviceProfile::H100_SXM.num_sms);
        let occ = md.occupancy();
        assert!((0.05..0.07).contains(&occ), "occupancy {occ} should be ~6%");
    }

    #[test]
    fn patched_metadata_raises_ctas_in_target_regime() {
        let shape = DecodeShape::llama70b_tp8(1, 512);
        let std_md = Planner::standard().plan(&shape).metadata;
        let pat_md = Planner::sequence_aware().plan(&shape).metadata;
        assert_eq!(std_md.grid_ctas(), 1);
        assert!(pat_md.grid_ctas() > std_md.grid_ctas());
        assert!(pat_md.occupancy() > std_md.occupancy());
    }

    #[test]
    fn forced_metadata_for_sweeps() {
        let shape = DecodeShape::llama70b_tp8(1, 512);
        let md = Planner::standard().plan_forced(&shape, 64).metadata;
        assert_eq!(md.num_splits, 64);
        // Over-split: effective splits cap at nblk = 4 CTAs.
        assert_eq!(md.grid_ctas(), 4);
        assert_eq!(md.path, DispatchPath::PrecomputedMetadata);
        let md2 = md.with_path(DispatchPath::InternalHeuristic);
        assert_eq!(md2.path, DispatchPath::InternalHeuristic);
        let md1 = md.with_splits(1);
        assert_eq!(md1.num_splits, 1);
        assert_eq!(md1.shape, md.shape);
    }

    #[test]
    fn sm_margin_reduces_budget() {
        let shape = DecodeShape::llama70b_tp8(1, 2048);
        let a = Planner::standard().plan(&shape).metadata;
        let b = PlannerBuilder::policy(StandardPolicy)
            .sm_margin(100)
            .build()
            .plan(&shape)
            .metadata;
        assert_eq!(a.sm_margin, 0);
        assert_eq!(b.sm_margin, 100);
        // Fewer SMs available can only lower (or keep) the chosen splits.
        assert!(b.num_splits <= a.num_splits.max(32));
    }

    #[test]
    fn decision_horizon_is_the_nblk_bucket_edge() {
        // Bucket-pure policies (all built-ins) promise validity to the end
        // of the current 128-token bucket — the paper's bucket boundaries.
        for policy in [&StandardPolicy as &dyn SplitPolicy, &SequenceAwarePolicy] {
            assert_eq!(policy.decision_horizon(&DecodeShape::llama70b_tp8(1, 1)), 128);
            assert_eq!(policy.decision_horizon(&DecodeShape::llama70b_tp8(1, 384)), 384);
            assert_eq!(policy.decision_horizon(&DecodeShape::llama70b_tp8(1, 385)), 512);
            assert_eq!(policy.decision_horizon(&DecodeShape::llama70b_tp8(1, 512)), 512);
            assert_eq!(policy.decision_horizon(&DecodeShape::llama70b_tp8(1, 513)), 640);
        }
        // A non-bucket-pure policy defaults to no reuse at all.
        struct ExactLk;
        impl SplitPolicy for ExactLk {
            fn name(&self) -> &'static str {
                "exact-lk"
            }
            fn num_splits(&self, shape: &DecodeShape, _: usize, _: bool) -> usize {
                1 + shape.l_k % 3
            }
            fn shape_bucket_pure(&self) -> bool {
                false
            }
        }
        assert_eq!(ExactLk.decision_horizon(&DecodeShape::llama70b_tp8(1, 400)), 400);
    }

    #[test]
    fn occupancy_saturates_on_oversized_margin() {
        // The satellite fix: sm_margin > num_sms must not underflow.
        let md = PlannerBuilder::policy(SequenceAwarePolicy)
            .sm_margin(1_000)
            .build()
            .plan(&DecodeShape::llama70b_tp8(1, 512))
            .metadata;
        assert_eq!(md.sm_margin, 1_000);
        let occ = md.occupancy(); // would panic on the seed's subtraction
        assert!((0.0..=1.0).contains(&occ));
    }
}
