//! Scheduler metadata: the launch-path contract between a serving stack and
//! the attention kernel.
//!
//! §5.1 distinguishes two deployment paths:
//!
//! * **Precomputed metadata** (`get_scheduler_metadata()` + explicit
//!   `num_splits`, the vLLM path): the serving engine decides the split
//!   count *before* launch and passes it explicitly. The full 21–24%
//!   improvement applies here — and this is exactly what our rust
//!   coordinator does (`coordinator/scheduler.rs` builds a
//!   [`SchedulerMetadata`] per decode step).
//! * **Internal heuristic** (no metadata): the kernel's own dispatch picks
//!   the split late, yielding only ~1.00–1.05x. The simulator models this
//!   as retaining part of the setup overhead (see `sim/kernel_model.rs`).

use super::tiles::DecodeShape;

/// How the split decision reaches the kernel launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DispatchPath {
    /// vLLM-style: split chosen ahead of launch, combine schedule
    /// specialized. The paper's headline numbers (Table 1).
    PrecomputedMetadata,
    /// Kernel-internal dispatch: late decision, generic combine schedule
    /// (~1.00–1.05x gains per §5.1).
    InternalHeuristic,
}

/// A split-selection policy: standard upstream or the paper's patch (or an
/// evolved candidate from `evolve/`).
pub trait SplitPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Decide `num_splits` for one launch. `num_sm` is the SM budget
    /// (132 - sm_margin on H100); `pack_gqa` selects the GQA layout.
    fn num_splits(&self, shape: &DecodeShape, num_sm: usize, pack_gqa: bool) -> usize;

    /// Produce the full launch metadata (the `get_scheduler_metadata()`
    /// analog).
    fn metadata(&self, shape: &DecodeShape, sm_margin: usize, pack_gqa: bool) -> SchedulerMetadata {
        let num_sm = super::H100_NUM_SMS.saturating_sub(sm_margin).max(1);
        SchedulerMetadata {
            shape: *shape,
            num_splits: self.num_splits(shape, num_sm, pack_gqa),
            pack_gqa,
            sm_margin,
            path: DispatchPath::PrecomputedMetadata,
        }
    }
}

/// Precomputed launch schedule for one decode-attention call — the analog
/// of FA3's `get_scheduler_metadata()` result that inference stacks pass
/// back at launch time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerMetadata {
    pub shape: DecodeShape,
    pub num_splits: usize,
    pub pack_gqa: bool,
    /// SMs reserved for the combine-scheduler CTA (§3.1's `sm_margin` knob).
    pub sm_margin: usize,
    pub path: DispatchPath,
}

impl SchedulerMetadata {
    /// Metadata for a manually-forced split count (the A/B benches and the
    /// Figure 3 sweep pass explicit `num_splits` exactly like the paper's
    /// harness does through the Python bindings).
    pub fn forced(shape: DecodeShape, num_splits: usize) -> SchedulerMetadata {
        assert!(num_splits >= 1);
        SchedulerMetadata {
            shape,
            num_splits,
            pack_gqa: true,
            sm_margin: 0,
            path: DispatchPath::PrecomputedMetadata,
        }
    }

    pub fn with_path(mut self, path: DispatchPath) -> SchedulerMetadata {
        self.path = path;
        self
    }

    /// CTAs this launch puts on the GPU: one per (tile, effective split).
    pub fn grid_ctas(&self) -> usize {
        let eff = super::tiles::SplitGeometry::effective_splits(self.shape.l_k, self.num_splits);
        self.shape.total_mblocks(self.pack_gqa) * eff
    }

    /// SM occupancy fraction this grid achieves in its first wave —
    /// the quantity §2.1 shows collapsing to ~6%.
    pub fn occupancy(&self) -> f64 {
        let sms = (super::H100_NUM_SMS - self.sm_margin).max(1) as f64;
        (self.grid_ctas() as f64 / sms).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::{SequenceAwarePolicy, StandardPolicy};

    #[test]
    fn occupancy_collapse_reproduced() {
        // §2.1: "operating on 8 tiles without sequence splitting translates
        // to an occupancy of ~6%". 8 tiles = e.g. batch 1, H_KV 8.
        let shape = DecodeShape::decode(1, 512, 64, 8, 128);
        let md = StandardPolicy.metadata(&shape, 0, true);
        assert_eq!(md.num_splits, 1);
        assert_eq!(md.grid_ctas(), 8);
        let occ = md.occupancy();
        assert!((0.05..0.07).contains(&occ), "occupancy {occ} should be ~6%");
    }

    #[test]
    fn patched_metadata_raises_ctas_in_target_regime() {
        let shape = DecodeShape::llama70b_tp8(1, 512);
        let std_md = StandardPolicy.metadata(&shape, 0, true);
        let pat_md = SequenceAwarePolicy.metadata(&shape, 0, true);
        assert_eq!(std_md.grid_ctas(), 1);
        assert!(pat_md.grid_ctas() > std_md.grid_ctas());
        assert!(pat_md.occupancy() > std_md.occupancy());
    }

    #[test]
    fn forced_metadata_for_sweeps() {
        let shape = DecodeShape::llama70b_tp8(1, 512);
        let md = SchedulerMetadata::forced(shape, 64);
        assert_eq!(md.num_splits, 64);
        // Over-split: effective splits cap at nblk = 4 CTAs.
        assert_eq!(md.grid_ctas(), 4);
        assert_eq!(md.path, DispatchPath::PrecomputedMetadata);
        let md2 = md.with_path(DispatchPath::InternalHeuristic);
        assert_eq!(md2.path, DispatchPath::InternalHeuristic);
    }

    #[test]
    fn sm_margin_reduces_budget() {
        let shape = DecodeShape::llama70b_tp8(1, 2048);
        let a = StandardPolicy.metadata(&shape, 0, true);
        let b = StandardPolicy.metadata(&shape, 100, true);
        assert_eq!(a.sm_margin, 0);
        assert_eq!(b.sm_margin, 100);
        // Fewer SMs available can only lower (or keep) the chosen splits.
        assert!(b.num_splits <= a.num_splits.max(32));
    }
}
