//! fa3-split CLI — leader entrypoint for the reproduction stack.
//!
//! Subcommands:
//!   serve       end-to-end serving over an ExecutionBackend (pjrt|sim)
//!   cluster     multi-replica, tensor-parallel fleet on the sim clock
//!   table1      reproduce Table 1 (kernel A/B on the simulated H100)
//!   ucurve      reproduce Figure 3 (split sweep s = 1..64)
//!   regression  reproduce §5.3 (160-config safety sweep)
//!   evolve      reproduce §3 (evolutionary search, OpenEvolve analog)
//!   decide      print every registered policy's decision for one shape
//!   policies    list the policies in the planner registry
//!   lint        pallas-lint: source passes + plan-space model checker
//!   info        artifact/manifest inventory
//!
//! All split planning goes through `planner::PolicyRegistry` /
//! `planner::Planner`; the `--policy`, `--device`, and `--router` options
//! accept any registered policy name, device-profile preset, and cluster
//! routing policy — unknown values fail with the full list of valid names
//! (driven from the registries, never hardcoded).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Context;

use fa3_split::backend::{AttnGeometry, ExecutionBackend, PjrtBackend, SimBackend};
use fa3_split::bench_harness::{regression, table1, ucurve};
use fa3_split::cluster::{self, ClusterTopology, Fleet, FleetConfig, TpConfig};
use fa3_split::coordinator::{
    BatcherConfig, Engine, EngineConfig, ResumePolicy, SloConfig, StreamEvent, SubmitOptions,
};
use fa3_split::evolve::{Search, SearchConfig};
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::obs;
use fa3_split::planner::{DeviceProfile, Planner, PolicyRegistry};
use fa3_split::runtime::Registry;
use fa3_split::schedule::{ScheduleConfig, TokenBudget};
use fa3_split::sim::Simulator;
use fa3_split::util::cli;
use fa3_split::workload::ChatWorkload;

const USAGE: &str = "fa3-split — sequence-aware split heuristic reproduction

Usage: fa3-split <command> [options]

Commands:
  serve        serve a synthetic chat workload (--backend pjrt|sim)
  cluster      simulate a multi-replica tensor-parallel serving fleet
  table1       reproduce Table 1 (A/B kernel test, simulated H100)
  ucurve       reproduce Figure 3 (split sweep s=1..64)
  regression   reproduce §5.3 (160-config regression sweep)
  evolve       reproduce §3 (evolutionary heuristic search)
  decide       show every registered policy's split decision for a shape
  policies     list registered split policies
  lint         static analysis + plan-space invariant verification
  info         list artifacts and model config

Run `fa3-split <command> --help` for per-command options.";

fn artifacts_dir() -> PathBuf {
    std::env::var("FA3_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let Some(command) = argv.get(1).cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    // Re-split argv for the subcommand parsers (skip the command token).
    let sub_argv: Vec<String> =
        std::iter::once(format!("fa3-split {command}")).chain(argv[2..].iter().cloned()).collect();

    match command.as_str() {
        "serve" => cmd_serve(&sub_argv),
        "cluster" => cmd_cluster(&sub_argv),
        "table1" => cmd_table1(&sub_argv),
        "ucurve" => cmd_ucurve(&sub_argv),
        "regression" => cmd_regression(&sub_argv),
        "evolve" => cmd_evolve(&sub_argv),
        "decide" => cmd_decide(&sub_argv),
        "lint" => cmd_lint(&sub_argv),
        "policies" => cmd_policies(),
        "info" => cmd_info(),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn parse(p: cli::Parser, argv: &[String]) -> cli::Args {
    match p.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

/// Resolve `--device` against the preset table, exiting with the full
/// preset listing on an unknown name.
fn device_from_args(args: &cli::Args) -> DeviceProfile {
    let device_name = args.str("device");
    match DeviceProfile::by_name(&device_name) {
        Some(device) => device,
        None => {
            eprintln!("unknown device '{device_name}' (known: {})", DeviceProfile::help_line());
            std::process::exit(2);
        }
    }
}

/// Resolve `--policy` / `--device` / `--sm-margin` into a configured
/// planner via the registry (exits with the registry's name listing on an
/// unknown policy or device).
fn planner_from_args(registry: &PolicyRegistry, args: &cli::Args) -> Planner {
    let device = device_from_args(args);
    match registry.builder_for(&args.str("policy"), &device) {
        Ok(builder) => builder.sm_margin(args.usize("sm-margin")).build(),
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

/// Resolve `--chunk-tokens` / `--max-batch-tokens` into a
/// [`ScheduleConfig`], exiting with the valid ranges on a bad value
/// (mirrors the policy/device/router listing idiom: the message names
/// every acceptable value, never just "invalid").
fn schedule_from_args(args: &cli::Args, max_seq: usize, max_batch: usize) -> ScheduleConfig {
    let chunk = args.usize("chunk-tokens");
    let budget = args.usize("max-batch-tokens");
    if chunk > max_seq {
        eprintln!(
            "invalid --chunk-tokens {chunk} (valid: 0 (monolithic prefill) or 1..={max_seq})"
        );
        std::process::exit(2);
    }
    if chunk == 0 {
        if budget > 0 {
            eprintln!(
                "--max-batch-tokens {budget} requires --chunk-tokens > 0 \
                 (valid: 0 (unbounded) under monolithic prefill)"
            );
            std::process::exit(2);
        }
        return ScheduleConfig::default();
    }
    let floor = chunk.max(max_batch);
    if budget > 0 && budget < floor {
        eprintln!(
            "invalid --max-batch-tokens {budget} (valid: 0 (unbounded) or \
             >= {floor} = max(--chunk-tokens, max running batch))"
        );
        std::process::exit(2);
    }
    let budget =
        if budget == 0 { TokenBudget::unbounded() } else { TokenBudget::capped(budget) };
    ScheduleConfig::bounded(chunk, budget)
}

fn cmd_serve(argv: &[String]) -> anyhow::Result<()> {
    let registry = PolicyRegistry::builtin();
    let args = parse(
        cli::Parser::new("serve a synthetic chat workload over an execution backend")
            .opt("backend", "pjrt", "execution backend: pjrt (AOT artifacts) | sim (H100 model)")
            .opt("requests", "8", "number of requests")
            .opt("tokens", "32", "max new tokens per request")
            .opt("policy", "sequence-aware", format!("split policy: {}", registry.help_line()))
            .opt("device", "h100-sxm", format!("device profile: {}", DeviceProfile::help_line()))
            .opt("sm-margin", "0", "SMs reserved for the combine scheduler")
            .opt("prefix", "0", "shared system-prompt length, tokens, additive to the sampled prompt (0 = off)")
            .opt("prefix-fanout", "4", "requests per distinct system prompt (1 = disjoint)")
            .opt("chunk-tokens", "0", "prefill chunk size, tokens (0 = monolithic prefill)")
            .opt("max-batch-tokens", "0", "per-step token budget across chunk+decode rows (0 = unbounded; requires --chunk-tokens)")
            .opt("gap-us", "0", "mean Poisson inter-arrival gap, µs (0 = closed loop; requires --backend sim)")
            .flag("mixed", "mixed open-loop trace: 3/4 short interactive + 1/4 long-prompt batch requests (requires --backend sim)")
            .opt("arrivals", "poisson", "mixed-trace arrival process: poisson | flash-crowd | diurnal (requires --mixed)")
            .opt("preemption", "off", "priority preemption of running requests: on | off")
            .opt("resume", "auto", "preempted-request resume path: auto (modeled-cost pick) | swap | recompute")
            .opt("preempt-budget", "1", "max preemptions per engine step (>= 1)")
            .flag("slo", "per-class SLO goodput accounting with default TTFT/TPOT targets (sheds hopeless queued requests)")
            .opt("trace-out", "", "write a Chrome trace-event JSON here (open in chrome://tracing or Perfetto)")
            .opt("trace-capacity", "65536", "flight-recorder ring capacity, events (ring keeps the most recent window)")
            .opt("metrics-out", "", "write Prometheus text-format metrics here")
            .opt("seed", "7", "workload seed"),
        argv,
    );
    let planner = planner_from_args(&registry, &args);
    let mut cfg = EngineConfig::default();
    cfg.schedule = schedule_from_args(&args, 1024, cfg.batcher.max_batch);
    match args.str("preemption").as_str() {
        "on" => cfg.preemption.enabled = true,
        "off" => {}
        other => {
            eprintln!("invalid --preemption '{other}' (valid: on, off)");
            std::process::exit(2);
        }
    }
    let resume_name = args.str("resume");
    match ResumePolicy::parse(&resume_name) {
        Some(p) => cfg.preemption.resume = p,
        None => {
            eprintln!("invalid --resume '{resume_name}' (valid: auto, swap, recompute)");
            std::process::exit(2);
        }
    }
    let preempt_budget = args.usize("preempt-budget");
    if preempt_budget == 0 {
        eprintln!("invalid --preempt-budget 0 (valid: >= 1)");
        std::process::exit(2);
    }
    cfg.preemption.max_per_step = preempt_budget;
    if args.has("slo") {
        cfg.slo = Some(SloConfig::default());
    }
    // Tracing is opt-in: the recorder stays a capacity-0 no-op unless a
    // trace is actually being written.
    if !args.str("trace-out").is_empty() {
        cfg.trace_capacity = args.usize("trace-capacity");
    }

    // Resolve the backend behind the trait: nothing below this point
    // branches on sim vs PJRT.
    let backend_name = args.str("backend");
    let mut builder = match backend_name.as_str() {
        "pjrt" => {
            let dir = artifacts_dir();
            anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
            let pjrt = Arc::new(Registry::open(&dir)?);
            let backend: Box<dyn ExecutionBackend> =
                Box::new(PjrtBackend::new(pjrt, cfg.batcher.max_batch)?);
            Engine::builder(backend)
        }
        "sim" => Engine::builder(Box::new(SimBackend::h100()))
            .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
            .available_splits(vec![1, 3]),
        other => {
            eprintln!("unknown backend '{other}' (known: pjrt, sim)");
            std::process::exit(2);
        }
    };
    builder = builder.planner(planner).config(cfg);
    let mut engine = builder.build()?;

    let mixed = args.has("mixed");
    let gap_us = args.u64("gap-us");
    let open_loop = mixed || gap_us > 0;
    if open_loop && !engine.backend_caps().virtual_clock {
        eprintln!(
            "--mixed / --gap-us replay arrivals on the virtual clock \
             (valid only with --backend sim)"
        );
        std::process::exit(2);
    }
    let arrivals = args.str("arrivals");
    if arrivals != "poisson" && !mixed {
        eprintln!("--arrivals {arrivals} warps the mixed trace (requires --mixed)");
        std::process::exit(2);
    }
    let stream = if mixed {
        // The mixed trace carries its own per-class prompt/output shapes;
        // --tokens/--prefix only apply to the homogeneous workload.
        let (seed, n) = (args.u64("seed"), args.usize("requests"));
        match arrivals.as_str() {
            "poisson" => ChatWorkload::mixed_open_loop(seed, n, gap_us),
            "flash-crowd" => ChatWorkload::flash_crowd(seed, n, gap_us, 4),
            "diurnal" => ChatWorkload::diurnal(seed, n, gap_us, 50_000),
            other => {
                eprintln!(
                    "unknown arrival process '{other}' (known: poisson, flash-crowd, diurnal)"
                );
                std::process::exit(2);
            }
        }
    } else {
        ChatWorkload {
            seed: args.u64("seed"),
            n_requests: args.usize("requests"),
            output_mean: args.usize("tokens"),
            output_cap: args.usize("tokens"),
            mean_gap_us: gap_us,
            shared_prefix_len: args.usize("prefix"),
            prefix_fanout: args.usize("prefix-fanout").max(1),
            ..Default::default()
        }
        .generate()
    };
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for g in stream {
        let mut r = g.request;
        if !mixed {
            r.max_new_tokens = args.usize("tokens");
        }
        let opts = SubmitOptions::default().priority(g.priority);
        let submitted = if open_loop {
            engine.submit_at_with(r, g.arrival_offset_us, opts)
        } else {
            engine.submit_with(r, opts)
        };
        match submitted {
            Ok(h) => handles.push(h),
            Err(e) => eprintln!("request refused: {e}"),
        }
    }
    let done = engine.run_until_idle()?;
    if !engine.backend_caps().virtual_clock {
        engine.metrics.wall_us = t0.elapsed().as_micros() as u64;
    }
    println!(
        "policy '{}' on '{}': served {} requests in {:.2}s",
        engine.policy_name(),
        engine.backend_caps().name,
        done.len(),
        t0.elapsed().as_secs_f64()
    );
    print!("{}", engine.metrics.report());
    // Each handle streamed its tokens as they decoded.
    let streamed: usize = handles
        .iter()
        .map(|h| {
            std::iter::from_fn(|| h.try_event())
                .filter(|ev| matches!(ev, StreamEvent::Token { .. }))
                .count()
        })
        .sum();
    println!("streamed {streamed} tokens across {} request handles", handles.len());
    let trace_out = args.str("trace-out");
    if !trace_out.is_empty() {
        let label = format!("engine ({})", engine.backend_caps().name);
        let trace = obs::engine_trace(engine.recorder(), &label);
        std::fs::write(&trace_out, trace.to_string())
            .with_context(|| format!("writing {trace_out}"))?;
        println!(
            "wrote Chrome trace to {trace_out} ({} events, {} dropped)",
            engine.recorder().len(),
            engine.recorder().dropped()
        );
    }
    let metrics_out = args.str("metrics-out");
    if !metrics_out.is_empty() {
        std::fs::write(&metrics_out, engine.metrics.to_prometheus())
            .with_context(|| format!("writing {metrics_out}"))?;
        println!("wrote Prometheus metrics to {metrics_out}");
    }
    Ok(())
}

fn cmd_cluster(argv: &[String]) -> anyhow::Result<()> {
    let registry = PolicyRegistry::builtin();
    let args = parse(
        cli::Parser::new(
            "simulate a multi-replica tensor-parallel fleet (each replica = one TP group \
             planning the sharded shape)",
        )
        .opt("replicas", "2", "fleet size (number of TP groups)")
        .opt("tp", "8", "tensor-parallel degree (must divide the model's head counts)")
        .opt("hkv", "8", "full-model KV heads (H_Q = 8*H_KV, Llama-70B-style GQA)")
        .opt("device", "h100-sxm", format!("device profile: {}", DeviceProfile::help_line()))
        .opt("router", "least-loaded", format!("routing policy: {}", cluster::router::help_line()))
        .opt("roles", "colocated", "replica roles: colocated | split (prefill/decode pools; requires --router disaggregated)")
        .opt("xfer", "nvlink", format!("cross-pool KV interconnect: {}", cluster::topology::Interconnect::help_line()))
        .opt("policy", "sequence-aware", format!("split policy: {}", registry.help_line()))
        .opt("requests", "16", "number of requests")
        .opt("tokens", "64", "max new tokens per request")
        .opt("prompt-median", "420", "median prompt length (the paper's heavy-decode regime)")
        .opt("turns", "1", "requests per chat session (the session-affinity unit)")
        .opt("gap-us", "0", "mean Poisson inter-arrival gap, µs (0 = closed loop)")
        .opt("max-batch", "2", "per-replica max running batch")
        .opt("chunk-tokens", "0", "prefill chunk size, tokens (0 = monolithic prefill)")
        .opt("max-batch-tokens", "0", "per-step token budget across chunk+decode rows (0 = unbounded; requires --chunk-tokens)")
        .opt("prefix", "0", "shared system-prompt length, tokens, additive to the sampled prompt (0 = off)")
        .opt("prefix-fanout", "4", "requests per distinct system prompt (1 = disjoint)")
        .opt("preemption", "off", "per-replica priority preemption: on | off")
        .flag("slo", "per-replica SLO goodput accounting with default TTFT/TPOT targets")
        .opt("trace-out", "", "write a merged per-replica Chrome trace-event JSON here")
        .opt("trace-capacity", "65536", "per-replica flight-recorder ring capacity, events")
        .opt("metrics-out", "", "write per-replica Prometheus text-format metrics here")
        .opt("seed", "7", "workload seed"),
        argv,
    );
    let device = device_from_args(&args);
    let router_name = args.str("router");
    let Some(router) = cluster::router::by_name(&router_name) else {
        eprintln!(
            "unknown router '{router_name}' (known: {})",
            cluster::router::help_line()
        );
        std::process::exit(2);
    };
    // Resolve the policy up front so an unknown name fails with the
    // registry's listing before any replica is built.
    if let Err(msg) = registry.source_for(&args.str("policy"), &device) {
        eprintln!("{msg}");
        std::process::exit(2);
    }

    // `--roles`/`--xfer` get the same listed-names exit(2) treatment as
    // `--router`/`--policy`: typos die before any replica is built.
    let roles_name = args.str("roles");
    let split = match roles_name.as_str() {
        "colocated" => false,
        "split" => true,
        other => {
            eprintln!("unknown roles '{other}' (known: colocated|split)");
            std::process::exit(2);
        }
    };
    let xfer_name = args.str("xfer");
    let Some(interconnect) = cluster::topology::Interconnect::by_name(&xfer_name) else {
        eprintln!(
            "unknown interconnect '{xfer_name}' (known: {})",
            cluster::topology::Interconnect::help_line()
        );
        std::process::exit(2);
    };

    let h_kv = args.usize("hkv");
    let model = AttnGeometry { h_q: 8 * h_kv, h_kv, d: 128, max_seq: 1024 };
    let n_replicas = args.usize("replicas");
    let mut builder = ClusterTopology::builder(model)
        .tp(TpConfig::new(args.usize("tp")))
        .interconnect(interconnect);
    if split {
        // Equal-device split: half the fleet prefills (at least one
        // replica), the rest decodes. `build()` rejects a pool-less side
        // (e.g. --replicas 1) with its MissingPool error.
        let prefill = (n_replicas / 2).max(1);
        let decode = n_replicas.saturating_sub(prefill);
        builder = builder
            .pool(prefill, device, cluster::ReplicaRole::Prefill)
            .pool(decode, device, cluster::ReplicaRole::Decode);
    } else {
        builder = builder.replicas(n_replicas, device);
    }
    let topology = builder.build().map_err(|e| anyhow::anyhow!("invalid topology: {e}"))?;

    let trace_out = args.str("trace-out");
    let mut engine_cfg = EngineConfig {
        batcher: BatcherConfig::for_max_batch(args.usize("max-batch")),
        schedule: schedule_from_args(&args, 1024, args.usize("max-batch")),
        trace_capacity: if trace_out.is_empty() { 0 } else { args.usize("trace-capacity") },
        ..Default::default()
    };
    match args.str("preemption").as_str() {
        "on" => engine_cfg.preemption.enabled = true,
        "off" => {}
        other => {
            eprintln!("invalid --preemption '{other}' (valid: on, off)");
            std::process::exit(2);
        }
    }
    if args.has("slo") {
        engine_cfg.slo = Some(SloConfig::default());
    }
    let mut fleet = Fleet::new(
        topology,
        router,
        FleetConfig::default().policy(args.str("policy")).engine(engine_cfg),
    )?;

    let workload = ChatWorkload {
        seed: args.u64("seed"),
        n_requests: args.usize("requests"),
        prompt_median: args.usize("prompt-median"),
        output_mean: args.usize("tokens"),
        output_cap: args.usize("tokens"),
        mean_gap_us: args.u64("gap-us"),
        turns_per_session: args.usize("turns").max(1),
        shared_prefix_len: args.usize("prefix"),
        prefix_fanout: args.usize("prefix-fanout").max(1),
        ..Default::default()
    };
    let report = fleet.run(&workload.generate())?;
    print!("{}", report.render());
    if args.usize("prefix") > 0 {
        for r in fleet.replicas() {
            let p = r.metrics().prefix;
            if p.lookups > 0 {
                println!(
                    "replica {} prefix cache: hit-rate {:.1}%, saved {} blocks / {} tokens",
                    r.index(),
                    p.hit_rate() * 100.0,
                    p.blocks_saved(),
                    p.tokens_cached
                );
            }
        }
    }
    if !trace_out.is_empty() {
        let events: usize = fleet.replicas().iter().map(|r| r.recorder().len()).sum();
        std::fs::write(&trace_out, fleet.chrome_trace().to_string())
            .with_context(|| format!("writing {trace_out}"))?;
        println!("wrote merged Chrome trace to {trace_out} ({events} events across replicas)");
    }
    let metrics_out = args.str("metrics-out");
    if !metrics_out.is_empty() {
        std::fs::write(&metrics_out, fleet.prometheus())
            .with_context(|| format!("writing {metrics_out}"))?;
        println!("wrote Prometheus metrics to {metrics_out}");
    }
    Ok(())
}

fn cmd_table1(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("Table 1 A/B on the simulated H100")
            .opt("replays", "501", "interleaved replays per cell")
            .opt("seed", "43777", "noise seed"),
        argv,
    );
    let cells = table1::run(&Simulator::h100(), args.usize("replays"), args.u64("seed"));
    print!("{}", table1::render(&cells));
    table1::verify(&cells).map_err(|e| anyhow::anyhow!(e))?;
    println!("OK");
    Ok(())
}

fn cmd_ucurve(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("Figure 3 split sweep")
            .opt("replays", "301", "replays per point")
            .opt("seed", "61795", "noise seed"),
        argv,
    );
    let points = ucurve::run(&Simulator::h100(), args.usize("replays"), args.u64("seed"));
    print!("{}", ucurve::render_table(&points));
    println!("{}", ucurve::render_plot(&points, 14));
    ucurve::verify(&points).map_err(|e| anyhow::anyhow!(e))?;
    println!("OK");
    Ok(())
}

fn cmd_regression(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("§5.3 regression sweep")
            .opt("replays", "201", "replays per cell")
            .opt("seed", "24147", "noise seed"),
        argv,
    );
    let cells = regression::run(&Simulator::h100(), args.usize("replays"), args.u64("seed"));
    print!("{}", regression::render(&cells));
    regression::verify(&cells).map_err(|e| anyhow::anyhow!(e))?;
    println!("OK");
    Ok(())
}

fn cmd_evolve(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("§3 evolutionary heuristic search")
            .opt("generations", "30", "EA generations")
            .opt("population", "48", "population size")
            .opt("seed", "58113", "search seed"),
        argv,
    );
    let cfg = SearchConfig {
        seed: args.u64("seed"),
        population: args.usize("population"),
        generations: args.usize("generations"),
        ..Default::default()
    };
    let report = Search::new(cfg, Simulator::h100()).run(|g| {
        println!(
            "gen {:>3}: best {:.3} µs, mean(valid) {:.3} µs, rejected {}",
            g.generation, g.best_tpot_us, g.mean_valid_tpot_us, g.rejected
        );
    });
    println!("\nspeedup over upstream: {:.3}x\n", report.speedup());
    println!("{}", report.best.render_python());
    Ok(())
}

fn cmd_decide(argv: &[String]) -> anyhow::Result<()> {
    let registry = PolicyRegistry::builtin();
    let args = parse(
        cli::Parser::new("show every registered policy's decision for one decode shape")
            .opt("batch", "1", "batch size")
            .opt("lk", "512", "sequence length L_K")
            .opt("hkv", "1", "KV heads (H_Q = 8*H_KV)")
            .opt("d", "128", "head dim")
            .opt("device", "h100-sxm", format!("device profile: {}", DeviceProfile::help_line()))
            .opt("sm-margin", "0", "SMs reserved for the combine scheduler"),
        argv,
    );
    let shape = DecodeShape::decode(
        args.usize("batch"),
        args.usize("lk"),
        8 * args.usize("hkv"),
        args.usize("hkv"),
        args.usize("d"),
    );
    let device = device_from_args(&args);
    let sim = Simulator::for_profile(&device);
    println!(
        "shape: B={} L_K={} H_Q={} H_KV={} D={} -> nblk={}, tiles={}  (device: {}, {} SMs)",
        shape.batch,
        shape.l_k,
        shape.h_q,
        shape.h_kv,
        shape.d,
        shape.nblk(),
        shape.total_mblocks(true),
        device.name,
        device.num_sms,
    );
    let mut names = registry.names();
    names.reverse(); // ladder order: standard first
    for name in names {
        let mut planner = registry
            .builder_for(name, &device)
            .map_err(|e| anyhow::anyhow!(e))?
            .sm_margin(args.usize("sm-margin"))
            .build();
        let plan = planner.plan(&shape);
        let t = sim.kernel(&plan.metadata);
        println!(
            "  {name:<15} s={:<3} ctas={:<4} occupancy={:>5.1}%  \
             est.combine {:>4.2} µs  sim latency {:.2} µs",
            plan.num_splits(),
            plan.grid_ctas,
            plan.occupancy * 100.0,
            plan.combine_estimate_us,
            t.total_us
        );
    }
    Ok(())
}

fn cmd_lint(argv: &[String]) -> anyhow::Result<()> {
    use fa3_split::analysis::{self, fixtures, LintOptions, ModelCheckConfig};

    let args = parse(
        cli::Parser::new(
            "pallas-lint: source-tree passes (layering, no_alloc, struct_ripple, \
             bench_manifest) + plan-space model checker",
        )
        .flag("json", "print the findings report as JSON to stdout")
        .flag("quick", "reduced model-check domain (seconds even in debug builds)")
        .flag("no-modelcheck", "skip the plan-space model checker entirely")
        .flag("fixtures", "also run the seeded-violation fixture corpus (lint self-test)")
        .opt("out", "", "also write the JSON report to this path")
        .opt("root", "", "repo root to lint (default: this crate's parent directory)"),
        argv,
    );

    let root = if args.str("root").is_empty() {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."))
    } else {
        PathBuf::from(args.str("root"))
    };
    let mut opts = LintOptions::at_repo_root(&root);
    if args.has("no-modelcheck") {
        opts.modelcheck = None;
    } else if args.has("quick") {
        opts.modelcheck = Some(ModelCheckConfig::quick());
    }

    let mut report = analysis::run(&opts)?;
    if args.has("fixtures") {
        fixtures::verify(&mut report.findings);
    }

    let json = report.to_json().to_string_pretty();
    let out = args.str("out");
    if !out.is_empty() {
        std::fs::write(&out, format!("{json}\n"))?;
    }
    if args.has("json") {
        println!("{json}");
    } else {
        for f in &report.findings {
            println!("{}", f.render());
        }
        let s = &report.source;
        println!(
            "scanned {} files ({} struct defs, {} literal sites, {} use edges, \
             {} no_alloc regions, {} suppressed)",
            s.files_scanned,
            s.struct_defs,
            s.literal_sites,
            s.use_edges,
            s.no_alloc_regions,
            s.suppressed
        );
        if let Some(mc) = &report.modelcheck {
            println!(
                "model checker: domain {} (no-regression pairs {}), violations {}",
                mc.get("total_domain").to_string_pretty(),
                mc.get("no_regression_domain").to_string_pretty(),
                mc.get("violations").to_string_pretty()
            );
        }
        println!("{} error(s), {} warning(s)", report.errors(), report.warnings());
    }
    if !report.clean() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_policies() -> anyhow::Result<()> {
    let registry = PolicyRegistry::builtin();
    println!("registered split policies:\n{}", registry.describe());
    println!("device profiles:");
    for p in DeviceProfile::presets() {
        println!(
            "  {:<12} {} SMs, {:.0} GB/s HBM, split cap {}",
            p.name, p.num_sms, p.hbm_bw_gbps, p.max_splits
        );
    }
    println!("cluster routers: {}", cluster::router::help_line());
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    let reg = Registry::open(&dir)?;
    let m = &reg.manifest;
    println!("artifacts dir: {}", dir.display());
    println!("{} artifacts:", m.entries.len());
    for e in &m.entries {
        println!(
            "  [{:?}] {} ({} inputs, {} outputs)",
            e.kind,
            e.name,
            e.inputs.len(),
            e.outputs.len()
        );
    }
    if let Some(model) = &m.model {
        let c = &model.config;
        println!(
            "model: preset '{}' — {} layers, d_model {}, H_Q {}, H_KV {}, D {}, vocab {}, {:.1}M params",
            model.preset,
            c.n_layers,
            c.d_model,
            c.n_heads_q,
            c.n_heads_kv,
            c.head_dim,
            c.vocab,
            c.n_params as f64 / 1e6
        );
    }
    Ok(())
}
