//! fa3-split CLI — leader entrypoint for the reproduction stack.
//!
//! Subcommands:
//!   serve       end-to-end serving over the AOT artifacts (PJRT CPU)
//!   table1      reproduce Table 1 (kernel A/B on the simulated H100)
//!   ucurve      reproduce Figure 3 (split sweep s = 1..64)
//!   regression  reproduce §5.3 (160-config safety sweep)
//!   evolve      reproduce §3 (evolutionary search, OpenEvolve analog)
//!   decide      print both heuristics' decisions for one shape
//!   info        artifact/manifest inventory

use std::path::PathBuf;
use std::sync::Arc;

use fa3_split::bench_harness::{regression, table1, ucurve};
use fa3_split::coordinator::{Engine, EngineConfig};
use fa3_split::evolve::{Search, SearchConfig};
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::heuristics::{SequenceAwarePolicy, SplitPolicy, StandardPolicy};
use fa3_split::runtime::Registry;
use fa3_split::sim::Simulator;
use fa3_split::util::cli;
use fa3_split::workload::ChatWorkload;

const USAGE: &str = "fa3-split — sequence-aware split heuristic reproduction

Usage: fa3-split <command> [options]

Commands:
  serve        serve a synthetic chat workload over the AOT artifacts
  table1       reproduce Table 1 (A/B kernel test, simulated H100)
  ucurve       reproduce Figure 3 (split sweep s=1..64)
  regression   reproduce §5.3 (160-config regression sweep)
  evolve       reproduce §3 (evolutionary heuristic search)
  decide       show both policies' split decision for a shape
  info         list artifacts and model config

Run `fa3-split <command> --help` for per-command options.";

fn artifacts_dir() -> PathBuf {
    std::env::var("FA3_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let Some(command) = argv.get(1).cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    // Re-split argv for the subcommand parsers (skip the command token).
    let sub_argv: Vec<String> =
        std::iter::once(format!("fa3-split {command}")).chain(argv[2..].iter().cloned()).collect();

    match command.as_str() {
        "serve" => cmd_serve(&sub_argv),
        "table1" => cmd_table1(&sub_argv),
        "ucurve" => cmd_ucurve(&sub_argv),
        "regression" => cmd_regression(&sub_argv),
        "evolve" => cmd_evolve(&sub_argv),
        "decide" => cmd_decide(&sub_argv),
        "info" => cmd_info(),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn parse(p: cli::Parser, argv: &[String]) -> cli::Args {
    match p.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn policy_by_name(name: &str) -> Box<dyn SplitPolicy> {
    match name {
        "standard" => Box::new(StandardPolicy),
        "patched" | "sequence-aware" => Box::new(SequenceAwarePolicy),
        other => {
            eprintln!("unknown policy '{other}' (use standard|patched)");
            std::process::exit(2);
        }
    }
}

fn cmd_serve(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("serve a synthetic chat workload over the AOT artifacts")
            .opt("requests", "8", "number of requests")
            .opt("tokens", "32", "max new tokens per request")
            .opt("policy", "patched", "split policy: standard|patched")
            .opt("seed", "7", "workload seed"),
        argv,
    );
    let dir = artifacts_dir();
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    let registry = Arc::new(Registry::open(&dir)?);
    let mut engine = Engine::with_pjrt(
        registry,
        policy_by_name(&args.str("policy")),
        EngineConfig::default(),
    )?;
    let workload = ChatWorkload {
        seed: args.u64("seed"),
        n_requests: args.usize("requests"),
        output_mean: args.usize("tokens"),
        output_cap: args.usize("tokens"),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    for g in workload.generate() {
        let mut r = g.request;
        r.max_new_tokens = args.usize("tokens");
        engine.submit(r);
    }
    let done = engine.run_until_idle()?;
    engine.metrics.wall_us = t0.elapsed().as_micros() as u64;
    println!(
        "policy '{}': served {} requests in {:.2}s",
        engine.policy_name(),
        done.len(),
        t0.elapsed().as_secs_f64()
    );
    print!("{}", engine.metrics.report());
    Ok(())
}

fn cmd_table1(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("Table 1 A/B on the simulated H100")
            .opt("replays", "501", "interleaved replays per cell")
            .opt("seed", "43777", "noise seed"),
        argv,
    );
    let cells = table1::run(&Simulator::h100(), args.usize("replays"), args.u64("seed"));
    print!("{}", table1::render(&cells));
    table1::verify(&cells).map_err(|e| anyhow::anyhow!(e))?;
    println!("OK");
    Ok(())
}

fn cmd_ucurve(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("Figure 3 split sweep")
            .opt("replays", "301", "replays per point")
            .opt("seed", "61795", "noise seed"),
        argv,
    );
    let points = ucurve::run(&Simulator::h100(), args.usize("replays"), args.u64("seed"));
    print!("{}", ucurve::render_table(&points));
    println!("{}", ucurve::render_plot(&points, 14));
    ucurve::verify(&points).map_err(|e| anyhow::anyhow!(e))?;
    println!("OK");
    Ok(())
}

fn cmd_regression(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("§5.3 regression sweep")
            .opt("replays", "201", "replays per cell")
            .opt("seed", "24147", "noise seed"),
        argv,
    );
    let cells = regression::run(&Simulator::h100(), args.usize("replays"), args.u64("seed"));
    print!("{}", regression::render(&cells));
    regression::verify(&cells).map_err(|e| anyhow::anyhow!(e))?;
    println!("OK");
    Ok(())
}

fn cmd_evolve(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("§3 evolutionary heuristic search")
            .opt("generations", "30", "EA generations")
            .opt("population", "48", "population size")
            .opt("seed", "58113", "search seed"),
        argv,
    );
    let cfg = SearchConfig {
        seed: args.u64("seed"),
        population: args.usize("population"),
        generations: args.usize("generations"),
        ..Default::default()
    };
    let report = Search::new(cfg, Simulator::h100()).run(|g| {
        println!(
            "gen {:>3}: best {:.3} µs, mean(valid) {:.3} µs, rejected {}",
            g.generation, g.best_tpot_us, g.mean_valid_tpot_us, g.rejected
        );
    });
    println!("\nspeedup over upstream: {:.3}x\n", report.speedup());
    println!("{}", report.best.render_python());
    Ok(())
}

fn cmd_decide(argv: &[String]) -> anyhow::Result<()> {
    let args = parse(
        cli::Parser::new("show both policies' decision for one decode shape")
            .opt("batch", "1", "batch size")
            .opt("lk", "512", "sequence length L_K")
            .opt("hkv", "1", "KV heads (H_Q = 8*H_KV)")
            .opt("d", "128", "head dim"),
        argv,
    );
    let shape = DecodeShape::decode(
        args.usize("batch"),
        args.usize("lk"),
        8 * args.usize("hkv"),
        args.usize("hkv"),
        args.usize("d"),
    );
    let sim = Simulator::h100();
    println!(
        "shape: B={} L_K={} H_Q={} H_KV={} D={} -> nblk={}, tiles={}",
        shape.batch,
        shape.l_k,
        shape.h_q,
        shape.h_kv,
        shape.d,
        shape.nblk(),
        shape.total_mblocks(true)
    );
    for (name, md) in [
        ("standard", StandardPolicy.metadata(&shape, 0, true)),
        ("sequence-aware", SequenceAwarePolicy.metadata(&shape, 0, true)),
    ] {
        let t = sim.kernel(&md);
        println!(
            "  {name:<15} s={:<3} ctas={:<4} occupancy={:>5.1}%  sim latency {:.2} µs",
            md.num_splits,
            t.active_ctas,
            t.occupancy * 100.0,
            t.total_us
        );
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    let reg = Registry::open(&dir)?;
    let m = &reg.manifest;
    println!("artifacts dir: {}", dir.display());
    println!("{} artifacts:", m.entries.len());
    for e in &m.entries {
        println!(
            "  [{:?}] {} ({} inputs, {} outputs)",
            e.kind,
            e.name,
            e.inputs.len(),
            e.outputs.len()
        );
    }
    if let Some(model) = &m.model {
        let c = &model.config;
        println!(
            "model: preset '{}' — {} layers, d_model {}, H_Q {}, H_KV {}, D {}, vocab {}, {:.1}M params",
            model.preset,
            c.n_layers,
            c.d_model,
            c.n_heads_q,
            c.n_heads_kv,
            c.head_dim,
            c.vocab,
            c.n_params as f64 / 1e6
        );
    }
    Ok(())
}
