//! Artifact manifest: the contract written by python/compile/aot.py.
//!
//! `artifacts/manifest.json` indexes every AOT-lowered HLO module with its
//! full input/output signature plus the model's parameter table (the
//! positional weights ABI). This module parses and validates it; it does
//! not touch PJRT (that's [`super::registry`]).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::tensor::DType;

/// Manifest schema version this runtime understands.
pub const SUPPORTED_VERSION: i64 = 2;

/// What a compiled artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Standalone decode-attention kernel: `(q, k, v, kv_lens) -> out`.
    Kernel,
    /// Model decode step:
    /// `(tokens, positions, kv_k, kv_v, *params) -> (logits, kv_k, kv_v)`.
    Decode,
    /// Model prefill:
    /// `(tokens, kv_lens, kv_k, kv_v, *params) -> (logits, kv_k, kv_v)`.
    Prefill,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<ArtifactKind> {
        match s {
            "kernel" => Ok(ArtifactKind::Kernel),
            "decode" => Ok(ArtifactKind::Decode),
            "prefill" => Ok(ArtifactKind::Prefill),
            other => bail!("unknown artifact kind '{other}'"),
        }
    }
}

/// Shape + dtype of one input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    fn parse(j: &Json) -> Result<TensorSig> {
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|d| d.as_usize().context("non-integer dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSig { shape, dtype: DType::parse(j.req_str("dtype")?)? })
    }

    /// Total element count of this tensor spec.
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Metadata of one artifact (kernel shape parameters or model buckets).
#[derive(Debug, Clone, Default)]
pub struct ArtifactMeta {
    pub batch: Option<usize>,
    pub l_k: Option<usize>,
    pub h_q: Option<usize>,
    pub h_kv: Option<usize>,
    pub d: Option<usize>,
    pub num_splits: Option<usize>,
    pub prompt_len: Option<usize>,
    pub max_seq: Option<usize>,
    pub group: Option<String>,
}

impl ArtifactMeta {
    fn parse(j: &Json) -> ArtifactMeta {
        let u = |k: &str| j.get(k).as_usize();
        ArtifactMeta {
            batch: u("batch"),
            l_k: u("l_k"),
            h_q: u("h_q"),
            h_kv: u("h_kv"),
            d: u("d"),
            num_splits: u("num_splits"),
            prompt_len: u("prompt_len"),
            max_seq: u("max_seq"),
            group: j.get("group").as_str().map(|s| s.to_string()),
        }
    }
}

/// One compiled-artifact description.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: ArtifactKind,
    pub hlo_path: PathBuf,
    pub meta: ArtifactMeta,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// One model parameter in weights.bin.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

/// Model architecture constants baked into the artifacts.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads_q: usize,
    pub n_heads_kv: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub n_params: usize,
}

/// The manifest's `model` block: weights ABI + architecture.
#[derive(Debug, Clone)]
pub struct ModelBlock {
    pub preset: String,
    pub config: ModelConfig,
    pub weights_path: PathBuf,
    pub params: Vec<ParamSpec>,
}

/// Parsed and validated manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
    pub model: Option<ModelBlock>,
    by_name: HashMap<String, usize>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`, validating structure and that every
    /// referenced file exists.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let version = root.get("version").as_i64().context("missing version")?;
        if version != SUPPORTED_VERSION {
            bail!("manifest version {version} != supported {SUPPORTED_VERSION}");
        }

        let mut entries = Vec::new();
        let mut by_name = HashMap::new();
        for e in root.req_arr("entries")? {
            let name = e.req_str("name")?.to_string();
            let hlo_path = dir.join(e.req_str("hlo")?);
            if !hlo_path.exists() {
                bail!("artifact '{name}' references missing file {}", hlo_path.display());
            }
            let entry = ArtifactEntry {
                kind: ArtifactKind::parse(e.req_str("kind")?)?,
                hlo_path,
                meta: ArtifactMeta::parse(e.get("meta")),
                inputs: e.req_arr("inputs")?.iter().map(TensorSig::parse).collect::<Result<_>>()?,
                outputs: e.req_arr("outputs")?.iter().map(TensorSig::parse).collect::<Result<_>>()?,
                name: name.clone(),
            };
            if by_name.insert(name.clone(), entries.len()).is_some() {
                bail!("duplicate artifact name '{name}'");
            }
            entries.push(entry);
        }

        let model = match root.get("model") {
            Json::Null => None,
            m => Some(Self::parse_model(m, dir)?),
        };

        Ok(Manifest { dir: dir.to_path_buf(), entries, model, by_name })
    }

    fn parse_model(m: &Json, dir: &Path) -> Result<ModelBlock> {
        let c = m.get("config");
        let config = ModelConfig {
            n_layers: c.req_usize("n_layers")?,
            d_model: c.req_usize("d_model")?,
            n_heads_q: c.req_usize("n_heads_q")?,
            n_heads_kv: c.req_usize("n_heads_kv")?,
            head_dim: c.req_usize("head_dim")?,
            vocab: c.req_usize("vocab")?,
            max_seq: c.req_usize("max_seq")?,
            n_params: c.req_usize("n_params")?,
        };
        let weights_path = dir.join(m.req_str("weights")?);
        if !weights_path.exists() {
            bail!("weights file missing: {}", weights_path.display());
        }
        let mut params = Vec::new();
        let mut expected_offset = 0usize;
        for p in m.req_arr("params")? {
            let spec = ParamSpec {
                name: p.req_str("name")?.to_string(),
                shape: p
                    .req_arr("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                offset_bytes: p.req_usize("offset_bytes")?,
                size_bytes: p.req_usize("size_bytes")?,
            };
            if spec.offset_bytes != expected_offset {
                bail!("param '{}' offset {} != expected {}", spec.name, spec.offset_bytes, expected_offset);
            }
            let n: usize = spec.shape.iter().product();
            if spec.size_bytes != 4 * n {
                bail!("param '{}' size {} != 4*{}", spec.name, spec.size_bytes, n);
            }
            expected_offset += spec.size_bytes;
            params.push(spec);
        }
        let file_len = std::fs::metadata(&weights_path)?.len() as usize;
        if file_len != expected_offset {
            bail!("weights.bin is {file_len} bytes, manifest expects {expected_offset}");
        }
        Ok(ModelBlock { preset: m.req_str("preset")?.to_string(), config, weights_path, params })
    }

    /// Look up an artifact entry by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    /// The kernel artifacts (decode split variants), in manifest order.
    pub fn kernels(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.entries.iter().filter(|e| e.kind == ArtifactKind::Kernel)
    }

    /// Find the attention-kernel artifact for an exact launch shape + split.
    pub fn find_kernel(
        &self,
        batch: usize,
        l_k: usize,
        h_kv: usize,
        num_splits: usize,
    ) -> Option<&ArtifactEntry> {
        self.kernels().find(|e| {
            e.meta.batch == Some(batch)
                && e.meta.l_k == Some(l_k)
                && e.meta.h_kv == Some(h_kv)
                && e.meta.num_splits == Some(num_splits)
        })
    }

    /// Smallest decode bucket that fits `batch` with the requested splits.
    pub fn find_decode_bucket(&self, batch: usize, num_splits: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kind == ArtifactKind::Decode
                    && e.meta.num_splits == Some(num_splits)
                    && e.meta.batch.is_some_and(|b| b >= batch)
            })
            .min_by_key(|e| e.meta.batch.unwrap())
    }

    /// Split variants the decode artifact set was compiled with
    /// (ascending, deduplicated). The execution backend advertises these
    /// through its topology so the engine's scheduler and the artifacts
    /// can't skew.
    pub fn decode_split_variants(&self) -> Vec<usize> {
        let mut splits: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Decode)
            .filter_map(|e| e.meta.num_splits)
            .collect();
        splits.sort_unstable();
        splits.dedup();
        splits
    }

    /// Smallest prefill bucket fitting `batch` rows of `prompt_len` tokens.
    pub fn find_prefill_bucket(&self, batch: usize, prompt_len: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kind == ArtifactKind::Prefill
                    && e.meta.batch.is_some_and(|b| b >= batch)
                    && e.meta.prompt_len.is_some_and(|p| p >= prompt_len)
            })
            .min_by_key(|e| (e.meta.batch.unwrap(), e.meta.prompt_len.unwrap()))
    }

    /// Load one parameter's data from weights.bin.
    pub fn load_param(&self, spec: &ParamSpec) -> Result<super::HostTensor> {
        let model = self.model.as_ref().context("manifest has no model block")?;
        let file = std::fs::File::open(&model.weights_path)?;
        use std::io::{Read, Seek, SeekFrom};
        let mut reader = std::io::BufReader::new(file);
        reader.seek(SeekFrom::Start(spec.offset_bytes as u64))?;
        let mut bytes = vec![0u8; spec.size_bytes];
        reader.read_exact(&mut bytes)?;
        super::HostTensor::f32_from_le_bytes(&spec.shape, &bytes)
    }

    /// Load every parameter in ABI order (one pass over weights.bin).
    pub fn load_all_params(&self) -> Result<Vec<super::HostTensor>> {
        let model = self.model.as_ref().context("manifest has no model block")?;
        let bytes = std::fs::read(&model.weights_path)?;
        model
            .params
            .iter()
            .map(|p| {
                super::HostTensor::f32_from_le_bytes(
                    &p.shape,
                    &bytes[p.offset_bytes..p.offset_bytes + p.size_bytes],
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fa3_manifest_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const MINI: &str = r#"{
      "version": 2,
      "entries": [
        {"name": "attn_x", "kind": "kernel", "hlo": "attn_x.hlo.txt",
         "meta": {"batch": 1, "l_k": 512, "h_q": 8, "h_kv": 1, "d": 128, "num_splits": 3},
         "inputs": [{"shape": [1,8,128], "dtype": "f32"}],
         "outputs": [{"shape": [1,8,128], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn loads_minimal_manifest() {
        let dir = tmpdir("ok");
        write_manifest(&dir, MINI);
        std::fs::write(dir.join("attn_x.hlo.txt"), "HloModule x").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.get("attn_x").unwrap();
        assert_eq!(e.kind, ArtifactKind::Kernel);
        assert_eq!(e.meta.l_k, Some(512));
        assert_eq!(e.inputs[0].num_elements(), 8 * 128);
        assert!(m.find_kernel(1, 512, 1, 3).is_some());
        assert!(m.find_kernel(1, 512, 1, 4).is_none());
        assert!(m.model.is_none());
    }

    #[test]
    fn missing_hlo_file_rejected() {
        let dir = tmpdir("missing");
        write_manifest(&dir, MINI);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn wrong_version_rejected() {
        let dir = tmpdir("ver");
        write_manifest(&dir, &MINI.replace("\"version\": 2", "\"version\": 99"));
        std::fs::write(dir.join("attn_x.hlo.txt"), "HloModule x").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn model_block_offset_validation() {
        let dir = tmpdir("model");
        std::fs::write(dir.join("k.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(dir.join("weights.bin"), vec![0u8; 24]).unwrap();
        let manifest = r#"{
          "version": 2,
          "entries": [{"name": "k", "kind": "decode", "hlo": "k.hlo.txt", "meta": {"batch": 1, "num_splits": 1},
                       "inputs": [], "outputs": []}],
          "model": {
            "preset": "tiny",
            "config": {"n_layers": 1, "d_model": 2, "n_heads_q": 1, "n_heads_kv": 1,
                       "head_dim": 2, "vocab": 3, "max_seq": 4, "n_params": 6},
            "weights": "weights.bin",
            "params": [
              {"name": "a", "shape": [2, 2], "offset_bytes": 0, "size_bytes": 16},
              {"name": "b", "shape": [2], "offset_bytes": 16, "size_bytes": 8}
            ]
          }
        }"#;
        write_manifest(&dir, manifest);
        let m = Manifest::load(&dir).unwrap();
        let model = m.model.as_ref().unwrap();
        assert_eq!(model.params.len(), 2);
        let t = m.load_param(&model.params[1]).unwrap();
        assert_eq!(t.shape(), &[2]);
        let all = m.load_all_params().unwrap();
        assert_eq!(all.len(), 2);

        // Corrupt offset must be rejected.
        write_manifest(&dir, &manifest.replace("\"offset_bytes\": 16", "\"offset_bytes\": 20"));
        assert!(Manifest::load(&dir).is_err());
        // Wrong total size must be rejected.
        std::fs::write(dir.join("weights.bin"), vec![0u8; 25]).unwrap();
        write_manifest(&dir, manifest);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn bucket_routing_picks_smallest_fit() {
        let dir = tmpdir("bucket");
        for n in ["d1", "d4", "p1"] {
            std::fs::write(dir.join(format!("{n}.hlo.txt")), "HloModule x").unwrap();
        }
        write_manifest(
            &dir,
            r#"{
          "version": 2,
          "entries": [
            {"name": "d1", "kind": "decode", "hlo": "d1.hlo.txt",
             "meta": {"batch": 1, "num_splits": 3}, "inputs": [], "outputs": []},
            {"name": "d4", "kind": "decode", "hlo": "d4.hlo.txt",
             "meta": {"batch": 4, "num_splits": 3}, "inputs": [], "outputs": []},
            {"name": "p1", "kind": "prefill", "hlo": "p1.hlo.txt",
             "meta": {"batch": 4, "prompt_len": 128}, "inputs": [], "outputs": []}
          ]
        }"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.find_decode_bucket(1, 3).unwrap().name, "d1");
        assert_eq!(m.find_decode_bucket(2, 3).unwrap().name, "d4");
        assert_eq!(m.find_decode_bucket(4, 3).unwrap().name, "d4");
        assert!(m.find_decode_bucket(5, 3).is_none());
        assert!(m.find_decode_bucket(1, 2).is_none());
        assert_eq!(m.find_prefill_bucket(2, 100).unwrap().name, "p1");
        assert!(m.find_prefill_bucket(2, 200).is_none());
    }

    #[test]
    fn duplicate_names_rejected() {
        let dir = tmpdir("dup");
        std::fs::write(dir.join("attn_x.hlo.txt"), "HloModule x").unwrap();
        let dup = MINI.replace(
            "]\n    }",
            r#", {"name": "attn_x", "kind": "kernel", "hlo": "attn_x.hlo.txt",
                "meta": {}, "inputs": [], "outputs": []}]
    }"#,
        );
        write_manifest(&dir, &dup);
        assert!(Manifest::load(&dir).is_err());
    }
}
