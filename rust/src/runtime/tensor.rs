//! Host-side tensors and PJRT literal marshalling.
//!
//! The runtime only traffics in the two dtypes the artifacts use: `f32`
//! (activations, caches, weights) and `s32` (tokens, positions, lengths).

use anyhow::{anyhow, bail, Context, Result};

/// Element type of a [`HostTensor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    S32,
}

impl DType {
    /// Parse a manifest dtype string (`f32` | `s32`).
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "s32" => Ok(DType::S32),
            other => bail!("unsupported dtype '{other}' (artifacts use f32/s32)"),
        }
    }

    /// The manifest spelling of this dtype.
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::S32 => "s32",
        }
    }

    /// Bytes per element.
    pub fn bytes(&self) -> usize {
        4
    }

    fn element_type(&self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::S32 => xla::ElementType::S32,
        }
    }
}

/// A host tensor: shape + typed data.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    S32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    /// An f32 host tensor (shape must cover `data` exactly).
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(HostTensor::F32 { shape: shape.to_vec(), data })
    }

    /// An s32 host tensor (shape must cover `data` exactly).
    pub fn s32(shape: &[usize], data: Vec<i32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(HostTensor::S32 { shape: shape.to_vec(), data })
    }

    /// An all-zeros f32 host tensor.
    pub fn zeros_f32(shape: &[usize]) -> HostTensor {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// The tensor's element dtype.
    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::S32 { .. } => DType::S32,
        }
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::S32 { shape, .. } => shape,
        }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The f32 data, or an error for non-f32 tensors.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// The s32 data, or an error for non-s32 tensors.
    pub fn as_s32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::S32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not s32")),
        }
    }

    fn raw_bytes(&self) -> &[u8] {
        match self {
            HostTensor::F32 { data, .. } => unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            },
            HostTensor::S32 { data, .. } => unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            },
        }
    }

    /// Convert to an XLA literal (host copy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            self.dtype().element_type(),
            self.shape(),
            self.raw_bytes(),
        )
        .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }

    /// Upload directly to a device-resident buffer.
    ///
    /// Uses the typed `buffer_from_host_buffer` path: the crate's
    /// `buffer_from_host_raw_bytes` passes `ElementType` discriminants where
    /// the C API expects `PrimitiveType` numbering, silently mistyping the
    /// buffer (S32 ⇒ S16).
    pub fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            HostTensor::F32 { shape, data } => client
                .buffer_from_host_buffer::<f32>(data, shape, None)
                .map_err(|e| anyhow!("buffer upload failed: {e:?}")),
            HostTensor::S32 { shape, data } => client
                .buffer_from_host_buffer::<i32>(data, shape, None)
                .map_err(|e| anyhow!("buffer upload failed: {e:?}")),
        }
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
                HostTensor::f32(&dims, data)
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec s32: {e:?}"))?;
                HostTensor::s32(&dims, data)
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Load a contiguous f32 slice from raw little-endian bytes
    /// (the weights.bin ABI).
    pub fn f32_from_le_bytes(shape: &[usize], bytes: &[u8]) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!("shape {shape:?} wants {} bytes, got {}", n * 4, bytes.len());
        }
        let mut data = Vec::with_capacity(n);
        for chunk in bytes.chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().context("chunk")?));
        }
        HostTensor::f32(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates_shape() {
        assert!(HostTensor::f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::s32(&[4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn accessors() {
        let t = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert!(t.as_s32().is_err());
    }

    #[test]
    fn zeros() {
        let t = HostTensor::zeros_f32(&[3, 5]);
        assert_eq!(t.len(), 15);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn le_bytes_roundtrip() {
        let vals = [1.5f32, -2.25, 0.0, 3.0e9];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let t = HostTensor::f32_from_le_bytes(&[4], &bytes).unwrap();
        assert_eq!(t.as_f32().unwrap(), &vals);
        assert!(HostTensor::f32_from_le_bytes(&[5], &bytes).is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("s32").unwrap(), DType::S32);
        assert!(DType::parse("bf16").is_err());
        assert_eq!(DType::F32.name(), "f32");
    }

    // Literal round-trips require a PJRT client and are covered by the
    // integration tests in rust/tests/runtime_integration.rs.
}
