//! PJRT runtime: load AOT artifacts and execute them from the rust hot path.
//!
//! The compile path (`make artifacts`) is Python/JAX; the request path is
//! this module: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (see python/compile/aot.py for why).
//!
//! * [`tensor`]    — host-side tensors and literal marshalling,
//! * [`artifacts`] — manifest.json parsing and artifact descriptions,
//! * [`executor`]  — one compiled executable + typed execute wrappers,
//! * [`registry`]  — lazy-compiling artifact registry with shape-bucket
//!                   routing and persistent device-resident weights.

pub mod artifacts;
pub mod executor;
pub mod registry;
pub mod tensor;

pub use artifacts::{ArtifactEntry, ArtifactKind, Manifest, ModelBlock, TensorSig};
pub use executor::Executor;
pub use registry::Registry;
pub use tensor::{DType, HostTensor};
