//! Lazy-compiling artifact registry: the runtime facade the coordinator
//! and benches use.
//!
//! Owns the PJRT client, compiles artifacts on first use (compilation is
//! seconds; serving steady-state never recompiles), keeps the model
//! weights device-resident, and routes (batch, splits) requests to the
//! right shape bucket — the CUDA-Graph-style static-shape routing vLLM
//! does on real hardware.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::artifacts::{ArtifactEntry, Manifest};
use super::executor::Executor;
use super::tensor::HostTensor;

/// Artifact registry + PJRT client + persistent weights.
pub struct Registry {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    compiled: Mutex<HashMap<String, std::sync::Arc<Executor>>>,
    /// Device-resident model parameters in ABI order (uploaded once).
    weights: Mutex<Option<std::sync::Arc<Vec<xla::PjRtBuffer>>>>,
}

// See executor.rs for the Send/Sync rationale.
unsafe impl Send for Registry {}
unsafe impl Sync for Registry {}

impl Registry {
    /// Open `artifacts_dir` on a CPU PJRT client.
    pub fn open(artifacts_dir: &Path) -> Result<Registry> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Registry {
            manifest,
            client,
            compiled: Mutex::new(HashMap::new()),
            weights: Mutex::new(None),
        })
    }

    /// The PJRT client artifacts execute on.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Get (compiling if needed) the executor for a named artifact.
    pub fn executor(&self, name: &str) -> Result<std::sync::Arc<Executor>> {
        {
            let cache = self.compiled.lock().unwrap();
            if let Some(exe) = cache.get(name) {
                return Ok(exe.clone());
            }
        }
        let entry = self
            .manifest
            .get(name)
            .with_context(|| format!("no artifact named '{name}' in manifest"))?
            .clone();
        // Compile outside the lock (it takes seconds); racing compiles of
        // the same artifact are wasteful but harmless.
        let exe = std::sync::Arc::new(Executor::compile(&self.client, &entry)?);
        self.compiled.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// The (cached) compiled executor for an artifact entry.
    pub fn executor_for(&self, entry: &ArtifactEntry) -> Result<std::sync::Arc<Executor>> {
        self.executor(&entry.name)
    }

    /// Eagerly compile every artifact whose name passes `filter`.
    pub fn warmup<F: Fn(&ArtifactEntry) -> bool>(&self, filter: F) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .entries
            .iter()
            .filter(|e| filter(e))
            .map(|e| e.name.clone())
            .collect();
        for name in &names {
            self.executor(name)?;
        }
        Ok(names.len())
    }

    /// Device-resident weights in ABI order, uploading on first call.
    pub fn weights(&self) -> Result<std::sync::Arc<Vec<xla::PjRtBuffer>>> {
        {
            let w = self.weights.lock().unwrap();
            if let Some(w) = w.as_ref() {
                return Ok(w.clone());
            }
        }
        let host = self.manifest.load_all_params()?;
        let bufs: Vec<xla::PjRtBuffer> =
            host.iter().map(|t| t.to_buffer(&self.client)).collect::<Result<_>>()?;
        let arc = std::sync::Arc::new(bufs);
        *self.weights.lock().unwrap() = Some(arc.clone());
        Ok(arc)
    }

    /// Execute a model artifact whose trailing inputs are the weights:
    /// uploads `dynamic` args, reuses the persistent weight buffers.
    pub fn execute_model(
        &self,
        entry_name: &str,
        dynamic: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let exe = self.executor(entry_name)?;
        let weights = self.weights()?;
        let expected = exe.entry.inputs.len();
        if dynamic.len() + weights.len() != expected {
            anyhow::bail!(
                "'{entry_name}': {} dynamic + {} weights != {} manifest inputs",
                dynamic.len(),
                weights.len(),
                expected
            );
        }
        // Validate dynamic shapes against the signature prefix.
        for (i, (arg, sig)) in dynamic.iter().zip(&exe.entry.inputs).enumerate() {
            if arg.shape() != sig.shape.as_slice() || arg.dtype() != sig.dtype {
                anyhow::bail!(
                    "'{entry_name}' dynamic input {i}: got {:?}/{}, manifest says {:?}/{}",
                    arg.shape(),
                    arg.dtype().name(),
                    sig.shape,
                    sig.dtype.name()
                );
            }
        }
        let dyn_bufs: Vec<xla::PjRtBuffer> =
            dynamic.iter().map(|t| t.to_buffer(&self.client)).collect::<Result<_>>()?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(expected);
        args.extend(dyn_bufs.iter());
        args.extend(weights.iter());
        exe.execute_buffers(&args)
    }
}
