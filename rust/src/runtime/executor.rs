//! One compiled executable with typed execute wrappers.
//!
//! All artifacts are lowered with `return_tuple=True`, so PJRT returns a
//! single tuple buffer; [`Executor::execute`] untuples it back into host
//! tensors. For the serving hot path, [`Executor::execute_buffers`] accepts
//! device-resident buffers (persistent weights) so the ~200 MB parameter
//! set is uploaded once, not per step.

use anyhow::{anyhow, bail, Context, Result};

use super::artifacts::ArtifactEntry;
use super::tensor::HostTensor;

/// A compiled artifact bound to a PJRT client.
pub struct Executor {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Executor {
    /// Compile `entry`'s HLO text on `client`.
    pub fn compile(client: &xla::PjRtClient, entry: &ArtifactEntry) -> Result<Executor> {
        let proto = xla::HloModuleProto::from_text_file(
            entry.hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", entry.hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling '{}': {e:?}", entry.name))?;
        Ok(Executor { entry: entry.clone(), exe })
    }

    /// Validate `args` against the manifest signature.
    fn check_args(&self, args: &[HostTensor]) -> Result<()> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "'{}' expects {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                args.len()
            );
        }
        for (i, (arg, sig)) in args.iter().zip(&self.entry.inputs).enumerate() {
            if arg.shape() != sig.shape.as_slice() || arg.dtype() != sig.dtype {
                bail!(
                    "'{}' input {i}: got {:?}/{}, manifest says {:?}/{}",
                    self.entry.name,
                    arg.shape(),
                    arg.dtype().name(),
                    sig.shape,
                    sig.dtype.name()
                );
            }
        }
        Ok(())
    }

    /// Execute with host tensors (copies in, copies out). Returns the
    /// untupled outputs in manifest order.
    pub fn execute(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_args(args)?;
        let literals: Vec<xla::Literal> =
            args.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let outs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing '{}': {e:?}", self.entry.name))?;
        self.untuple(outs)
    }

    /// Execute with pre-uploaded device buffers (zero host->device copies
    /// for persistent args like model weights).
    pub fn execute_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "'{}' expects {} inputs, got {} buffers",
                self.entry.name,
                self.entry.inputs.len(),
                args.len()
            );
        }
        let outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow!("executing '{}' (buffers): {e:?}", self.entry.name))?;
        self.untuple(outs)
    }

    fn untuple(&self, outs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostTensor>> {
        let buf = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("'{}' produced no output buffer", self.entry.name))?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("downloading output of '{}': {e:?}", self.entry.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling output of '{}': {e:?}", self.entry.name))?;
        if parts.len() != self.entry.outputs.len() {
            bail!(
                "'{}' returned {} outputs, manifest says {}",
                self.entry.name,
                parts.len(),
                self.entry.outputs.len()
            );
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

// PJRT executables are internally synchronized; the CPU client supports
// concurrent execute calls. The raw pointers inside the xla wrappers are
// not marked Send/Sync, so we assert it for our usage pattern (one logical
// owner, engine worker threads).
unsafe impl Send for Executor {}
unsafe impl Sync for Executor {}
