//! Step composition: continuous batching with chunked prefill.
//!
//! Before this subsystem every engine step was *homogeneous* — either a
//! monolithic prefill over every prompt-incomplete request or a
//! decode-only wave — so a long prompt parked the whole running set
//! behind its ingestion (head-of-line blocking: TPOT spikes for running
//! decodes, TTFT spikes for everything queued behind the prefill). The
//! production pattern (TGI-style continuous batching with Sarathi-style
//! chunked prefill) caps how much prompt one step may ingest and lets
//! decode rows ride in the same wave, so both latencies stay bounded
//! under heavy traffic.
//!
//! The subsystem is three small pieces, all pure data-in/data-out (it
//! sits *below* the coordinator in the layering DAG and knows nothing
//! about requests, KV blocks, or backends):
//!
//! * [`ChunkPolicy`] — how much prompt a single step may ingest per
//!   request: [`ChunkPolicy::Monolithic`] (the chunk = ∞ limit, exactly
//!   the legacy prefill-first schedule) or [`ChunkPolicy::Bounded`]
//!   (at most `c` prompt tokens per request per step).
//! * [`TokenBudget`] — the per-step ceiling on *total* tokens entering
//!   the model across all rows (decode rows count 1 each); the knob that
//!   bounds step latency, and therefore TPOT, under chunked prefill.
//! * [`StepComposer`] — folds the two into one decision per step:
//!   [`StepComposer::compose_into`] turns a sweep of [`SlotView`]s into
//!   a [`MixedStepPlan`] (decode rows + prefill [`ChunkSpan`]s) in the
//!   engine's reused scratch, allocation-free in steady state.
//!
//! Invariants (property-tested in `tests/continuous_batching.rs` and the
//! composer's unit suite; see DESIGN.md §Continuous batching):
//!
//! 1. **Monolithic ≡ legacy.** Under [`ChunkPolicy::Monolithic`] the
//!    composed plan maps 1:1 onto `Batcher::plan_into`'s prefill-first
//!    schedule, and the engine executes it through the *unchanged*
//!    legacy prefill/decode paths — chunk = ∞ is byte-identical to the
//!    pre-composer engine by code-path reuse, not by re-derivation.
//! 2. **Chunk spans tile the prompt.** Across steps, one request's spans
//!    are contiguous, non-overlapping, and end exactly at the prompt
//!    length; the first span skips prefix-cache-resident tokens (but
//!    always ingests at least the final prompt token, which seeds
//!    decode).
//! 3. **Decode first.** Decode rows are admitted into the budget before
//!    any chunk: an in-flight generation is never starved by prompt
//!    ingestion (config validation guarantees the budget covers the
//!    whole running set).
//! 4. **Progress.** Any step with runnable work composes at least one
//!    row.

mod composer;
mod policy;
pub mod slack;

pub use composer::{ChunkSpan, MixedStepPlan, SlotView, StepComposer};
pub use policy::{ChunkPolicy, ScheduleConfig, TokenBudget};
pub use slack::{deadline_slack_us, min_service_us, ttft_slack_us};
