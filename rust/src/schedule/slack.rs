//! Slack arithmetic for deadline-aware goodput scheduling.
//!
//! *Slack* is how much schedule margin a request still has: its latest
//! acceptable finish (absolute deadline, or arrival + SLO) minus the
//! earliest instant it could possibly finish (now + a modeled lower
//! bound on remaining service). A request with negative slack is
//! *hopeless* — no schedule can land it inside its target, so every KV
//! block and token budget it would consume is goodput-free; the engine
//! sheds it at admission instead (DESIGN.md §Overload survival).
//!
//! Everything here is pure arithmetic over caller-supplied costs: the
//! `schedule` layer sits below `sim` and `coordinator` in the layering
//! DAG (it may use only `obs`/`util`), so the engine passes in the
//! modeled prefill/decode costs rather than this module importing a cost
//! model.

/// Modeled lower bound on a queued request's remaining service time, µs:
/// its (remaining) prompt ingestion plus one decode step per output
/// token still owed. A *lower* bound by construction — contention, chunk
/// interleaving, and queueing only push the real finish later — so a
/// request this bound already disqualifies is truly hopeless.
pub fn min_service_us(prefill_cost_us: f64, remaining_tokens: usize, decode_step_us: f64) -> f64 {
    prefill_cost_us + remaining_tokens as f64 * decode_step_us
}

/// Deadline slack, µs: `deadline − (now + min_service)`. Negative means
/// even the contention-free schedule misses the deadline.
pub fn deadline_slack_us(deadline_us: u64, now_us: u64, min_service_us: f64) -> f64 {
    deadline_us as f64 - (now_us as f64 + min_service_us)
}

/// First-token slack against a TTFT SLO, µs:
/// `(arrival + slo) − (now + modeled prefill)`. Negative means the
/// request will miss its TTFT target even if admitted this instant —
/// and a missed TTFT target means zero goodput for the whole request,
/// which is what makes shedding on this signal safe.
pub fn ttft_slack_us(arrival_us: u64, ttft_slo_us: u64, now_us: u64, prefill_cost_us: f64) -> f64 {
    (arrival_us as f64 + ttft_slo_us as f64) - (now_us as f64 + prefill_cost_us)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_service_is_prefill_plus_decode_steps() {
        assert!((min_service_us(100.0, 10, 12.0) - 220.0).abs() < 1e-9);
        assert!((min_service_us(50.0, 0, 12.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_slack_signs() {
        // Deadline 1000, now 500, needs 400 → 100 µs to spare.
        assert!((deadline_slack_us(1000, 500, 400.0) - 100.0).abs() < 1e-9);
        // Needs 600 → hopeless by 100 µs.
        assert!(deadline_slack_us(1000, 500, 600.0) < 0.0);
        // Deadline already passed: negative regardless of service cost.
        assert!(deadline_slack_us(400, 500, 0.0) < 0.0);
    }

    #[test]
    fn ttft_slack_signs() {
        // Arrived at 0 with a 2 ms TTFT SLO; at now=1500 a 300 µs
        // prefill still lands at 1800 ≤ 2000.
        assert!(ttft_slack_us(0, 2000, 1500, 300.0) > 0.0);
        // At now=1900 the same prefill lands at 2200 > 2000: hopeless.
        assert!(ttft_slack_us(0, 2000, 1900, 300.0) < 0.0);
        // Later arrival shifts the window right.
        assert!(ttft_slack_us(1000, 2000, 1900, 300.0) > 0.0);
    }
}
