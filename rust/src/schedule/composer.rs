//! [`StepComposer`]: one decision per step — which rows run, and how
//! much prompt each may ingest.

use super::policy::{ChunkPolicy, ScheduleConfig};

/// What the composer needs to know about one occupied slot. Plain data —
/// the engine projects its running set into these each step, so the
/// composer stays below the coordinator in the layering DAG.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotView {
    /// KV-cache slot (stable for the request's life).
    pub slot: usize,
    /// Total prompt length, tokens.
    pub prompt_len: usize,
    /// Prompt tokens already ingested (the per-request chunk cursor).
    pub prefilled: usize,
    /// Leading prompt tokens whose KV already exists (the prefix-cache
    /// grant): the first chunk starts after them — cached prompt blocks
    /// skip chunking entirely.
    pub cached_tokens: usize,
    /// Generation complete: the slot needs no further work.
    pub done: bool,
}

/// One prefill chunk: ingest `len` prompt tokens of `slot` starting at
/// prompt offset `start`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkSpan {
    /// Target slot.
    pub slot: usize,
    /// First prompt offset this chunk ingests.
    pub start: usize,
    /// Tokens this chunk ingests (>= 1).
    pub len: usize,
}

impl ChunkSpan {
    /// One past the last prompt offset this chunk ingests.
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// The composed step: prefill chunks plus decode rows, in the engine's
/// reused scratch. Under [`ChunkPolicy::Monolithic`] this is exactly the
/// legacy `StepPlan` in new clothes (chunks ↔ prefill slots, executed
/// prefill-first); under [`ChunkPolicy::Bounded`] chunks and decode rows
/// share one mixed step.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MixedStepPlan {
    /// Prefill chunks, in ascending slot order.
    pub chunks: Vec<ChunkSpan>,
    /// Slots ready for one decode token, in ascending slot order.
    pub decode_slots: Vec<usize>,
    /// Artifact bucket for the decode wave (smallest bucket >= the decode
    /// row count), `None` when no row decodes.
    pub decode_bucket: Option<usize>,
}

impl MixedStepPlan {
    /// Clear for refill (keeps buffer capacity).
    pub fn clear(&mut self) {
        self.chunks.clear();
        self.decode_slots.clear();
        self.decode_bucket = None;
    }

    /// Whether the step carries no work at all.
    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty() && self.decode_slots.is_empty()
    }

    /// Total tokens entering the model this step (decode rows count 1
    /// each) — the quantity [`super::TokenBudget`] bounds.
    pub fn step_tokens(&self) -> usize {
        self.decode_slots.len() + self.chunks.iter().map(|c| c.len).sum::<usize>()
    }

    /// Classify the composition for the flight recorder: decode rows
    /// only, prompt ingestion only, or a genuinely mixed wave. Meaningful
    /// only for non-empty plans (an empty plan classifies as `Decode`;
    /// callers gate on [`MixedStepPlan::is_empty`] first).
    // pallas-lint: no_alloc
    pub fn step_class(&self) -> crate::obs::StepClass {
        match (self.chunks.is_empty(), self.decode_slots.is_empty()) {
            (true, _) => crate::obs::StepClass::Decode,
            (false, true) => crate::obs::StepClass::Prefill,
            (false, false) => crate::obs::StepClass::Mixed,
        }
    }
}

/// Per-step composer: pure function of the slot sweep and the configured
/// [`ScheduleConfig`]. Owns no request state — the chunk cursor is the
/// engine's `prefilled` counter, reflected back through [`SlotView`].
#[derive(Debug, Clone, Default)]
pub struct StepComposer {
    cfg: ScheduleConfig,
}

impl StepComposer {
    /// A composer for one engine's configuration.
    pub fn new(cfg: ScheduleConfig) -> StepComposer {
        StepComposer { cfg }
    }

    /// The configuration this composer applies.
    pub fn config(&self) -> &ScheduleConfig {
        &self.cfg
    }

    /// Whether this composer reproduces the legacy prefill-first schedule.
    pub fn is_monolithic(&self) -> bool {
        self.cfg.chunk.is_monolithic()
    }

    /// Compose one step into caller-owned scratch (cleared first) from a
    /// sweep of occupied slots in ascending slot order. `buckets` is the
    /// ascending artifact bucket ladder the decode wave packs into.
    ///
    /// Monolithic: a 1:1 mapping of the legacy `Batcher::plan_into`
    /// schedule — every prompt-incomplete slot becomes one full-remainder
    /// chunk, every prompt-complete unfinished slot a decode row (the
    /// engine then runs chunks XOR decode, prefill first, exactly as
    /// before).
    ///
    /// Bounded: decode rows are admitted first (1 budget token each; the
    /// config validation guarantees they always all fit), then each
    /// prompt-incomplete slot gets one chunk of
    /// `min(chunk, remaining prompt, remaining budget)` tokens. The first
    /// chunk of a request starts after its prefix-cache-resident tokens —
    /// but never skips the final prompt token, which must be ingested to
    /// seed decode.
    ///
    /// The steady state refills existing capacity without allocating (the
    /// engine reuses one [`MixedStepPlan`] across steps).
    // pallas-lint: no_alloc
    pub fn compose_into<I>(&self, slots: I, buckets: &[usize], out: &mut MixedStepPlan)
    where
        I: Iterator<Item = SlotView> + Clone,
    {
        out.clear();
        match self.cfg.chunk {
            ChunkPolicy::Monolithic => {
                for s in slots {
                    if s.done {
                        continue;
                    }
                    if s.prefilled < s.prompt_len {
                        out.chunks.push(ChunkSpan {
                            slot: s.slot,
                            start: s.prefilled,
                            len: s.prompt_len - s.prefilled,
                        });
                    } else {
                        out.decode_slots.push(s.slot);
                    }
                }
            }
            ChunkPolicy::Bounded(chunk) => {
                // Pass 1 — decode rows reserve their budget first
                // (invariant 3: generation is never starved by ingestion).
                // pallas-lint: allow(no_alloc): cloning the slot iterator copies a borrow, no heap
                for s in slots.clone() {
                    if !s.done && s.prefilled >= s.prompt_len {
                        out.decode_slots.push(s.slot);
                    }
                }
                let limit = self.cfg.budget.limit().unwrap_or(usize::MAX);
                let mut used = out.decode_slots.len();
                // Pass 2 — chunks take what's left, in slot order.
                for s in slots {
                    if s.done || s.prefilled >= s.prompt_len || used >= limit {
                        continue;
                    }
                    let start = chunk_start(&s);
                    let len = chunk.min(s.prompt_len - start).min(limit - used);
                    debug_assert!(len >= 1);
                    used += len;
                    out.chunks.push(ChunkSpan { slot: s.slot, start, len });
                }
            }
        }
        if !out.decode_slots.is_empty() {
            out.decode_bucket =
                buckets.iter().copied().find(|&b| b >= out.decode_slots.len());
        }
    }
}

/// Where a request's next chunk starts: its chunk cursor, except that the
/// very first chunk jumps over prefix-cache-resident tokens (their KV
/// already exists — composing with block-level sharing, cached prompt
/// blocks skip chunking). The final prompt token is never skipped: even a
/// fully-cached prompt ingests it to seed the decode state.
fn chunk_start(s: &SlotView) -> usize {
    if s.prefilled == 0 {
        s.cached_tokens.min(s.prompt_len.saturating_sub(1))
    } else {
        s.prefilled
    }
}

#[cfg(test)]
mod tests {
    use super::super::policy::TokenBudget;
    use super::*;

    const BUCKETS: &[usize] = &[1, 2, 4];

    fn view(slot: usize, prompt_len: usize, prefilled: usize) -> SlotView {
        SlotView { slot, prompt_len, prefilled, cached_tokens: 0, done: false }
    }

    fn compose(composer: &StepComposer, views: &[SlotView]) -> MixedStepPlan {
        let mut out = MixedStepPlan::default();
        composer.compose_into(views.iter().copied(), BUCKETS, &mut out);
        out
    }

    #[test]
    fn monolithic_is_prefill_first() {
        let c = StepComposer::new(ScheduleConfig::default());
        let plan = compose(&c, &[view(0, 100, 0), view(1, 50, 50), view(2, 80, 0)]);
        assert_eq!(
            plan.chunks,
            vec![
                ChunkSpan { slot: 0, start: 0, len: 100 },
                ChunkSpan { slot: 2, start: 0, len: 80 }
            ]
        );
        // Decode rows are still reported (the legacy StepPlan does too);
        // the engine runs chunks first and decode next step.
        assert_eq!(plan.decode_slots, vec![1]);
        assert_eq!(plan.decode_bucket, Some(1));
    }

    #[test]
    fn monolithic_decode_only_when_prompts_done() {
        let c = StepComposer::new(ScheduleConfig::default());
        let plan = compose(&c, &[view(0, 10, 10), view(3, 7, 7)]);
        assert!(plan.chunks.is_empty());
        assert_eq!(plan.decode_slots, vec![0, 3]);
        assert_eq!(plan.decode_bucket, Some(2));
        assert_eq!(plan.step_tokens(), 2);
    }

    #[test]
    fn bounded_interleaves_chunks_with_decode() {
        let c = StepComposer::new(ScheduleConfig::bounded(32, TokenBudget::unbounded()));
        let plan = compose(&c, &[view(0, 100, 0), view(1, 50, 50), view(2, 100, 64)]);
        assert_eq!(plan.decode_slots, vec![1]);
        assert_eq!(
            plan.chunks,
            vec![
                ChunkSpan { slot: 0, start: 0, len: 32 },
                ChunkSpan { slot: 2, start: 64, len: 32 }
            ]
        );
        assert_eq!(plan.step_tokens(), 65);
        // Final partial chunk.
        let plan = compose(&c, &[view(2, 100, 96)]);
        assert_eq!(plan.chunks, vec![ChunkSpan { slot: 2, start: 96, len: 4 }]);
    }

    #[test]
    fn budget_rations_chunks_never_decode() {
        // Budget 6 over 4 decode rows: 2 tokens left for chunking.
        let c = StepComposer::new(ScheduleConfig::bounded(4, TokenBudget::capped(6)));
        let views = [
            view(0, 10, 10),
            view(1, 10, 10),
            view(2, 10, 10),
            view(3, 10, 10),
            view(4, 40, 0),
            view(5, 40, 0),
        ];
        let plan = compose(&c, &views);
        assert_eq!(plan.decode_slots, vec![0, 1, 2, 3]);
        assert_eq!(plan.chunks, vec![ChunkSpan { slot: 4, start: 0, len: 2 }]);
        assert_eq!(plan.step_tokens(), 6);
        // Exhausted budget: later prompts wait entirely.
        let c = StepComposer::new(ScheduleConfig::bounded(4, TokenBudget::capped(4)));
        let plan = compose(&c, &views);
        assert_eq!(plan.decode_slots.len(), 4);
        assert!(plan.chunks.is_empty(), "{plan:?}");
    }

    #[test]
    fn first_chunk_skips_cached_prefix_but_seeds_decode() {
        let c = StepComposer::new(ScheduleConfig::bounded(64, TokenBudget::unbounded()));
        // 128 of 200 tokens prefix-cached: chunking starts at 128.
        let cached = SlotView { slot: 0, prompt_len: 200, prefilled: 0, cached_tokens: 128, done: false };
        let plan = compose(&c, &[cached]);
        assert_eq!(plan.chunks, vec![ChunkSpan { slot: 0, start: 128, len: 64 }]);
        // Fully cached prompt: still one 1-token chunk (the decode seed).
        let full = SlotView { slot: 0, prompt_len: 200, prefilled: 0, cached_tokens: 200, done: false };
        let plan = compose(&c, &[full]);
        assert_eq!(plan.chunks, vec![ChunkSpan { slot: 0, start: 199, len: 1 }]);
        // Once the cursor moved, the cache grant no longer matters.
        let resumed = SlotView { slot: 0, prompt_len: 200, prefilled: 192, cached_tokens: 128, done: false };
        let plan = compose(&c, &[resumed]);
        assert_eq!(plan.chunks, vec![ChunkSpan { slot: 0, start: 192, len: 8 }]);
    }

    #[test]
    fn done_slots_compose_nothing() {
        for cfg in [
            ScheduleConfig::default(),
            ScheduleConfig::bounded(8, TokenBudget::unbounded()),
        ] {
            let c = StepComposer::new(cfg);
            let done = SlotView { slot: 0, prompt_len: 10, prefilled: 10, cached_tokens: 0, done: true };
            let plan = compose(&c, &[done]);
            assert!(plan.is_empty());
            assert_eq!(plan.decode_bucket, None);
        }
    }

    #[test]
    fn scratch_reuse_keeps_capacity() {
        let c = StepComposer::new(ScheduleConfig::bounded(16, TokenBudget::unbounded()));
        let views = [view(0, 100, 0), view(1, 50, 50)];
        let mut out = MixedStepPlan::default();
        c.compose_into(views.iter().copied(), BUCKETS, &mut out);
        let want = out.clone();
        let (cap_c, cap_d) = (out.chunks.capacity(), out.decode_slots.capacity());
        c.compose_into(views.iter().copied(), BUCKETS, &mut out);
        assert_eq!(out, want);
        assert_eq!(out.chunks.capacity(), cap_c);
        assert_eq!(out.decode_slots.capacity(), cap_d);
    }
}
