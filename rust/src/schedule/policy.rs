//! The two knobs of step composition: chunk size and step token budget.

use anyhow::{bail, Result};

/// How much of a prompt a single step may ingest for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ChunkPolicy {
    /// The chunk = ∞ limit: whole prompts ingest in one step and prefill
    /// excludes decode from the step (the legacy prefill-first schedule,
    /// reproduced exactly — the byte-identity baseline).
    #[default]
    Monolithic,
    /// At most this many prompt tokens per request per step; the
    /// remainder resumes next step, interleaved with decode rows.
    /// Must be >= 1 (use [`ChunkPolicy::Monolithic`] for "off").
    Bounded(usize),
}

impl ChunkPolicy {
    /// CLI-facing constructor: `0` means monolithic (the `--chunk-tokens`
    /// off value), anything else bounds the chunk.
    pub fn from_chunk_tokens(chunk_tokens: usize) -> ChunkPolicy {
        if chunk_tokens == 0 {
            ChunkPolicy::Monolithic
        } else {
            ChunkPolicy::Bounded(chunk_tokens)
        }
    }

    /// Whether this is the chunk = ∞ (legacy-equivalent) policy.
    pub fn is_monolithic(&self) -> bool {
        matches!(self, ChunkPolicy::Monolithic)
    }

    /// The bound, if any.
    pub fn chunk_tokens(&self) -> Option<usize> {
        match *self {
            ChunkPolicy::Monolithic => None,
            ChunkPolicy::Bounded(c) => Some(c),
        }
    }
}

/// Per-step ceiling on total tokens entering the model across all rows
/// (decode rows count 1 each, chunk rows their span length). Bounds the
/// worst-case step latency — the TPOT guarantee chunked prefill exists
/// to provide.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TokenBudget {
    limit: Option<usize>,
}

impl TokenBudget {
    /// No per-step ceiling (the default).
    pub fn unbounded() -> TokenBudget {
        TokenBudget { limit: None }
    }

    /// At most `limit` tokens per step across all rows.
    pub fn capped(limit: usize) -> TokenBudget {
        assert!(limit >= 1, "a zero token budget can never make progress");
        TokenBudget { limit: Some(limit) }
    }

    /// CLI-facing constructor: `0` means unbounded (the
    /// `--max-batch-tokens` off value).
    pub fn from_max_batch_tokens(max_batch_tokens: usize) -> TokenBudget {
        if max_batch_tokens == 0 {
            TokenBudget::unbounded()
        } else {
            TokenBudget::capped(max_batch_tokens)
        }
    }

    /// The ceiling, if any.
    pub fn limit(&self) -> Option<usize> {
        self.limit
    }
}

/// Step-composition configuration carried by `EngineConfig`. The default
/// (`Monolithic` + unbounded) reproduces the legacy engine exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ScheduleConfig {
    /// Prompt-ingestion bound per request per step.
    pub chunk: ChunkPolicy,
    /// Total-token ceiling per step.
    pub budget: TokenBudget,
}

impl ScheduleConfig {
    /// Bounded chunking with an explicit step budget — the production
    /// configuration the continuous-batching bench gates.
    pub fn bounded(chunk_tokens: usize, budget: TokenBudget) -> ScheduleConfig {
        ScheduleConfig { chunk: ChunkPolicy::Bounded(chunk_tokens.max(1)), budget }
    }

    /// Validate against the engine it will drive. `max_batch` is the slot
    /// capacity: a capped budget must cover one decode token per slot
    /// (invariant 3 — decode rows are never rationed) and must fit at
    /// least one full chunk (otherwise chunks could starve forever).
    pub fn validate(&self, max_batch: usize) -> Result<()> {
        let Some(limit) = self.budget.limit() else { return Ok(()) };
        if self.chunk.is_monolithic() {
            bail!(
                "a token budget ({limit}) needs bounded chunks: monolithic prefill \
                 ingests whole prompts and cannot respect a per-step ceiling"
            );
        }
        if limit < max_batch {
            bail!(
                "token budget {limit} below the decode batch capacity {max_batch}: \
                 every running request must fit one decode token per step"
            );
        }
        if let Some(chunk) = self.chunk.chunk_tokens() {
            if limit < chunk {
                bail!(
                    "token budget {limit} below the chunk size {chunk}: \
                     no prefill chunk could ever be scheduled"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_policy_cli_mapping() {
        assert_eq!(ChunkPolicy::from_chunk_tokens(0), ChunkPolicy::Monolithic);
        assert_eq!(ChunkPolicy::from_chunk_tokens(64), ChunkPolicy::Bounded(64));
        assert!(ChunkPolicy::Monolithic.is_monolithic());
        assert_eq!(ChunkPolicy::Bounded(64).chunk_tokens(), Some(64));
        assert_eq!(ChunkPolicy::Monolithic.chunk_tokens(), None);
    }

    #[test]
    fn budget_cli_mapping() {
        assert_eq!(TokenBudget::from_max_batch_tokens(0).limit(), None);
        assert_eq!(TokenBudget::from_max_batch_tokens(512).limit(), Some(512));
        assert_eq!(TokenBudget::default(), TokenBudget::unbounded());
    }

    #[test]
    #[should_panic]
    fn zero_cap_panics() {
        TokenBudget::capped(0);
    }

    #[test]
    fn default_config_is_legacy() {
        let cfg = ScheduleConfig::default();
        assert!(cfg.chunk.is_monolithic());
        assert_eq!(cfg.budget.limit(), None);
        assert!(cfg.validate(8).is_ok());
    }

    #[test]
    fn validation_rejects_inconsistent_budgets() {
        // Budget without chunking: monolithic prefill can't respect it.
        let cfg = ScheduleConfig {
            chunk: ChunkPolicy::Monolithic,
            budget: TokenBudget::capped(256),
        };
        assert!(cfg.validate(4).is_err());
        // Budget below the decode capacity: decode rows would be rationed.
        let cfg = ScheduleConfig::bounded(4, TokenBudget::capped(6));
        assert!(cfg.validate(8).is_err());
        // Budget below the chunk size: chunks could never schedule.
        let cfg = ScheduleConfig::bounded(128, TokenBudget::capped(64));
        assert!(cfg.validate(4).is_err());
        // Consistent: fine.
        let cfg = ScheduleConfig::bounded(128, TokenBudget::capped(256));
        assert!(cfg.validate(8).is_ok());
        // Unbounded budget never constrains.
        let cfg = ScheduleConfig::bounded(128, TokenBudget::unbounded());
        assert!(cfg.validate(1024).is_ok());
    }
}
