//! Cluster topology: the fleet shape and the tensor-parallel shard-geometry
//! derivation.
//!
//! Tensor parallelism is how production deployments *enter* the paper's
//! low-head-count regime: TP divides KV heads across devices, so a TP-8
//! shard of an 8-KV-head GQA model decodes with `H_KV = 1` per device —
//! exactly the `Batch × H_KV < 4` tile counts where the sequence-aware
//! policy's 21–24% window opens (§2.1). The topology is therefore the
//! *planner-facing* object: each replica's [`crate::planner::Planner`]
//! plans the **sharded** [`crate::backend::AttnGeometry`] this module
//! derives, never the full-model one.
//!
//! Derivation rule (validated at build time, [`TpConfig::shard_geometry`]):
//!
//! ```text
//! H_Q_shard  = H_Q  / tp_degree      (must divide evenly)
//! H_KV_shard = H_KV / tp_degree      (must divide evenly; covers degree > H_KV)
//! D, max_seq replicated; group = H_Q/H_KV preserved on every shard
//! ```
//!
//! The PackGqa interaction check: with `pack_gqa` the query group rides the
//! M dimension, so per-shard tiles are `Batch × H_KV_shard` **only while
//! the group fits one `Q_BLOCK` M-block**. Sharding preserves the group
//! (both head counts divide by the same degree), and the topology verifies
//! that invariant, but it *rejects* models whose group already spills
//! (`group > Q_BLOCK`): their tile arithmetic — and the fleet's occupancy
//! accounting built on it — would silently change meaning.

use std::fmt;

use crate::backend::AttnGeometry;
use crate::coordinator::EngineConfig;
use crate::heuristics::tiles::{DecodeShape, Q_BLOCK};
use crate::planner::DeviceProfile;

/// Tensor-parallel configuration of every replica in a fleet (each replica
/// models one TP group's single shard — the devices inside a group run in
/// lockstep, so one shard's plan is the group's plan).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TpConfig {
    /// Ways the attention heads are divided (1 = no sharding).
    pub degree: usize,
}

impl TpConfig {
    /// A tensor-parallel group of `degree` devices.
    pub fn new(degree: usize) -> TpConfig {
        TpConfig { degree }
    }

    /// Derive the per-shard geometry from the full model's, validating
    /// head divisibility and the PackGqa packing invariant.
    pub fn shard_geometry(&self, model: &AttnGeometry) -> Result<AttnGeometry, TopologyError> {
        if self.degree == 0 {
            return Err(TopologyError::ZeroDegree);
        }
        if model.h_kv == 0 || model.h_q % model.h_kv != 0 {
            return Err(TopologyError::GroupMismatch { h_q: model.h_q, h_kv: model.h_kv });
        }
        let probe = DecodeShape::decode(1, 1, model.h_q, model.h_kv, model.d);
        let Some(shard) = probe.shard(self.degree) else {
            return Err(TopologyError::IndivisibleHeads {
                h_q: model.h_q,
                h_kv: model.h_kv,
                degree: self.degree,
            });
        };
        // PackGqa interaction: a group wider than one M-block means per-
        // shard tiles stop being Batch × H_KV_shard — refuse rather than
        // let the fleet's occupancy accounting drift (see module docs).
        if shard.group_size() > Q_BLOCK {
            return Err(TopologyError::PackGqaSpill {
                group: shard.group_size(),
                q_block: Q_BLOCK,
            });
        }
        debug_assert_eq!(
            shard.m_blocks(true),
            probe.m_blocks(true),
            "sharding must not change packed M-block count"
        );
        Ok(AttnGeometry { h_q: shard.h_q, h_kv: shard.h_kv, d: model.d, max_seq: model.max_seq })
    }
}

/// Why a topology failed to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyError {
    ZeroDegree,
    /// `H_Q` is not a multiple of `H_KV` — no valid GQA grouping.
    GroupMismatch { h_q: usize, h_kv: usize },
    /// Heads don't divide evenly across shards (includes `degree > H_KV`).
    IndivisibleHeads { h_q: usize, h_kv: usize, degree: usize },
    /// The packed query group exceeds one M-block (`Q_BLOCK` rows).
    PackGqaSpill { group: usize, q_block: usize },
    /// A fleet needs at least one replica.
    NoReplicas,
}

impl fmt::Display for TopologyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TopologyError::ZeroDegree => write!(f, "tp degree must be >= 1"),
            TopologyError::GroupMismatch { h_q, h_kv } => {
                write!(f, "H_Q={h_q} is not a multiple of H_KV={h_kv}")
            }
            TopologyError::IndivisibleHeads { h_q, h_kv, degree } => write!(
                f,
                "cannot shard H_Q={h_q}/H_KV={h_kv} across tp={degree} shards \
                 (both head counts must divide evenly)"
            ),
            TopologyError::PackGqaSpill { group, q_block } => write!(
                f,
                "query group of {group} spills past one {q_block}-row M-block under pack_gqa; \
                 per-shard tile accounting would change meaning"
            ),
            TopologyError::NoReplicas => write!(f, "a fleet needs at least one replica"),
        }
    }
}

impl std::error::Error for TopologyError {}

/// One replica's hardware + engine configuration. Heterogeneous fleets mix
/// specs (different device profiles, different KV budgets).
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub device: DeviceProfile,
    /// Engine-config override; `None` inherits the fleet default.
    pub engine: Option<EngineConfig>,
}

impl ReplicaSpec {
    /// A replica of `device` using the fleet's default engine config.
    pub fn new(device: DeviceProfile) -> ReplicaSpec {
        ReplicaSpec { device, engine: None }
    }

    /// Override the engine configuration for this replica alone.
    pub fn engine(mut self, cfg: EngineConfig) -> ReplicaSpec {
        self.engine = Some(cfg);
        self
    }
}

/// The validated fleet shape: full-model geometry, TP configuration, the
/// derived per-shard geometry, and one spec per replica.
#[derive(Debug, Clone)]
pub struct ClusterTopology {
    model: AttnGeometry,
    tp: TpConfig,
    shard: AttnGeometry,
    replicas: Vec<ReplicaSpec>,
}

impl ClusterTopology {
    /// Start describing a cluster around the full (unsharded) model geometry.
    pub fn builder(model: AttnGeometry) -> ClusterTopologyBuilder {
        ClusterTopologyBuilder { model, tp: TpConfig::new(1), replicas: Vec::new() }
    }

    /// The full (unsharded) model geometry.
    pub fn model(&self) -> AttnGeometry {
        self.model
    }

    /// The tensor-parallel configuration.
    pub fn tp(&self) -> TpConfig {
        self.tp
    }

    /// The per-shard geometry every replica's planner plans against.
    pub fn shard_geometry(&self) -> AttnGeometry {
        self.shard
    }

    /// The replica specs, in index order.
    pub fn replicas(&self) -> &[ReplicaSpec] {
        &self.replicas
    }

    /// Number of replicas (TP groups).
    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// The sharded decode shape one replica launches for a live batch —
    /// what its planner sees each step (the step's `l_k` clamp happens in
    /// the scheduler as usual).
    pub fn shard_shape(&self, batch: usize, l_k: usize) -> DecodeShape {
        DecodeShape::decode(batch, l_k, self.shard.h_q, self.shard.h_kv, self.shard.d)
    }

    /// Per-shard work tiles for a decode batch under pack_gqa — the §2.1
    /// quantity TP shrinks (`Batch × H_KV / tp_degree`).
    pub fn shard_tiles(&self, batch: usize) -> usize {
        batch * self.shard.h_kv
    }
}

/// Builder for [`ClusterTopology`]; all validation happens in `build`.
pub struct ClusterTopologyBuilder {
    model: AttnGeometry,
    tp: TpConfig,
    replicas: Vec<ReplicaSpec>,
}

impl ClusterTopologyBuilder {
    /// Set the tensor-parallel degree (validated at `build`).
    pub fn tp(mut self, tp: TpConfig) -> ClusterTopologyBuilder {
        self.tp = tp;
        self
    }

    /// Add one replica.
    pub fn replica(mut self, spec: ReplicaSpec) -> ClusterTopologyBuilder {
        self.replicas.push(spec);
        self
    }

    /// Add `n` identical replicas on `device`.
    pub fn replicas(mut self, n: usize, device: DeviceProfile) -> ClusterTopologyBuilder {
        self.replicas.extend((0..n).map(|_| ReplicaSpec::new(device)));
        self
    }

    /// Validate and freeze the topology (head divisibility, PackGqa packing).
    pub fn build(self) -> Result<ClusterTopology, TopologyError> {
        if self.replicas.is_empty() {
            return Err(TopologyError::NoReplicas);
        }
        let shard = self.tp.shard_geometry(&self.model)?;
        Ok(ClusterTopology { model: self.model, tp: self.tp, shard, replicas: self.replicas })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama70b() -> AttnGeometry {
        AttnGeometry { h_q: 64, h_kv: 8, d: 128, max_seq: 1024 }
    }

    #[test]
    fn tp8_derives_the_paper_shape() {
        let shard = TpConfig::new(8).shard_geometry(&llama70b()).unwrap();
        assert_eq!(shard, AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 });
        // tp = 1 is the identity.
        assert_eq!(TpConfig::new(1).shard_geometry(&llama70b()).unwrap(), llama70b());
    }

    #[test]
    fn tile_count_shrinks_by_degree() {
        for degree in [1usize, 2, 4, 8] {
            let topo = ClusterTopology::builder(llama70b())
                .tp(TpConfig::new(degree))
                .replicas(2, DeviceProfile::H100_SXM)
                .build()
                .unwrap();
            assert_eq!(topo.shard_tiles(1), 8 / degree);
            assert_eq!(topo.shard_shape(1, 512).total_mblocks(true), 8 / degree);
        }
    }

    #[test]
    fn divisibility_rejected_at_build() {
        let err = TpConfig::new(3).shard_geometry(&llama70b()).unwrap_err();
        assert!(matches!(err, TopologyError::IndivisibleHeads { degree: 3, .. }));
        // More shards than KV heads: same rejection.
        let err = TpConfig::new(16).shard_geometry(&llama70b()).unwrap_err();
        assert!(matches!(err, TopologyError::IndivisibleHeads { .. }));
        assert!(matches!(
            TpConfig::new(0).shard_geometry(&llama70b()),
            Err(TopologyError::ZeroDegree)
        ));
        // The builder surfaces the same error.
        let err = ClusterTopology::builder(llama70b())
            .tp(TpConfig::new(5))
            .replicas(1, DeviceProfile::H100_SXM)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("tp=5"));
    }

    #[test]
    fn group_mismatch_and_pack_gqa_spill_rejected() {
        let bad_group = AttnGeometry { h_q: 10, h_kv: 4, d: 128, max_seq: 1024 };
        assert!(matches!(
            TpConfig::new(2).shard_geometry(&bad_group),
            Err(TopologyError::GroupMismatch { .. })
        ));
        // A 128-wide query group spills past one 64-row M-block.
        let wide = AttnGeometry { h_q: 256, h_kv: 2, d: 128, max_seq: 1024 };
        let err = TpConfig::new(2).shard_geometry(&wide).unwrap_err();
        assert!(matches!(err, TopologyError::PackGqaSpill { group: 128, .. }), "{err}");
    }

    #[test]
    fn builder_requires_replicas_and_keeps_specs() {
        assert!(matches!(
            ClusterTopology::builder(llama70b()).build(),
            Err(TopologyError::NoReplicas)
        ));
        let topo = ClusterTopology::builder(llama70b())
            .tp(TpConfig::new(4))
            .replicas(2, DeviceProfile::H100_SXM)
            .replica(ReplicaSpec::new(DeviceProfile::A100_SXM))
            .build()
            .unwrap();
        assert_eq!(topo.num_replicas(), 3);
        assert_eq!(topo.replicas()[2].device.name, "A100-SXM4");
        assert_eq!(topo.shard_geometry().h_kv, 2);
    }
}
