//! Cluster topology: the fleet shape and the tensor-parallel shard-geometry
//! derivation.
//!
//! Tensor parallelism is how production deployments *enter* the paper's
//! low-head-count regime: TP divides KV heads across devices, so a TP-8
//! shard of an 8-KV-head GQA model decodes with `H_KV = 1` per device —
//! exactly the `Batch × H_KV < 4` tile counts where the sequence-aware
//! policy's 21–24% window opens (§2.1). The topology is therefore the
//! *planner-facing* object: each replica's [`crate::planner::Planner`]
//! plans the **sharded** [`crate::backend::AttnGeometry`] this module
//! derives, never the full-model one.
//!
//! Derivation rule (validated at build time, [`TpConfig::shard_geometry`]):
//!
//! ```text
//! H_Q_shard  = H_Q  / tp_degree      (must divide evenly)
//! H_KV_shard = H_KV / tp_degree      (must divide evenly; covers degree > H_KV)
//! D, max_seq replicated; group = H_Q/H_KV preserved on every shard
//! ```
//!
//! The PackGqa interaction check: with `pack_gqa` the query group rides the
//! M dimension, so per-shard tiles are `Batch × H_KV_shard` **only while
//! the group fits one `Q_BLOCK` M-block**. Sharding preserves the group
//! (both head counts divide by the same degree), and the topology verifies
//! that invariant, but it *rejects* models whose group already spills
//! (`group > Q_BLOCK`): their tile arithmetic — and the fleet's occupancy
//! accounting built on it — would silently change meaning.

use std::fmt;

use crate::backend::AttnGeometry;
use crate::coordinator::EngineConfig;
use crate::heuristics::tiles::{DecodeShape, Q_BLOCK};
use crate::planner::DeviceProfile;
use crate::sim::host_transfer::HostTransferModel;

/// Tensor-parallel configuration of every replica in a fleet (each replica
/// models one TP group's single shard — the devices inside a group run in
/// lockstep, so one shard's plan is the group's plan).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TpConfig {
    /// Ways the attention heads are divided (1 = no sharding).
    pub degree: usize,
}

impl TpConfig {
    /// A tensor-parallel group of `degree` devices.
    pub fn new(degree: usize) -> TpConfig {
        TpConfig { degree }
    }

    /// Derive the per-shard geometry from the full model's, validating
    /// head divisibility and the PackGqa packing invariant.
    pub fn shard_geometry(&self, model: &AttnGeometry) -> Result<AttnGeometry, TopologyError> {
        if self.degree == 0 {
            return Err(TopologyError::ZeroDegree);
        }
        if model.h_kv == 0 || model.h_q % model.h_kv != 0 {
            return Err(TopologyError::GroupMismatch { h_q: model.h_q, h_kv: model.h_kv });
        }
        let probe = DecodeShape::decode(1, 1, model.h_q, model.h_kv, model.d);
        let Some(shard) = probe.shard(self.degree) else {
            return Err(TopologyError::IndivisibleHeads {
                h_q: model.h_q,
                h_kv: model.h_kv,
                degree: self.degree,
            });
        };
        // PackGqa interaction: a group wider than one M-block means per-
        // shard tiles stop being Batch × H_KV_shard — refuse rather than
        // let the fleet's occupancy accounting drift (see module docs).
        if shard.group_size() > Q_BLOCK {
            return Err(TopologyError::PackGqaSpill {
                group: shard.group_size(),
                q_block: Q_BLOCK,
            });
        }
        debug_assert_eq!(
            shard.m_blocks(true),
            probe.m_blocks(true),
            "sharding must not change packed M-block count"
        );
        Ok(AttnGeometry { h_q: shard.h_q, h_kv: shard.h_kv, d: model.d, max_seq: model.max_seq })
    }
}

/// Which serving phase a replica hosts.
///
/// Colocated fleets run every replica [`ReplicaRole::Unified`]; a
/// disaggregated fleet partitions its replicas into a **prefill pool**
/// (prompt ingestion + first token) and a **decode pool** (token
/// generation over KV handed off across the [`Interconnect`]). The
/// split matters because the two phases live in different planning
/// regimes: prefill is compute-saturated at any head count, while
/// decode is exactly the `Batch × H_KV < 4` starved regime the
/// sequence-aware policy targets — a decode pool concentrates it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaRole {
    /// Both phases (the colocated default).
    Unified,
    /// Prompt ingestion only: runs each request's prefill and emits its
    /// first token, then hands the KV blocks to the decode pool.
    Prefill,
    /// Token generation only: continues requests whose prefilled KV
    /// arrived over the modeled interconnect.
    Decode,
}

impl ReplicaRole {
    /// Stable lowercase label for reports and the CLI.
    pub fn label(self) -> &'static str {
        match self {
            ReplicaRole::Unified => "unified",
            ReplicaRole::Prefill => "prefill",
            ReplicaRole::Decode => "decode",
        }
    }
}

/// KV bytes per 16-token block used to convert interconnect bandwidth
/// into per-block wire time — the same Llama-70B-class anchor
/// `sim::host_transfer` documents (a block is a few hundred KiB across
/// the layer stack).
pub const KV_BLOCK_BYTES: usize = 256 * 1024;

/// The modeled cross-pool link a prefill→decode KV handoff travels.
///
/// Presets are anchored the way `sim/kernel_model.rs` anchors kernel
/// costs: effective (not peak) per-direction bandwidth plus a fixed
/// submission+sync latency. [`Interconnect::ZERO`] is the free link the
/// differential tests force (`--xfer zero`): byte-identity to colocated
/// serving must survive a handoff that costs nothing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interconnect {
    pub name: &'static str,
    /// Effective per-direction bandwidth, GB/s (`f64::INFINITY` = free).
    pub gbps: f64,
    /// Fixed per-transfer submission + sync latency, µs.
    pub base_us: f64,
}

/// Interconnect names accepted by [`Interconnect::by_name`] — the single
/// source the CLI `--xfer` help and unknown-value errors come from.
pub const INTERCONNECT_NAMES: [&str; 4] = ["nvlink", "infiniband", "pcie", "zero"];

impl Interconnect {
    /// NVLink-class scale-up fabric (effective, not peak).
    pub const NVLINK: Interconnect = Interconnect { name: "nvlink", gbps: 200.0, base_us: 5.0 };
    /// 400 Gb InfiniBand-class scale-out fabric.
    pub const INFINIBAND: Interconnect =
        Interconnect { name: "infiniband", gbps: 50.0, base_us: 15.0 };
    /// Host-bounced PCIe path (matches the `HostTransferModel` default).
    pub const PCIE: Interconnect = Interconnect { name: "pcie", gbps: 25.0, base_us: 20.0 };
    /// The free link: infinite bandwidth, zero latency (identity tests).
    pub const ZERO: Interconnect =
        Interconnect { name: "zero", gbps: f64::INFINITY, base_us: 0.0 };

    /// Look up a preset by CLI-friendly name.
    pub fn by_name(name: &str) -> Option<Interconnect> {
        match name {
            "nvlink" => Some(Interconnect::NVLINK),
            "infiniband" | "ib" => Some(Interconnect::INFINIBAND),
            "pcie" => Some(Interconnect::PCIE),
            "zero" => Some(Interconnect::ZERO),
            _ => None,
        }
    }

    /// `nvlink|infiniband|pcie|zero` — for CLI help.
    pub fn help_line() -> String {
        INTERCONNECT_NAMES.join("|")
    }

    /// Derive the per-block transfer model from this link's bandwidth —
    /// the host-transfer ledger machinery reused for cross-pool D2D.
    pub fn transfer_model(&self) -> HostTransferModel {
        HostTransferModel::for_link(self.base_us, self.gbps, KV_BLOCK_BYTES)
    }

    /// One-way wire time for `blocks` KV blocks, µs (a handoff crosses
    /// the link once; there is no return trip to wait for).
    pub fn transfer_us(&self, blocks: usize) -> u64 {
        self.transfer_model().swap_out_us(blocks).round() as u64
    }
}

/// Why a topology failed to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyError {
    ZeroDegree,
    /// `H_Q` is not a multiple of `H_KV` — no valid GQA grouping.
    GroupMismatch { h_q: usize, h_kv: usize },
    /// Heads don't divide evenly across shards (includes `degree > H_KV`).
    IndivisibleHeads { h_q: usize, h_kv: usize, degree: usize },
    /// The packed query group exceeds one M-block (`Q_BLOCK` rows).
    PackGqaSpill { group: usize, q_block: usize },
    /// A fleet needs at least one replica.
    NoReplicas,
    /// Unified replicas mixed with pooled (prefill/decode) ones — a fleet
    /// is either fully colocated or fully disaggregated, never both (a
    /// unified replica inside a disaggregated fleet would need per-request
    /// phase decisions this model deliberately keeps at the pool level).
    MixedRoles { unified: usize, pooled: usize },
    /// A disaggregated fleet is missing one of its pools.
    MissingPool { role: ReplicaRole },
}

impl fmt::Display for TopologyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TopologyError::ZeroDegree => write!(f, "tp degree must be >= 1"),
            TopologyError::GroupMismatch { h_q, h_kv } => {
                write!(f, "H_Q={h_q} is not a multiple of H_KV={h_kv}")
            }
            TopologyError::IndivisibleHeads { h_q, h_kv, degree } => write!(
                f,
                "cannot shard H_Q={h_q}/H_KV={h_kv} across tp={degree} shards \
                 (both head counts must divide evenly)"
            ),
            TopologyError::PackGqaSpill { group, q_block } => write!(
                f,
                "query group of {group} spills past one {q_block}-row M-block under pack_gqa; \
                 per-shard tile accounting would change meaning"
            ),
            TopologyError::NoReplicas => write!(f, "a fleet needs at least one replica"),
            TopologyError::MixedRoles { unified, pooled } => write!(
                f,
                "{unified} unified replica(s) mixed with {pooled} pooled one(s); a fleet is \
                 either fully colocated or fully disaggregated"
            ),
            TopologyError::MissingPool { role } => write!(
                f,
                "disaggregated fleet has no {} pool (needs at least one replica of each role)",
                role.label()
            ),
        }
    }
}

impl std::error::Error for TopologyError {}

/// One replica's hardware + engine configuration. Heterogeneous fleets mix
/// specs (different device profiles, different KV budgets).
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub device: DeviceProfile,
    /// Engine-config override; `None` inherits the fleet default.
    pub engine: Option<EngineConfig>,
    /// Which serving phase this replica hosts (colocated by default).
    pub role: ReplicaRole,
}

impl ReplicaSpec {
    /// A replica of `device` using the fleet's default engine config.
    pub fn new(device: DeviceProfile) -> ReplicaSpec {
        ReplicaSpec { device, engine: None, role: ReplicaRole::Unified }
    }

    /// Override the engine configuration for this replica alone.
    pub fn engine(mut self, cfg: EngineConfig) -> ReplicaSpec {
        self.engine = Some(cfg);
        self
    }

    /// Assign this replica to a serving-phase pool.
    pub fn role(mut self, role: ReplicaRole) -> ReplicaSpec {
        self.role = role;
        self
    }
}

/// The validated fleet shape: full-model geometry, TP configuration, the
/// derived per-shard geometry, and one spec per replica.
#[derive(Debug, Clone)]
pub struct ClusterTopology {
    model: AttnGeometry,
    tp: TpConfig,
    shard: AttnGeometry,
    replicas: Vec<ReplicaSpec>,
    interconnect: Interconnect,
}

impl ClusterTopology {
    /// Start describing a cluster around the full (unsharded) model geometry.
    pub fn builder(model: AttnGeometry) -> ClusterTopologyBuilder {
        ClusterTopologyBuilder {
            model,
            tp: TpConfig::new(1),
            replicas: Vec::new(),
            interconnect: Interconnect::NVLINK,
        }
    }

    /// The full (unsharded) model geometry.
    pub fn model(&self) -> AttnGeometry {
        self.model
    }

    /// The tensor-parallel configuration.
    pub fn tp(&self) -> TpConfig {
        self.tp
    }

    /// The per-shard geometry every replica's planner plans against.
    pub fn shard_geometry(&self) -> AttnGeometry {
        self.shard
    }

    /// The replica specs, in index order.
    pub fn replicas(&self) -> &[ReplicaSpec] {
        &self.replicas
    }

    /// Number of replicas (TP groups).
    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// The sharded decode shape one replica launches for a live batch —
    /// what its planner sees each step (the step's `l_k` clamp happens in
    /// the scheduler as usual).
    pub fn shard_shape(&self, batch: usize, l_k: usize) -> DecodeShape {
        DecodeShape::decode(batch, l_k, self.shard.h_q, self.shard.h_kv, self.shard.d)
    }

    /// Per-shard work tiles for a decode batch under pack_gqa — the §2.1
    /// quantity TP shrinks (`Batch × H_KV / tp_degree`).
    pub fn shard_tiles(&self, batch: usize) -> usize {
        batch * self.shard.h_kv
    }

    /// The cross-pool link prefill→decode handoffs travel (relevant only
    /// for disaggregated fleets; colocated ones never cross it).
    pub fn interconnect(&self) -> Interconnect {
        self.interconnect
    }

    /// Whether this fleet is split into prefill/decode pools. Build-time
    /// validation guarantees the alternative is all-[`ReplicaRole::Unified`].
    pub fn is_disaggregated(&self) -> bool {
        self.replicas.iter().any(|s| s.role != ReplicaRole::Unified)
    }

    /// Replica indices holding `role`, in index order.
    pub fn pool(&self, role: ReplicaRole) -> Vec<usize> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, s)| s.role == role)
            .map(|(i, _)| i)
            .collect()
    }

    /// The role of replica `index`.
    pub fn role_of(&self, index: usize) -> ReplicaRole {
        self.replicas[index].role
    }
}

/// Builder for [`ClusterTopology`]; all validation happens in `build`.
pub struct ClusterTopologyBuilder {
    model: AttnGeometry,
    tp: TpConfig,
    replicas: Vec<ReplicaSpec>,
    interconnect: Interconnect,
}

impl ClusterTopologyBuilder {
    /// Set the tensor-parallel degree (validated at `build`).
    pub fn tp(mut self, tp: TpConfig) -> ClusterTopologyBuilder {
        self.tp = tp;
        self
    }

    /// Add one replica.
    pub fn replica(mut self, spec: ReplicaSpec) -> ClusterTopologyBuilder {
        self.replicas.push(spec);
        self
    }

    /// Add `n` identical replicas on `device`.
    pub fn replicas(mut self, n: usize, device: DeviceProfile) -> ClusterTopologyBuilder {
        self.replicas.extend((0..n).map(|_| ReplicaSpec::new(device)));
        self
    }

    /// Add `n` identical replicas on `device` assigned to `role`'s pool.
    pub fn pool(
        mut self,
        n: usize,
        device: DeviceProfile,
        role: ReplicaRole,
    ) -> ClusterTopologyBuilder {
        self.replicas.extend((0..n).map(|_| ReplicaSpec::new(device).role(role)));
        self
    }

    /// Set the cross-pool interconnect (only disaggregated fleets use it).
    pub fn interconnect(mut self, ic: Interconnect) -> ClusterTopologyBuilder {
        self.interconnect = ic;
        self
    }

    /// Validate and freeze the topology (head divisibility, PackGqa
    /// packing, role partitioning).
    pub fn build(self) -> Result<ClusterTopology, TopologyError> {
        if self.replicas.is_empty() {
            return Err(TopologyError::NoReplicas);
        }
        let unified = self.replicas.iter().filter(|s| s.role == ReplicaRole::Unified).count();
        let pooled = self.replicas.len() - unified;
        if unified > 0 && pooled > 0 {
            return Err(TopologyError::MixedRoles { unified, pooled });
        }
        if pooled > 0 {
            for role in [ReplicaRole::Prefill, ReplicaRole::Decode] {
                if !self.replicas.iter().any(|s| s.role == role) {
                    return Err(TopologyError::MissingPool { role });
                }
            }
        }
        let shard = self.tp.shard_geometry(&self.model)?;
        Ok(ClusterTopology {
            model: self.model,
            tp: self.tp,
            shard,
            replicas: self.replicas,
            interconnect: self.interconnect,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama70b() -> AttnGeometry {
        AttnGeometry { h_q: 64, h_kv: 8, d: 128, max_seq: 1024 }
    }

    #[test]
    fn tp8_derives_the_paper_shape() {
        let shard = TpConfig::new(8).shard_geometry(&llama70b()).unwrap();
        assert_eq!(shard, AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 });
        // tp = 1 is the identity.
        assert_eq!(TpConfig::new(1).shard_geometry(&llama70b()).unwrap(), llama70b());
    }

    #[test]
    fn tile_count_shrinks_by_degree() {
        for degree in [1usize, 2, 4, 8] {
            let topo = ClusterTopology::builder(llama70b())
                .tp(TpConfig::new(degree))
                .replicas(2, DeviceProfile::H100_SXM)
                .build()
                .unwrap();
            assert_eq!(topo.shard_tiles(1), 8 / degree);
            assert_eq!(topo.shard_shape(1, 512).total_mblocks(true), 8 / degree);
        }
    }

    #[test]
    fn divisibility_rejected_at_build() {
        let err = TpConfig::new(3).shard_geometry(&llama70b()).unwrap_err();
        assert!(matches!(err, TopologyError::IndivisibleHeads { degree: 3, .. }));
        // More shards than KV heads: same rejection.
        let err = TpConfig::new(16).shard_geometry(&llama70b()).unwrap_err();
        assert!(matches!(err, TopologyError::IndivisibleHeads { .. }));
        assert!(matches!(
            TpConfig::new(0).shard_geometry(&llama70b()),
            Err(TopologyError::ZeroDegree)
        ));
        // The builder surfaces the same error.
        let err = ClusterTopology::builder(llama70b())
            .tp(TpConfig::new(5))
            .replicas(1, DeviceProfile::H100_SXM)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("tp=5"));
    }

    #[test]
    fn group_mismatch_and_pack_gqa_spill_rejected() {
        let bad_group = AttnGeometry { h_q: 10, h_kv: 4, d: 128, max_seq: 1024 };
        assert!(matches!(
            TpConfig::new(2).shard_geometry(&bad_group),
            Err(TopologyError::GroupMismatch { .. })
        ));
        // A 128-wide query group spills past one 64-row M-block.
        let wide = AttnGeometry { h_q: 256, h_kv: 2, d: 128, max_seq: 1024 };
        let err = TpConfig::new(2).shard_geometry(&wide).unwrap_err();
        assert!(matches!(err, TopologyError::PackGqaSpill { group: 128, .. }), "{err}");
    }

    #[test]
    fn role_partition_validated_at_build() {
        // Colocated: all unified, fine.
        let topo = ClusterTopology::builder(llama70b())
            .replicas(2, DeviceProfile::H100_SXM)
            .build()
            .unwrap();
        assert!(!topo.is_disaggregated());
        assert_eq!(topo.pool(ReplicaRole::Prefill), Vec::<usize>::new());
        // Disaggregated: one of each pool, fine.
        let topo = ClusterTopology::builder(llama70b())
            .tp(TpConfig::new(8))
            .pool(1, DeviceProfile::H100_SXM, ReplicaRole::Prefill)
            .pool(2, DeviceProfile::H100_SXM, ReplicaRole::Decode)
            .build()
            .unwrap();
        assert!(topo.is_disaggregated());
        assert_eq!(topo.pool(ReplicaRole::Prefill), vec![0]);
        assert_eq!(topo.pool(ReplicaRole::Decode), vec![1, 2]);
        assert_eq!(topo.role_of(0), ReplicaRole::Prefill);
        // Mixed unified + pooled: rejected.
        let err = ClusterTopology::builder(llama70b())
            .replicas(1, DeviceProfile::H100_SXM)
            .pool(1, DeviceProfile::H100_SXM, ReplicaRole::Decode)
            .build()
            .unwrap_err();
        assert!(matches!(err, TopologyError::MixedRoles { unified: 1, pooled: 1 }), "{err}");
        // A pool on its own: rejected, naming the missing role.
        let err = ClusterTopology::builder(llama70b())
            .pool(2, DeviceProfile::H100_SXM, ReplicaRole::Decode)
            .build()
            .unwrap_err();
        assert!(matches!(err, TopologyError::MissingPool { role: ReplicaRole::Prefill }));
        assert!(err.to_string().contains("no prefill pool"));
    }

    #[test]
    fn interconnect_presets_price_transfers() {
        // PCIe matches the host-transfer anchor: ~10 µs/block + base.
        let m = Interconnect::PCIE.transfer_model();
        assert!((m.us_per_block - 10.486).abs() < 0.01, "{}", m.us_per_block);
        assert_eq!(Interconnect::PCIE.transfer_us(10), 125); // 20 + 10*10.486
        // Faster links cost strictly less; the zero link costs nothing.
        assert!(Interconnect::NVLINK.transfer_us(10) < Interconnect::INFINIBAND.transfer_us(10));
        assert!(
            Interconnect::INFINIBAND.transfer_us(10) < Interconnect::PCIE.transfer_us(10)
        );
        assert_eq!(Interconnect::ZERO.transfer_us(1_000), 0);
        // Name registry round-trips; default topology link is NVLink.
        for name in INTERCONNECT_NAMES {
            assert_eq!(Interconnect::by_name(name).unwrap().name, name);
            assert!(Interconnect::help_line().contains(name));
        }
        assert!(Interconnect::by_name("carrier-pigeon").is_none());
        let topo = ClusterTopology::builder(llama70b())
            .replicas(1, DeviceProfile::H100_SXM)
            .build()
            .unwrap();
        assert_eq!(topo.interconnect().name, "nvlink");
        let topo = ClusterTopology::builder(llama70b())
            .replicas(1, DeviceProfile::H100_SXM)
            .interconnect(Interconnect::ZERO)
            .build()
            .unwrap();
        assert_eq!(topo.interconnect().name, "zero");
    }

    #[test]
    fn builder_requires_replicas_and_keeps_specs() {
        assert!(matches!(
            ClusterTopology::builder(llama70b()).build(),
            Err(TopologyError::NoReplicas)
        ));
        let topo = ClusterTopology::builder(llama70b())
            .tp(TpConfig::new(4))
            .replicas(2, DeviceProfile::H100_SXM)
            .replica(ReplicaSpec::new(DeviceProfile::A100_SXM))
            .build()
            .unwrap();
        assert_eq!(topo.num_replicas(), 3);
        assert_eq!(topo.replicas()[2].device.name, "A100-SXM4");
        assert_eq!(topo.shard_geometry().h_kv, 2);
    }
}
