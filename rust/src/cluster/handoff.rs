//! Cross-pool KV handoff accounting for disaggregated fleets.
//!
//! When a prefill replica finishes a request's prompt pass, its KV blocks
//! must reach a decode replica over the fleet's modeled interconnect
//! ([`super::topology::Interconnect`]). Nothing moves real bytes — like
//! `sim/host_transfer.rs`, the link is a latency oracle on the virtual
//! clock — but the *accounting* is real and must balance: every block
//! that departs a prefill replica is either **delivered** to a decode
//! replica or **cancelled** (the decode pool refused the continuation),
//! never both, never neither, and never twice.
//!
//! The [`TransferLedger`] is the single bookkeeper for that flow. The
//! fleet opens a [`Transfer`] per handoff at prefill-finish time and
//! closes it exactly once at decode-admission (or refusal) time; the
//! property suite in `rust/tests/disaggregation.rs` drives random
//! admit/handoff/cancel interleavings against [`TransferLedger::
//! check_invariants`] to prove the accounting never leaks or
//! double-frees.
//!
//! Handoff state machine (one `Transfer` per request):
//!
//! ```text
//!   prefill finishes            decode admits
//!  ───────────────▶  IN-FLIGHT ───────────────▶ DELIVERED
//!      begin()           │         deliver()
//!                        │ decode refuses
//!                        └────────────────────▶ CANCELLED
//!                                  cancel()
//! ```

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::coordinator::RequestId;

/// One in-flight KV handoff: `blocks` KV blocks leaving prefill replica
/// `from` at `depart_us`, landing (if delivered) at `arrive_us` =
/// depart + the interconnect's one-way wire time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    pub request: RequestId,
    /// Global index of the prefill replica the blocks left.
    pub from: usize,
    /// KV blocks on the wire (the request's prompt + first token,
    /// rounded up to the prefill replica's block size).
    pub blocks: usize,
    /// Virtual-clock instant the prefill leg finished.
    pub depart_us: u64,
    /// Earliest instant the decode pool can admit the continuation.
    pub arrive_us: u64,
}

/// Balance-sheet for cross-pool KV transfers. Conservation law:
/// `begun == delivered + cancelled + in_flight`, and the same identity
/// block-for-block.
#[derive(Debug, Default)]
pub struct TransferLedger {
    in_flight: HashMap<RequestId, Transfer>,
    begun: usize,
    delivered: usize,
    cancelled: usize,
    blocks_sent: usize,
    blocks_delivered: usize,
    blocks_cancelled: usize,
    /// Total one-way wire time paid by delivered + cancelled transfers.
    total_wire_us: u64,
}

impl TransferLedger {
    /// An empty ledger.
    pub fn new() -> TransferLedger {
        TransferLedger::default()
    }

    /// Open a handoff: the request's KV is now on the wire. A second
    /// `begin` for the same request would double-count its blocks, so it
    /// is an error, not an overwrite.
    pub fn begin(&mut self, t: Transfer) -> Result<()> {
        if self.in_flight.contains_key(&t.request) {
            bail!("request {} already has an in-flight KV transfer", t.request);
        }
        self.begun += 1;
        self.blocks_sent += t.blocks;
        self.in_flight.insert(t.request, t);
        Ok(())
    }

    /// Close a handoff as delivered (decode admitted the continuation).
    /// Delivering a transfer that was never begun — or one already
    /// closed — is the double-free analog and fails loudly.
    pub fn deliver(&mut self, request: RequestId) -> Result<Transfer> {
        let Some(t) = self.in_flight.remove(&request) else {
            bail!("request {request} has no in-flight KV transfer to deliver");
        };
        self.delivered += 1;
        self.blocks_delivered += t.blocks;
        self.total_wire_us += t.arrive_us - t.depart_us;
        Ok(t)
    }

    /// Close a handoff as cancelled (the decode pool refused the
    /// continuation). The wire time was still paid — the blocks crossed
    /// before the refusal — so it still accrues.
    pub fn cancel(&mut self, request: RequestId) -> Result<Transfer> {
        let Some(t) = self.in_flight.remove(&request) else {
            bail!("request {request} has no in-flight KV transfer to cancel");
        };
        self.cancelled += 1;
        self.blocks_cancelled += t.blocks;
        self.total_wire_us += t.arrive_us - t.depart_us;
        Ok(t)
    }

    /// Transfers currently on the wire.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Blocks currently on the wire.
    pub fn in_flight_blocks(&self) -> usize {
        self.in_flight.values().map(|t| t.blocks).sum()
    }

    /// Handoffs opened over the ledger's lifetime.
    pub fn begun(&self) -> usize {
        self.begun
    }

    /// Handoffs closed as delivered.
    pub fn delivered(&self) -> usize {
        self.delivered
    }

    /// Handoffs closed as cancelled.
    pub fn cancelled(&self) -> usize {
        self.cancelled
    }

    /// Blocks closed as delivered.
    pub fn blocks_delivered(&self) -> usize {
        self.blocks_delivered
    }

    /// Total one-way wire time paid by closed transfers, µs.
    pub fn total_wire_us(&self) -> u64 {
        self.total_wire_us
    }

    /// True once every opened handoff has been closed — the full-drain
    /// condition a finished fleet run must satisfy.
    pub fn drained(&self) -> bool {
        self.in_flight.is_empty()
    }

    /// Conservation check: counts and blocks both balance. Returns the
    /// violation as an error so property tests can surface it verbatim.
    pub fn check_invariants(&self) -> Result<()> {
        if self.begun != self.delivered + self.cancelled + self.in_flight.len() {
            bail!(
                "transfer count leak: begun {} != delivered {} + cancelled {} + in-flight {}",
                self.begun,
                self.delivered,
                self.cancelled,
                self.in_flight.len()
            );
        }
        let on_wire = self.in_flight_blocks();
        if self.blocks_sent != self.blocks_delivered + self.blocks_cancelled + on_wire {
            bail!(
                "transfer block leak: sent {} != delivered {} + cancelled {} + on-wire {}",
                self.blocks_sent,
                self.blocks_delivered,
                self.blocks_cancelled,
                on_wire
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xfer(request: RequestId, blocks: usize) -> Transfer {
        Transfer { request, from: 0, blocks, depart_us: 100, arrive_us: 150 }
    }

    #[test]
    fn ledger_balances_through_deliver_and_cancel() {
        let mut l = TransferLedger::new();
        l.begin(xfer(1, 4)).unwrap();
        l.begin(xfer(2, 7)).unwrap();
        assert_eq!(l.in_flight(), 2);
        assert_eq!(l.in_flight_blocks(), 11);
        l.check_invariants().unwrap();
        assert!(!l.drained());

        let t = l.deliver(1).unwrap();
        assert_eq!(t.blocks, 4);
        l.cancel(2).unwrap();
        l.check_invariants().unwrap();
        assert!(l.drained());
        assert_eq!((l.begun(), l.delivered(), l.cancelled()), (2, 1, 1));
        assert_eq!(l.blocks_delivered(), 4);
        assert_eq!(l.total_wire_us(), 100, "both closures paid the 50 µs wire");
    }

    #[test]
    fn double_begin_and_double_close_fail_loudly() {
        let mut l = TransferLedger::new();
        l.begin(xfer(1, 4)).unwrap();
        assert!(l.begin(xfer(1, 4)).unwrap_err().to_string().contains("already has"));
        l.deliver(1).unwrap();
        // Both closure paths reject an already-closed transfer.
        assert!(l.deliver(1).is_err());
        assert!(l.cancel(1).is_err());
        // Closing a never-begun transfer is the same error.
        assert!(l.deliver(99).unwrap_err().to_string().contains("no in-flight"));
        l.check_invariants().unwrap();
    }
}
