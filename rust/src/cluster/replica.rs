//! One fleet replica: a full serving [`Engine`] over its own simulated
//! device, planning the **sharded** attention geometry.
//!
//! A replica models one tensor-parallel group as a single engine: the
//! devices inside a TP group run in lockstep (same batch, same schedule),
//! so the group's decode launch is one sharded-shape plan on one device
//! profile. The fleet drives replicas on their virtual clocks
//! ([`Replica::advance_to`]) so routing decisions see each replica's true
//! state at every arrival instant.

use anyhow::{Context, Result};

use crate::backend::{AttnGeometry, SimBackend};
use crate::coordinator::{
    Engine, EngineConfig, EngineMetrics, FinishedRequest, Request, SubmitError,
};
use crate::planner::Planner;

use super::router::ReplicaSnapshot;
use super::topology::{ReplicaRole, ReplicaSpec};

/// One replica of the fleet.
pub struct Replica {
    index: usize,
    device_name: &'static str,
    /// Pool membership (`Unified` on colocated topologies).
    role: ReplicaRole,
    engine: Engine,
    /// Requests the router has assigned here (accepted by `submit_at`).
    assigned: usize,
    /// Requests refused at submission (never-fits shapes; the router
    /// contract makes this 0 in healthy fleets).
    rejected: usize,
}

impl Replica {
    /// Build a replica over its own [`SimBackend`] for `spec.device`,
    /// planning `shard` (the topology-derived per-shard geometry) with
    /// `planner` (constructed for the same device by the fleet).
    pub fn new(
        index: usize,
        spec: &ReplicaSpec,
        shard: AttnGeometry,
        planner: Planner,
        default_cfg: &EngineConfig,
    ) -> Result<Replica> {
        let cfg = spec.engine.clone().unwrap_or_else(|| default_cfg.clone());
        let mut engine = Engine::builder(Box::new(SimBackend::for_profile(&spec.device)))
            .planner(planner)
            .geometry(shard)
            .config(cfg)
            .build()
            .with_context(|| format!("building replica {index} ({})", spec.device.name))?;
        // Tag the flight recorder so merged fleet traces keep one Chrome
        // process (pid) per replica.
        engine.recorder_mut().set_replica(index as u32);
        Ok(Replica {
            index,
            device_name: spec.device.name,
            role: spec.role,
            engine,
            assigned: 0,
            rejected: 0,
        })
    }

    /// This replica's index in the fleet.
    pub fn index(&self) -> usize {
        self.index
    }

    /// Which pool this replica serves (`Unified` when colocated).
    pub fn role(&self) -> ReplicaRole {
        self.role
    }

    /// The device-profile preset name this replica simulates.
    pub fn device_name(&self) -> &'static str {
        self.device_name
    }

    /// The replica's serving engine (read-only).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The replica engine's rolling metrics.
    pub fn metrics(&self) -> &EngineMetrics {
        &self.engine.metrics
    }

    /// Mutable metrics access (the Prometheus exposition syncs mirrored
    /// counters into the registry before rendering).
    pub fn metrics_mut(&mut self) -> &mut EngineMetrics {
        &mut self.engine.metrics
    }

    /// The replica engine's flight recorder (trace export).
    pub fn recorder(&self) -> &crate::obs::FlightRecorder {
        self.engine.recorder()
    }

    /// Requests the router has placed here.
    pub fn assigned(&self) -> usize {
        self.assigned
    }

    /// Requests refused at submission (never-fits shapes).
    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// The router-facing load snapshot for a prospective request. Takes
    /// the request itself (not just its lengths) because the snapshot is
    /// prefix-aware: it probes the replica's block manager for the
    /// prompt's resident prefix, so routers see request-relative KV
    /// pressure and admissibility net of sharing.
    pub fn snapshot_for(&self, req: &Request) -> ReplicaSnapshot {
        let blocks = self.engine.block_manager();
        let probe = blocks.probe(&req.prompt);
        let bs = blocks.config().block_size;
        ReplicaSnapshot {
            index: self.index,
            queue_depth: self.engine.waiting_len() + self.engine.pending_len(),
            running: self.engine.running_len(),
            free_blocks: blocks.free_blocks(),
            total_blocks: blocks.config().num_blocks,
            can_admit_now: blocks.can_admit_prompt(&req.prompt, req.max_new_tokens),
            can_ever_admit: blocks.can_ever_admit(req.prompt.len(), req.max_new_tokens),
            shared_blocks: probe.matched_blocks,
            demand_blocks: (req.prompt.len() + req.max_new_tokens).div_ceil(bs),
        }
    }

    /// Place a routed request as an open-loop arrival at `arrival_us` on
    /// this replica's virtual clock.
    pub fn submit_at(&mut self, req: Request, arrival_us: u64) -> Result<(), SubmitError> {
        // The handle is dropped: fleet consumers read results from the
        // engine's finished set (streams remain per-request features of
        // the single-engine API).
        match self.engine.submit_at(req, arrival_us) {
            Ok(_handle) => {
                self.assigned += 1;
                Ok(())
            }
            Err(e) => {
                self.rejected += 1;
                Err(e)
            }
        }
    }

    /// Land a cross-pool KV handoff on this (decode) replica: import the
    /// handed-off token prefix as evictable cache blocks so the routed
    /// continuation admits against a warm cache. Passthrough to
    /// [`Engine::import_handoff`]; returns the imported block count.
    pub fn import_handoff(&mut self, request: u64, tokens: &[i32], wire_us: u64) -> usize {
        self.engine.import_handoff(request, tokens, wire_us)
    }

    /// Step the engine until its virtual clock reaches `t_us` or it goes
    /// idle — how the fleet interleaves replicas on a shared timeline.
    /// This loop runs for every replica at every fleet arrival, so it
    /// inherits the engine's zero-allocation steady-state step: advancing
    /// N replicas across a tick reuses each engine's scratch and plan
    /// cursor rather than multiplying per-step allocations by the fleet
    /// size.
    pub fn advance_to(&mut self, t_us: u64) -> Result<()> {
        while !self.engine.is_idle() && self.engine.now_us() < t_us {
            self.engine.step()?;
        }
        Ok(())
    }

    /// Drain to completion and return everything that finished on this
    /// replica (including requests completed during earlier `advance_to`
    /// calls).
    pub fn run_until_idle(&mut self) -> Result<Vec<FinishedRequest>> {
        self.engine.run_until_idle()
    }
}

impl std::fmt::Debug for Replica {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Replica")
            .field("index", &self.index)
            .field("device", &self.device_name)
            .field("role", &self.role)
            .field("assigned", &self.assigned)
            .field("running", &self.engine.running_len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology::{ClusterTopology, TpConfig};
    use crate::coordinator::FinishReason;
    use crate::planner::{DeviceProfile, PolicyRegistry};

    fn replica() -> Replica {
        let topo = ClusterTopology::builder(AttnGeometry {
            h_q: 64,
            h_kv: 8,
            d: 128,
            max_seq: 1024,
        })
        .tp(TpConfig::new(8))
        .replicas(1, DeviceProfile::H100_SXM)
        .build()
        .unwrap();
        let planner = PolicyRegistry::builtin()
            .builder_for("sequence-aware", &DeviceProfile::H100_SXM)
            .unwrap()
            .build();
        Replica::new(0, &topo.replicas()[0], topo.shard_geometry(), planner, &EngineConfig::default())
            .unwrap()
    }

    #[test]
    fn replica_serves_the_sharded_shape() {
        let mut r = replica();
        r.submit_at(Request::new(1, vec![7; 400], 20), 0).unwrap();
        let done = r.run_until_idle().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].reason, FinishReason::Length);
        assert_eq!(r.assigned(), 1);
        // TP-8 shard of the 8-KV-head model ⇒ 1 tile at B=1 ⇒ the
        // sequence-aware override fires in the boundary bucket (s = 3).
        assert!(r.metrics().split_histogram.get(3).copied().unwrap_or(0) > 0);
        assert!(r.metrics().mean_occupancy().unwrap() > 0.0);
    }

    #[test]
    fn advance_to_interleaves_on_the_virtual_clock() {
        let mut r = replica();
        r.submit_at(Request::new(1, vec![7; 64], 900), 0).unwrap();
        r.advance_to(5_000).unwrap();
        let t = r.engine().now_us();
        assert!(t >= 5_000, "clock advanced to the target, got {t}");
        assert!(!r.engine().is_idle(), "900 tokens outlast 5 ms here");
        r.run_until_idle().unwrap();
        assert!(r.engine().is_idle());
    }

    #[test]
    fn snapshot_reflects_queue_blocks_and_resident_prefixes() {
        let mut r = replica();
        let probe_req = Request::new(99, vec![5; 100], 50);
        let s0 = r.snapshot_for(&probe_req);
        assert_eq!(s0.queue_depth + s0.running, 0);
        assert!(s0.can_admit_now && s0.can_ever_admit);
        assert_eq!(s0.shared_blocks, 0);
        assert_eq!(s0.demand_blocks, 10); // 150 tokens / 16 per block
        r.submit_at(Request::new(1, vec![7; 64], 10), 0).unwrap();
        let s1 = r.snapshot_for(&probe_req);
        assert_eq!(s1.queue_depth, 1, "pending open-loop arrival counts as queued");
        // Once the replica serves a request, a same-prefix probe sees
        // its resident blocks (request-relative KV pressure).
        r.run_until_idle().unwrap();
        let warm = r.snapshot_for(&Request::new(3, vec![7; 64], 10));
        assert_eq!(warm.shared_blocks, 4, "64 tokens = 4 resident blocks");
        assert!(warm.prefix_hit_ratio() > 0.0);
        // Oversized request: refused at submission and counted.
        let err = r.submit_at(Request::new(2, vec![7; 2000], 10), 0).unwrap_err();
        assert!(matches!(err, SubmitError::Unschedulable { .. }));
        assert_eq!(r.rejected(), 1);
        assert!(!r.snapshot_for(&Request::new(4, vec![7; 2000], 10)).can_ever_admit);
    }
}
