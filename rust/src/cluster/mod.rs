//! The cluster subsystem: tensor-parallel head sharding + multi-replica
//! fleet routing over the planner/backend stack.
//!
//! The paper's premise is that low head count starves Hopper SMs — and the
//! most common way production deployments *enter* that regime is tensor
//! parallelism, which divides KV heads across GPUs: a TP-8 shard of an
//! 8-KV-head GQA model decodes with one KV head per device, exactly the
//! `Batch × H_KV < 4` tile counts where the sequence-aware policy's 21–24%
//! window opens. This module models the cluster level where that per-shard
//! head count is *decided*:
//!
//! * [`topology`] — [`ClusterTopology`] + [`TpConfig`]: derives the
//!   per-shard [`crate::backend::AttnGeometry`] (head divisibility and
//!   PackGqa packing validated at build time) so each replica's
//!   [`crate::planner::Planner`] plans the **sharded** shape,
//! * [`router`]   — the [`Router`] contract with [`RoundRobin`],
//!   [`LeastLoaded`] (queue depth + KV-block pressure),
//!   [`SessionAffinity`] (sticky: a session's KV stays on its replica),
//!   and the two-stage [`Disaggregated`] (prefill placement, then sticky
//!   decode placement) policies, placed in front of each replica's
//!   admission controller,
//! * [`replica`]  — one TP group as a full [`crate::coordinator::Engine`]
//!   over its own [`crate::backend::SimBackend`] (heterogeneous device
//!   profiles allowed), each tagged with a [`ReplicaRole`],
//! * [`handoff`]  — the [`TransferLedger`]: leak-free accounting for KV
//!   blocks crossing the modeled [`Interconnect`] between a prefill and
//!   a decode pool,
//! * [`fleet`]    — the driver that fans a
//!   [`crate::workload::ChatWorkload`] stream across replicas on the
//!   simulated virtual clock and aggregates [`FleetReport`] metrics
//!   (per-replica SM occupancy, pooled TTFT/TPOT, load imbalance,
//!   aggregate tokens/s; per-pool occupancy/imbalance/goodput and the
//!   decode-pool TPOT when disaggregated).
//!
//! Disaggregation matters here for the same reason TP does: the
//! sequence-aware policy pays off almost exclusively in `q_len = 1`
//! decode steps, so pooling decode on its own replicas concentrates the
//! paper's starved regime on hardware that does nothing else — prefill
//! interference leaves the decode pool entirely, at the price of one
//! modeled KV transfer per request between the pools.
//!
//! Surfaces: the `fa3-split cluster` CLI subcommand (`--roles`/`--xfer`
//! select pooling and the link), the `benches/cluster_scale.rs` and
//! `benches/disaggregation.rs` sweeps (`BENCH_cluster_scale.json`,
//! `BENCH_disaggregation.json`), and the `rust/tests/cluster_fleet.rs`,
//! `rust/tests/router_conformance.rs`, and `rust/tests/disaggregation.rs`
//! suites.

pub mod fleet;
pub mod handoff;
pub mod replica;
pub mod router;
pub mod topology;

pub use fleet::{Assignment, Fleet, FleetConfig, FleetReport, ReplicaReport};
pub use handoff::{Transfer, TransferLedger};
pub use replica::Replica;
pub use router::{
    Disaggregated, LeastLoaded, ReplicaSnapshot, RouteError, Router, RoundRobin,
    SessionAffinity, ROUTER_NAMES,
};
pub use topology::{
    ClusterTopology, ClusterTopologyBuilder, Interconnect, ReplicaRole, ReplicaSpec,
    TopologyError, TpConfig, INTERCONNECT_NAMES, KV_BLOCK_BYTES,
};
