//! The cluster subsystem: tensor-parallel head sharding + multi-replica
//! fleet routing over the planner/backend stack.
//!
//! The paper's premise is that low head count starves Hopper SMs — and the
//! most common way production deployments *enter* that regime is tensor
//! parallelism, which divides KV heads across GPUs: a TP-8 shard of an
//! 8-KV-head GQA model decodes with one KV head per device, exactly the
//! `Batch × H_KV < 4` tile counts where the sequence-aware policy's 21–24%
//! window opens. This module models the cluster level where that per-shard
//! head count is *decided*:
//!
//! * [`topology`] — [`ClusterTopology`] + [`TpConfig`]: derives the
//!   per-shard [`crate::backend::AttnGeometry`] (head divisibility and
//!   PackGqa packing validated at build time) so each replica's
//!   [`crate::planner::Planner`] plans the **sharded** shape,
//! * [`router`]   — the [`Router`] contract with [`RoundRobin`],
//!   [`LeastLoaded`] (queue depth + KV-block pressure), and
//!   [`SessionAffinity`] (sticky: a session's KV stays on its replica)
//!   policies, placed in front of each replica's admission controller,
//! * [`replica`]  — one TP group as a full [`crate::coordinator::Engine`]
//!   over its own [`crate::backend::SimBackend`] (heterogeneous device
//!   profiles allowed),
//! * [`fleet`]    — the driver that fans a
//!   [`crate::workload::ChatWorkload`] stream across replicas on the
//!   simulated virtual clock and aggregates [`FleetReport`] metrics
//!   (per-replica SM occupancy, pooled TTFT/TPOT, load imbalance,
//!   aggregate tokens/s).
//!
//! Surfaces: the `fa3-split cluster` CLI subcommand, the
//! `benches/cluster_scale.rs` sweep (`BENCH_cluster_scale.json` — the
//! occupancy gap widening as sharding shrinks head count), and the
//! `rust/tests/cluster_fleet.rs` suite.

pub mod fleet;
pub mod replica;
pub mod router;
pub mod topology;

pub use fleet::{Assignment, Fleet, FleetConfig, FleetReport, ReplicaReport};
pub use replica::Replica;
pub use router::{
    LeastLoaded, ReplicaSnapshot, RouteError, Router, RoundRobin, SessionAffinity, ROUTER_NAMES,
};
pub use topology::{
    ClusterTopology, ClusterTopologyBuilder, ReplicaSpec, TopologyError, TpConfig,
};
