//! The fleet driver: fan a chat stream across replicas on the simulated
//! virtual clock and aggregate fleet-level metrics.
//!
//! Data flow (DESIGN.md §Cluster):
//!
//! ```text
//! ClusterTopology ──derives──► per-shard AttnGeometry
//!        │                            │
//!        ▼                            ▼
//! Fleet::new ── per replica: PolicyRegistry planner(device) + SimBackend(device)
//!        │
//! Fleet::run(stream):
//!   for each arrival (time-ordered):
//!     advance every replica's virtual clock to the arrival instant
//!     snapshot replicas ──► Router::route ──► Replica::submit_at
//!   drain all replicas ──► FleetReport (per-replica + pooled metrics)
//! ```
//!
//! Routing therefore happens **before** each replica's admission
//! controller: the router picks placement from live load snapshots, the
//! replica's bounded queues still decide acceptance, and rejected
//! submissions are counted, never retried elsewhere (a retry would make
//! the A/B benches sensitive to rejection order; explicit is better).

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{
    EngineConfig, FinishedRequest, Request, RequestId, RequestTiming,
};
use crate::planner::PolicyRegistry;
use crate::util::stats::Summary;
use crate::util::table::{Align, Table};
use crate::workload::GeneratedRequest;

use super::handoff::{Transfer, TransferLedger};
use super::replica::Replica;
use super::router::{ReplicaSnapshot, RouteError, Router};
use super::topology::{ClusterTopology, ReplicaRole};

/// Fleet-wide configuration.
pub struct FleetConfig {
    /// Split-policy name resolved through the [`PolicyRegistry`] for each
    /// replica's device (so device-dependent policies tune per replica).
    pub policy: String,
    /// Default engine configuration (replica specs may override).
    pub engine: EngineConfig,
    /// Registry the policy is resolved from.
    pub registry: PolicyRegistry,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            policy: "sequence-aware".to_string(),
            engine: EngineConfig::default(),
            registry: PolicyRegistry::builtin(),
        }
    }
}

impl FleetConfig {
    /// Select the split policy every replica's planner is built with.
    pub fn policy(mut self, name: impl Into<String>) -> FleetConfig {
        self.policy = name.into();
        self
    }

    /// Set the default engine configuration (replica specs may override).
    pub fn engine(mut self, cfg: EngineConfig) -> FleetConfig {
        self.engine = cfg;
        self
    }
}

/// One routing decision, recorded for affinity/balance assertions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub request: RequestId,
    pub session: u64,
    pub replica: usize,
}

/// The fleet: replicas + router + recorded assignments.
pub struct Fleet {
    topology: ClusterTopology,
    replicas: Vec<Replica>,
    router: Box<dyn Router>,
    policy: String,
    assignments: Vec<Assignment>,
    /// Prefill-leg placements on a disaggregated fleet (empty when
    /// colocated; `assignments` then holds the decode-leg placements the
    /// affinity invariants are checked against).
    prefill_assignments: Vec<Assignment>,
    /// Cross-pool KV transfer accounting (empty when colocated).
    ledger: TransferLedger,
    rejected: usize,
    /// Latest arrival placed so far — `submit_at` enforces monotone
    /// arrivals (an out-of-order arrival would race replicas whose
    /// virtual clocks already fast-forwarded past it).
    last_arrival_us: u64,
    /// `run` is one-shot: per-replica engine metrics accumulate for the
    /// fleet's lifetime, so a second run would report contaminated
    /// aggregates. Enforced, not just documented.
    ran: bool,
    /// Routing scratch: per-arrival load snapshots, reused across the
    /// whole stream (with every replica's step loop now allocation-free
    /// in steady state, a fresh Vec per arrival would be the fleet tick's
    /// only remaining heap traffic).
    snaps: Vec<ReplicaSnapshot>,
}

impl Fleet {
    /// Build every replica: a planner for the replica's device (via the
    /// registry, so e.g. `extended` tunes against the right part) over a
    /// `SimBackend` of the same profile, all planning the topology's
    /// sharded geometry.
    pub fn new(
        topology: ClusterTopology,
        mut router: Box<dyn Router>,
        cfg: FleetConfig,
    ) -> Result<Fleet> {
        if topology.is_disaggregated() && router.two_stage().is_none() {
            bail!(
                "router '{}' is single-stage; a disaggregated topology (prefill/decode pools) \
                 requires the 'disaggregated' two-stage router",
                router.name()
            );
        }
        let shard = topology.shard_geometry();
        let mut replicas = Vec::with_capacity(topology.num_replicas());
        for (index, spec) in topology.replicas().iter().enumerate() {
            let planner = cfg
                .registry
                .builder_for(&cfg.policy, &spec.device)
                .map_err(|e| anyhow!(e))?
                .build();
            replicas.push(Replica::new(index, spec, shard, planner, &cfg.engine)?);
        }
        let num_replicas = replicas.len();
        Ok(Fleet {
            topology,
            replicas,
            router,
            policy: cfg.policy,
            assignments: Vec::new(),
            prefill_assignments: Vec::new(),
            ledger: TransferLedger::new(),
            rejected: 0,
            last_arrival_us: 0,
            ran: false,
            snaps: Vec::with_capacity(num_replicas),
        })
    }

    /// The topology this fleet was built from.
    pub fn topology(&self) -> &ClusterTopology {
        &self.topology
    }

    /// The fleet's replicas, in index order.
    pub fn replicas(&self) -> &[Replica] {
        &self.replicas
    }

    /// The routing policy's registry name.
    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// The split policy every replica plans with.
    pub fn policy_name(&self) -> &str {
        &self.policy
    }

    /// Every routing decision made so far, in arrival order. On a
    /// disaggregated fleet these are the **decode-leg** placements (the
    /// ones session affinity governs); see [`Fleet::prefill_assignments`].
    pub fn assignments(&self) -> &[Assignment] {
        &self.assignments
    }

    /// Prefill-leg placements on a disaggregated fleet (empty otherwise).
    pub fn prefill_assignments(&self) -> &[Assignment] {
        &self.prefill_assignments
    }

    /// The cross-pool KV transfer ledger (all-zero when colocated).
    pub fn ledger(&self) -> &TransferLedger {
        &self.ledger
    }

    /// Route and place one arrival at `arrival_us` on the fleet timeline.
    /// Every replica is first advanced to the arrival instant so the
    /// router sees true load. Arrivals must be monotone: replica clocks
    /// only move forward, so a past-dated arrival would be served out of
    /// order against requests the fleet already placed.
    ///
    /// Returns `Ok(Some(replica))` when placed, `Ok(None)` when the
    /// request was *refused* (unroutable, or the replica rejected the
    /// submission) — refusals are counted in the report, never fatal: one
    /// impossible request must not discard every already-served result of
    /// a one-shot run. `Err` is reserved for real failures (ordering
    /// violations, router contract breaches, engine errors).
    pub fn submit_at(&mut self, g: &GeneratedRequest, arrival_us: u64) -> Result<Option<usize>> {
        if arrival_us < self.last_arrival_us {
            bail!(
                "arrivals must be time-ordered: request {} at {arrival_us}µs after one at {}µs",
                g.request.id,
                self.last_arrival_us
            );
        }
        self.last_arrival_us = arrival_us;
        for r in &mut self.replicas {
            r.advance_to(arrival_us)?;
        }
        // Refill the reused snapshot scratch (ReplicaSnapshot is Copy).
        // Snapshots are prefix-aware: each replica probes the request's
        // prompt against its own block index, so the router sees where
        // the prefix already lives.
        self.snaps.clear();
        for r in &self.replicas {
            self.snaps.push(r.snapshot_for(&g.request));
        }
        let idx = match self.router.route(&g.request, g.session, &self.snaps) {
            Ok(idx) => idx,
            Err(RouteError::Unroutable { .. }) => {
                self.rejected += 1;
                return Ok(None);
            }
            Err(e @ RouteError::NoReplicas) => return Err(e.into()),
        };
        // Router contract (DESIGN.md §Cluster invariants 1 and 4).
        self.check_route_contract(idx, g.request.id)?;
        match self.replicas[idx].submit_at(g.request.clone(), arrival_us) {
            Ok(()) => {
                self.assignments.push(Assignment {
                    request: g.request.id,
                    session: g.session,
                    replica: idx,
                });
                Ok(Some(idx))
            }
            Err(_refused) => {
                self.rejected += 1;
                Ok(None)
            }
        }
    }

    /// Router contract (DESIGN.md §Cluster invariants 1 and 4): the
    /// routed index must name a member of the snapshot slice the router
    /// was shown (a pool subset on disaggregated fleets — membership is
    /// resolved by `ReplicaSnapshot::index`, never by slice position)
    /// that can ever admit the request. A misbehaving custom `Router`
    /// hits this error path, not a panic or a silently-wrong placement.
    fn check_route_contract(&self, idx: usize, request: RequestId) -> Result<()> {
        let member = self.snaps.iter().find(|s| s.index == idx);
        let eligible = member.is_some_and(|s| s.can_ever_admit);
        if !eligible {
            bail!(
                "router '{}' violated its contract: replica {idx} {} request {}",
                self.router.name(),
                if member.is_some() { "can never admit" } else { "is not a candidate for" },
                request
            );
        }
        Ok(())
    }

    /// Fan a generated stream (time-ordered, as `ChatWorkload::generate`
    /// produces) across the fleet, drain every replica, and report.
    /// One-shot: build a fresh fleet per run (engine metrics and routing
    /// state accumulate for the fleet's lifetime).
    ///
    /// ```
    /// use fa3_split::backend::AttnGeometry;
    /// use fa3_split::cluster::{ClusterTopology, Fleet, FleetConfig, SessionAffinity, TpConfig};
    /// use fa3_split::planner::DeviceProfile;
    /// use fa3_split::workload::ChatWorkload;
    ///
    /// let topology = ClusterTopology::builder(
    ///     AttnGeometry { h_q: 64, h_kv: 8, d: 128, max_seq: 1024 },
    /// )
    /// .tp(TpConfig::new(8)) // per-shard H_KV = 1: the paper's regime
    /// .replicas(2, DeviceProfile::H100_SXM)
    /// .build()
    /// .unwrap();
    /// let mut fleet =
    ///     Fleet::new(topology, Box::new(SessionAffinity::new()), FleetConfig::default()).unwrap();
    /// let stream = ChatWorkload { n_requests: 4, turns_per_session: 2, ..Default::default() };
    /// let report = fleet.run(&stream.generate()).unwrap();
    /// assert_eq!(report.finished.len(), 4);
    /// assert_eq!(report.affinity_violations(), 0);
    /// ```
    pub fn run(&mut self, stream: &[GeneratedRequest]) -> Result<FleetReport> {
        if self.ran {
            bail!("Fleet::run is one-shot (aggregates would mix runs); build a new Fleet");
        }
        self.ran = true;
        if self.topology.is_disaggregated() {
            return self.run_disaggregated(stream);
        }
        // Arrival ordering is enforced per submission by `submit_at`
        // (`ChatWorkload::generate` produces ordered streams by
        // construction).
        for g in stream {
            self.submit_at(g, g.arrival_offset_us)?;
        }
        let mut finished: Vec<FinishedRequest> = Vec::new();
        for r in &mut self.replicas {
            finished.extend(r.run_until_idle()?);
        }
        Ok(self.report(finished, None))
    }

    /// The role-aware run loop: every request makes a **prefill leg**
    /// (prompt + first token) in the prefill pool, hands its KV across
    /// the modeled interconnect, then runs its **decode leg** (the
    /// remaining tokens) in the decode pool.
    ///
    /// ```text
    /// arrival ──route_prefill──► prefill pool ──finish(t0)──┐
    ///                                                       │ ledger.begin
    ///                                     [wire: Interconnect::transfer_us]
    ///                                                       │
    ///   decode pool ◄──route (sticky) ◄── continuation arrives at
    ///       │                             depart + wire
    ///       │ ledger.deliver + Replica::import_handoff (KV lands as
    ///       │ evictable prefix blocks; admission revives them, so the
    ///       │ continuation's prompt is a cache hit, not a re-prefill)
    ///       ▼
    ///   merged FinishedRequest (prefill timing front, decode tail)
    /// ```
    ///
    /// A continuation the decode pool refuses cancels its transfer on
    /// the ledger and counts as rejected — its prefill-leg work is
    /// dropped from the report (the request was never fully served).
    /// Requests that finish entirely at prefill (`max_new <= 1`, or cut
    /// short there) never enter the ledger.
    fn run_disaggregated(&mut self, stream: &[GeneratedRequest]) -> Result<FleetReport> {
        struct Pending {
            session: u64,
            max_new: usize,
            prompt: Vec<i32>,
            replica: usize,
        }
        let prefill_pool = self.topology.pool(ReplicaRole::Prefill);
        let decode_pool = self.topology.pool(ReplicaRole::Decode);
        let ic = self.topology.interconnect();

        // Phase 1: route and place every prefill leg in arrival order.
        let mut pending: HashMap<RequestId, Pending> = HashMap::new();
        for g in stream {
            let arrival_us = g.arrival_offset_us;
            if arrival_us < self.last_arrival_us {
                bail!(
                    "arrivals must be time-ordered: request {} at {arrival_us}µs after one at \
                     {}µs",
                    g.request.id,
                    self.last_arrival_us
                );
            }
            self.last_arrival_us = arrival_us;
            for r in &mut self.replicas {
                r.advance_to(arrival_us)?;
            }
            // The prefill leg runs the prompt and emits the first token
            // (`max_new.min(1)`: a zero-token request never decodes, so
            // it must not grow a token the colocated fleet wouldn't).
            let pre = Request::new(
                g.request.id,
                g.request.prompt.clone(),
                g.request.max_new_tokens.min(1),
            );
            self.snaps.clear();
            for &i in &prefill_pool {
                self.snaps.push(self.replicas[i].snapshot_for(&pre));
            }
            let routed = self
                .router
                .two_stage()
                .expect("Fleet::new validated a two-stage router")
                .route_prefill(&pre, g.session, &self.snaps);
            let idx = match routed {
                Ok(idx) => idx,
                Err(RouteError::Unroutable { .. }) => {
                    self.rejected += 1;
                    continue;
                }
                Err(e @ RouteError::NoReplicas) => return Err(e.into()),
            };
            self.check_route_contract(idx, g.request.id)?;
            match self.replicas[idx].submit_at(pre, arrival_us) {
                Ok(()) => {
                    self.prefill_assignments.push(Assignment {
                        request: g.request.id,
                        session: g.session,
                        replica: idx,
                    });
                    pending.insert(
                        g.request.id,
                        Pending {
                            session: g.session,
                            max_new: g.request.max_new_tokens,
                            prompt: g.request.prompt.clone(),
                            replica: idx,
                        },
                    );
                }
                Err(_refused) => {
                    self.rejected += 1;
                }
            }
        }

        // Phase 2: drain the prefill pool.
        let mut prefill_fins: Vec<FinishedRequest> = Vec::new();
        for &i in &prefill_pool {
            prefill_fins.extend(self.replicas[i].run_until_idle()?);
        }

        // Phase 3: open a transfer per continuation-bound finish.
        // Requests that are already complete (nothing left to decode, or
        // cut short at prefill) are final as-is.
        struct Handoff {
            fin: FinishedRequest,
            session: u64,
            max_new: usize,
            prompt: Vec<i32>,
            transfer: Transfer,
        }
        let mut merged: Vec<FinishedRequest> = Vec::new();
        let mut handoffs: Vec<Handoff> = Vec::new();
        for fin in prefill_fins {
            let Some(p) = pending.remove(&fin.id) else {
                bail!("prefill pool finished unrouted request {}", fin.id);
            };
            if !fin.reason.is_natural() || p.max_new <= 1 {
                merged.push(fin);
                continue;
            }
            let bs =
                self.replicas[p.replica].engine().block_manager().config().block_size;
            let blocks = (fin.prompt_len + fin.tokens.len()).div_ceil(bs);
            let depart_us = fin.timing.finished_us;
            let transfer = Transfer {
                request: fin.id,
                from: p.replica,
                blocks,
                depart_us,
                arrive_us: depart_us + ic.transfer_us(blocks),
            };
            self.ledger.begin(transfer)?;
            handoffs.push(Handoff {
                fin,
                session: p.session,
                max_new: p.max_new,
                prompt: p.prompt,
                transfer,
            });
        }

        // Phase 4: land continuations on the decode pool in wire-arrival
        // order (ties broken by request id for determinism).
        handoffs.sort_by_key(|h| (h.transfer.arrive_us, h.fin.id));
        let mut continued: Vec<Handoff> = Vec::new();
        for h in handoffs {
            for &i in &decode_pool {
                self.replicas[i].advance_to(h.transfer.arrive_us)?;
            }
            // Continuation = original prompt ++ the prefill-leg token;
            // the sim backend's position-pure tokens make its output the
            // exact tail of the colocated stream.
            let mut cont_prompt = h.prompt.clone();
            cont_prompt.extend_from_slice(&h.fin.tokens);
            let cont = Request::new(h.fin.id, cont_prompt.clone(), h.max_new - 1);
            self.snaps.clear();
            for &i in &decode_pool {
                self.snaps.push(self.replicas[i].snapshot_for(&cont));
            }
            let routed = self.router.route(&cont, h.session, &self.snaps);
            let idx = match routed {
                Ok(idx) => idx,
                Err(RouteError::Unroutable { .. }) => {
                    // The decode pool refused the continuation: the
                    // blocks crossed the wire for nothing.
                    self.ledger.cancel(h.fin.id)?;
                    self.rejected += 1;
                    continue;
                }
                Err(e @ RouteError::NoReplicas) => return Err(e.into()),
            };
            self.check_route_contract(idx, h.fin.id)?;
            self.ledger.deliver(h.fin.id)?;
            let wire_us = h.transfer.arrive_us - h.transfer.depart_us;
            self.replicas[idx].import_handoff(h.fin.id, &cont_prompt, wire_us);
            match self.replicas[idx].submit_at(cont, h.transfer.arrive_us) {
                Ok(()) => {
                    self.assignments.push(Assignment {
                        request: h.fin.id,
                        session: h.session,
                        replica: idx,
                    });
                    continued.push(h);
                }
                Err(_refused) => {
                    // Delivered but refused at admission: the transfer
                    // stays closed (the import is just warm cache) and
                    // the request counts as rejected.
                    self.rejected += 1;
                }
            }
        }

        // Phase 5: drain the decode pool and merge each continuation
        // with its prefill leg: prefill-side arrival/TTFT, decode-side
        // finish, token streams concatenated.
        let mut decode_fins: HashMap<RequestId, FinishedRequest> = HashMap::new();
        for &i in &decode_pool {
            for fin in self.replicas[i].run_until_idle()? {
                decode_fins.insert(fin.id, fin);
            }
        }
        let mut decode_tpots: Vec<f64> = Vec::new();
        for h in continued {
            let Some(dec) = decode_fins.remove(&h.fin.id) else {
                bail!("decode pool lost admitted continuation {}", h.fin.id);
            };
            if dec.reason.is_natural() && dec.timing.n_generated >= 2 {
                decode_tpots.push(dec.timing.tpot_us());
            }
            let mut tokens = h.fin.tokens;
            tokens.extend_from_slice(&dec.tokens);
            merged.push(FinishedRequest {
                id: h.fin.id,
                prompt_len: h.fin.prompt_len,
                tokens,
                reason: dec.reason,
                priority: dec.priority,
                timing: RequestTiming {
                    arrival_us: h.fin.timing.arrival_us,
                    scheduled_us: h.fin.timing.scheduled_us,
                    first_token_us: h.fin.timing.first_token_us,
                    finished_us: dec.timing.finished_us,
                    n_generated: h.fin.timing.n_generated + dec.timing.n_generated,
                },
            });
        }
        merged.sort_by_key(|f| (f.timing.arrival_us, f.id));
        self.ledger.check_invariants()?;
        if !self.ledger.drained() {
            bail!("{} KV transfers still in flight after the run", self.ledger.in_flight());
        }
        let decode_pool_tpot = (!decode_tpots.is_empty()).then(|| Summary::of(&decode_tpots));
        Ok(self.report(merged, decode_pool_tpot))
    }

    /// Merge every replica's flight-recorder ring into one Chrome trace
    /// (one trace process per replica, labeled with its device). Replicas
    /// built without `trace_capacity` contribute only metadata. The CLI's
    /// `cluster --trace-out` writes this.
    pub fn chrome_trace(&self) -> crate::util::json::Json {
        let traces: Vec<crate::obs::ReplicaTrace> = self
            .replicas
            .iter()
            .map(|r| crate::obs::ReplicaTrace {
                pid: r.index() as u32,
                name: format!("replica {} ({})", r.index(), r.device_name()),
                recorder: r.recorder(),
            })
            .collect();
        crate::obs::fleet_trace(&traces)
    }

    /// Prometheus text exposition of every replica's metrics registry,
    /// one commented section per replica (each replica is its own scrape
    /// target in a real deployment; the file form keeps the sections
    /// adjacent). The CLI's `cluster --metrics-out` writes this.
    pub fn prometheus(&mut self) -> String {
        let mut out = String::new();
        for r in &mut self.replicas {
            out.push_str(&format!("# replica {} ({})\n", r.index(), r.device_name()));
            out.push_str(&r.metrics_mut().to_prometheus());
        }
        out
    }

    fn report(
        &self,
        finished: Vec<FinishedRequest>,
        decode_pool_tpot: Option<Summary>,
    ) -> FleetReport {
        let mut replica_reports = Vec::with_capacity(self.replicas.len());
        let mut ttfts: Vec<f64> = Vec::new();
        let mut tpots: Vec<f64> = Vec::new();
        for f in &finished {
            if f.reason.is_natural() {
                ttfts.push(f.timing.ttft_us() as f64);
                if f.timing.n_generated >= 2 {
                    tpots.push(f.timing.tpot_us());
                }
            }
        }
        for r in &self.replicas {
            let m = r.metrics();
            replica_reports.push(ReplicaReport {
                index: r.index(),
                device: r.device_name(),
                role: r.role(),
                requests_assigned: r.assigned(),
                requests_finished: m.requests_finished,
                tokens_generated: m.tokens_generated,
                mean_occupancy: m.mean_occupancy(),
                decode_occupancy_samples: m.decode_occupancy_samples() as usize,
                tpot: m.tpot(),
                ttft: m.ttft(),
                throughput_tok_s: m.throughput_tok_s(),
                wall_us: m.wall_us,
                rejected_backpressure: m.rejected_backpressure,
                goodput_tokens: m.goodput_tokens,
                preemptions: m.preemptions,
                requests_shed: m.requests_shed,
            });
        }
        let total_tokens: usize = replica_reports.iter().map(|r| r.tokens_generated).sum();
        let goodput_tokens: usize = replica_reports.iter().map(|r| r.goodput_tokens).sum();
        // Replicas run concurrently in a real deployment: fleet wall time
        // is the slowest replica's, and aggregate throughput follows.
        let wall_us = replica_reports.iter().map(|r| r.wall_us).max().unwrap_or(0);
        let aggregate_tok_s =
            if wall_us == 0 { 0.0 } else { total_tokens as f64 / (wall_us as f64 / 1e6) };
        let goodput_tok_s =
            if wall_us == 0 { 0.0 } else { goodput_tokens as f64 / (wall_us as f64 / 1e6) };
        FleetReport {
            policy: self.policy.clone(),
            router: self.router.name(),
            tp_degree: self.topology.tp().degree,
            shard_h_q: self.topology.shard_geometry().h_q,
            shard_h_kv: self.topology.shard_geometry().h_kv,
            interconnect: self.topology.interconnect().name,
            replicas: replica_reports,
            assignments: self.assignments.clone(),
            prefill_assignments: self.prefill_assignments.clone(),
            finished,
            ttft: (!ttfts.is_empty()).then(|| Summary::of(&ttfts)),
            tpot: (!tpots.is_empty()).then(|| Summary::of(&tpots)),
            decode_pool_tpot,
            handoffs: self.ledger.delivered(),
            handoffs_cancelled: self.ledger.cancelled(),
            transferred_blocks: self.ledger.blocks_delivered(),
            transfer_wire_us: self.ledger.total_wire_us(),
            total_tokens,
            goodput_tokens,
            wall_us,
            aggregate_tok_s,
            goodput_tok_s,
            rejected: self.rejected,
        }
    }
}

/// Per-replica slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub index: usize,
    pub device: &'static str,
    /// Pool membership (`Unified` on colocated fleets).
    pub role: ReplicaRole,
    pub requests_assigned: usize,
    pub requests_finished: usize,
    pub tokens_generated: usize,
    /// Mean planned first-wave SM occupancy over decode steps — the §2.1
    /// quantity TP sharding collapses. `None` when the replica ran no
    /// decode steps (an idle replica is not a measured 0%).
    pub mean_occupancy: Option<f64>,
    /// Decode-occupancy observations behind `mean_occupancy` — the weight
    /// the fleet-level pooled mean uses (a replica that decoded 10 steps
    /// must not count as much as one that decoded 10 000).
    pub decode_occupancy_samples: usize,
    pub tpot: Option<Summary>,
    pub ttft: Option<Summary>,
    pub throughput_tok_s: f64,
    pub wall_us: u64,
    /// Assigned arrivals the replica's bounded admission queue refused
    /// when they came due (they were routed but never served — without
    /// this counter they would silently vanish from the report).
    pub rejected_backpressure: usize,
    /// Tokens of naturally-finished requests that met their class's SLOs
    /// (zero when the replica ran without an SLO config).
    pub goodput_tokens: usize,
    /// Running requests the replica evicted for higher-priority heads.
    pub preemptions: usize,
    /// Queued requests the replica shed as hopeless.
    pub requests_shed: usize,
}

/// What a fleet run produced.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub policy: String,
    pub router: &'static str,
    pub tp_degree: usize,
    pub shard_h_q: usize,
    pub shard_h_kv: usize,
    /// The modeled cross-pool link's preset name (report metadata even
    /// when colocated, where no transfer ever uses it).
    pub interconnect: &'static str,
    pub replicas: Vec<ReplicaReport>,
    /// Decode-leg placements on a disaggregated fleet (all placements
    /// when colocated) — the list affinity invariants are checked on.
    pub assignments: Vec<Assignment>,
    /// Prefill-leg placements (empty when colocated).
    pub prefill_assignments: Vec<Assignment>,
    pub finished: Vec<FinishedRequest>,
    /// Pooled across replicas, naturally-finished requests only. On a
    /// disaggregated fleet these are **end-to-end** merged-request
    /// numbers: TPOT spans the wire gap between the pools, so it answers
    /// "what did the client see", not "how fast did decode step".
    pub ttft: Option<Summary>,
    pub tpot: Option<Summary>,
    /// Decode-side-only TPOT of handed-off continuations (`None` when
    /// colocated): inter-token time inside the decode pool, wire and
    /// prefill interference excluded — the paper's decode-step regime,
    /// and the quantity the disaggregation bench gates on.
    pub decode_pool_tpot: Option<Summary>,
    /// KV handoffs delivered to the decode pool (0 when colocated).
    pub handoffs: usize,
    /// KV handoffs whose continuation the decode pool refused.
    pub handoffs_cancelled: usize,
    /// KV blocks delivered across the interconnect.
    pub transferred_blocks: usize,
    /// Total one-way wire time paid by closed transfers, µs.
    pub transfer_wire_us: u64,
    pub total_tokens: usize,
    /// SLO-meeting tokens summed over replicas (zero without SLO config).
    pub goodput_tokens: usize,
    /// Slowest replica's clock (replicas run concurrently).
    pub wall_us: u64,
    pub aggregate_tok_s: f64,
    /// Fleet goodput rate over the same wall time as `aggregate_tok_s`.
    pub goodput_tok_s: f64,
    /// Requests refused at routing time: unroutable (no eligible replica,
    /// or a pinned replica that can't take the turn) plus never-fits
    /// shapes the chosen replica refused at submission.
    pub rejected: usize,
}

impl FleetReport {
    /// Routed arrivals later refused by a replica's bounded queue
    /// (summed over replicas). `rejected + rejected_backpressure()` is
    /// the full count of requests that entered the fleet but were never
    /// served.
    pub fn rejected_backpressure(&self) -> usize {
        self.replicas.iter().map(|r| r.rejected_backpressure).sum()
    }
}

/// Coefficient of variation (std/mean); 0 for degenerate inputs.
fn coeff_of_variation(values: &[f64]) -> f64 {
    let n = values.len();
    if n < 2 {
        return 0.0;
    }
    let mean = values.iter().sum::<f64>() / n as f64;
    if mean == 0.0 {
        return 0.0;
    }
    let var = values.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / n as f64;
    var.sqrt() / mean
}

impl FleetReport {
    /// Whether this fleet ran with prefill/decode pools.
    pub fn is_disaggregated(&self) -> bool {
        self.replicas.iter().any(|r| r.role != ReplicaRole::Unified)
    }

    /// Replica slices belonging to a pool, in index order.
    pub fn pool(&self, role: ReplicaRole) -> Vec<&ReplicaReport> {
        self.replicas.iter().filter(|r| r.role == role).collect()
    }

    /// Load-imbalance coefficient: coefficient of variation (std/mean) of
    /// per-replica generated tokens. 0 = perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        let tokens: Vec<f64> =
            self.replicas.iter().map(|r| r.tokens_generated as f64).collect();
        coeff_of_variation(&tokens)
    }

    /// Imbalance within one pool. Comparing a pool's number against the
    /// fleet-wide one separates "the router balanced each pool" from
    /// "the pools happen to be differently sized" — cross-pool token
    /// asymmetry is *structural* in disaggregation (prefill legs emit 1
    /// token each), not a routing defect.
    pub fn pool_imbalance(&self, role: ReplicaRole) -> f64 {
        let tokens: Vec<f64> =
            self.pool(role).iter().map(|r| r.tokens_generated as f64).collect();
        coeff_of_variation(&tokens)
    }

    /// Tokens generated inside one pool.
    pub fn pool_tokens(&self, role: ReplicaRole) -> usize {
        self.pool(role).iter().map(|r| r.tokens_generated).sum()
    }

    /// SLO-meeting tokens inside one pool (0 without SLO config).
    pub fn pool_goodput_tokens(&self, role: ReplicaRole) -> usize {
        self.pool(role).iter().map(|r| r.goodput_tokens).sum()
    }

    /// Sample-weighted mean decode occupancy inside one pool (the same
    /// pooling discipline as [`FleetReport::mean_occupancy`]). On a
    /// disaggregated fleet the decode pool's number is the paper's
    /// quantity: every step there is a `q_len = 1` starved-regime step,
    /// undiluted by chunked-prefill waves.
    pub fn pool_mean_occupancy(&self, role: ReplicaRole) -> f64 {
        let mut weighted = 0.0;
        let mut n = 0usize;
        for r in self.pool(role) {
            if let Some(occ) = r.mean_occupancy {
                weighted += occ * r.decode_occupancy_samples as f64;
                n += r.decode_occupancy_samples;
            }
        }
        if n == 0 {
            return 0.0;
        }
        weighted / n as f64
    }

    /// Sessions whose requests landed on more than one replica (must be 0
    /// under [`super::SessionAffinity`]). Counts *sessions*, not replica
    /// switches: an A→B→A session is one violation.
    pub fn affinity_violations(&self) -> usize {
        use std::collections::{HashMap, HashSet};
        let mut first: HashMap<u64, usize> = HashMap::new();
        let mut violators: HashSet<u64> = HashSet::new();
        for a in &self.assignments {
            match first.insert(a.session, a.replica) {
                Some(prev) if prev != a.replica => {
                    violators.insert(a.session);
                }
                _ => {}
            }
        }
        violators.len()
    }

    /// Pooled mean decode occupancy across replicas that actually decoded
    /// (idle replicas carry no sample and must not dilute the mean).
    /// Weighted by each replica's observation count — the mean of the
    /// merged samples, not a mean of per-replica means, so a lightly
    /// loaded replica cannot skew the fleet number (the same pooling
    /// discipline the fleet TTFT/TPOT summaries follow).
    pub fn mean_occupancy(&self) -> f64 {
        let mut weighted = 0.0;
        let mut n = 0usize;
        for r in &self.replicas {
            if let Some(occ) = r.mean_occupancy {
                weighted += occ * r.decode_occupancy_samples as f64;
                n += r.decode_occupancy_samples;
            }
        }
        if n == 0 {
            return 0.0;
        }
        weighted / n as f64
    }

    /// ASCII rendering for the CLI.
    pub fn render(&self) -> String {
        let mut out = format!(
            "fleet: {} replicas, tp={} (shard H_Q={} H_KV={}), policy '{}', router '{}'\n",
            self.replicas.len(),
            self.tp_degree,
            self.shard_h_q,
            self.shard_h_kv,
            self.policy,
            self.router
        );
        let mut t = Table::new(&[
            "Replica",
            "Device",
            "Assigned",
            "Finished",
            "Tokens",
            "Occupancy",
            "TPOT p50",
            "TTFT p99",
            "tok/s",
        ])
        .align(&[
            Align::Right,
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
        for r in &self.replicas {
            t.row(&[
                r.index.to_string(),
                r.device.to_string(),
                r.requests_assigned.to_string(),
                r.requests_finished.to_string(),
                r.tokens_generated.to_string(),
                r.mean_occupancy
                    .map(|o| format!("{:.1}%", o * 100.0))
                    .unwrap_or_else(|| "-".into()),
                r.tpot.as_ref().map(|s| format!("{:.1}", s.p50)).unwrap_or_else(|| "-".into()),
                r.ttft.as_ref().map(|s| format!("{:.1}", s.p99)).unwrap_or_else(|| "-".into()),
                format!("{:.0}", r.throughput_tok_s),
            ]);
        }
        out.push_str(&t.render());
        out.push_str(&format!(
            "aggregate: {} tokens, {:.0} tok/s, imbalance {:.3}, affinity violations {}, \
             rejected {} (+{} backpressure)\n",
            self.total_tokens,
            self.aggregate_tok_s,
            self.imbalance(),
            self.affinity_violations(),
            self.rejected,
            self.rejected_backpressure()
        ));
        // Pool + handoff lines only on disaggregated fleets: colocated
        // rendering stays byte-identical to the pre-pool format.
        if self.is_disaggregated() {
            out.push_str(&format!(
                "pools: prefill {} replica(s) (occupancy {:.1}%, imbalance {:.3}), decode {} \
                 replica(s) (occupancy {:.1}%, imbalance {:.3}), interconnect {}\n",
                self.pool(ReplicaRole::Prefill).len(),
                self.pool_mean_occupancy(ReplicaRole::Prefill) * 100.0,
                self.pool_imbalance(ReplicaRole::Prefill),
                self.pool(ReplicaRole::Decode).len(),
                self.pool_mean_occupancy(ReplicaRole::Decode) * 100.0,
                self.pool_imbalance(ReplicaRole::Decode),
                self.interconnect
            ));
            out.push_str(&format!(
                "handoffs: {} delivered (+{} cancelled), {} blocks, wire {}µs total\n",
                self.handoffs, self.handoffs_cancelled, self.transferred_blocks,
                self.transfer_wire_us
            ));
            if let Some(s) = &self.decode_pool_tpot {
                out.push_str(&format!(
                    "decode-pool TPOT µs: mean={:.1} p50={:.1} p99={:.1}\n",
                    s.mean, s.p50, s.p99
                ));
            }
        }
        // Overload-survival line only when something happened: keeps the
        // default (no-SLO, no-preemption) rendering byte-identical.
        let preemptions: usize = self.replicas.iter().map(|r| r.preemptions).sum();
        let shed: usize = self.replicas.iter().map(|r| r.requests_shed).sum();
        if self.goodput_tokens + preemptions + shed > 0 {
            out.push_str(&format!(
                "goodput: {} tokens ({:.0} tok/s), preemptions {}, shed {}\n",
                self.goodput_tokens, self.goodput_tok_s, preemptions, shed
            ));
        }
        if let Some(s) = &self.tpot {
            out.push_str(&format!(
                "fleet TPOT µs: mean={:.1} p50={:.1} p99={:.1}\n",
                s.mean, s.p50, s.p99
            ));
        }
        if let Some(s) = &self.ttft {
            out.push_str(&format!(
                "fleet TTFT µs: mean={:.1} p50={:.1} p99={:.1}\n",
                s.mean, s.p50, s.p99
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::AttnGeometry;
    use crate::cluster::router::{RoundRobin, SessionAffinity};
    use crate::cluster::topology::TpConfig;
    use crate::planner::DeviceProfile;
    use crate::workload::ChatWorkload;

    fn fleet(n: usize, tp: usize, router: Box<dyn Router>, policy: &str) -> Fleet {
        let topo = ClusterTopology::builder(AttnGeometry {
            h_q: 64,
            h_kv: 8,
            d: 128,
            max_seq: 1024,
        })
        .tp(TpConfig::new(tp))
        .replicas(n, DeviceProfile::H100_SXM)
        .build()
        .unwrap();
        Fleet::new(topo, router, FleetConfig::default().policy(policy)).unwrap()
    }

    #[test]
    fn closed_loop_stream_completes_and_balances() {
        let mut f = fleet(2, 8, Box::new(RoundRobin::new()), "sequence-aware");
        let stream = ChatWorkload { n_requests: 8, ..Default::default() }.generate();
        let report = f.run(&stream).unwrap();
        assert_eq!(report.finished.len(), 8);
        assert_eq!(report.rejected, 0);
        let assigned: Vec<usize> = report.replicas.iter().map(|r| r.requests_assigned).collect();
        assert_eq!(assigned, vec![4, 4], "round-robin splits evenly");
        assert!(report.total_tokens > 0);
        assert!(report.aggregate_tok_s > 0.0);
        assert!(report.mean_occupancy() > 0.0);
        assert!(report.render().contains("fleet TPOT"));
    }

    #[test]
    fn open_loop_arrivals_advance_replica_clocks() {
        let mut f = fleet(2, 8, Box::new(SessionAffinity::new()), "sequence-aware");
        let stream = ChatWorkload {
            n_requests: 12,
            mean_gap_us: 2_000,
            turns_per_session: 3,
            ..Default::default()
        }
        .generate();
        let report = f.run(&stream).unwrap();
        assert_eq!(report.finished.len(), 12);
        assert_eq!(report.affinity_violations(), 0);
        // Arrivals span the timeline, so the fleet wall covers them.
        let last = stream.last().unwrap().arrival_offset_us;
        assert!(report.wall_us >= last);
    }

    #[test]
    fn imbalance_is_zero_when_even_and_positive_when_skewed() {
        let even = FleetReport {
            policy: "p".into(),
            router: "r",
            tp_degree: 1,
            shard_h_q: 8,
            shard_h_kv: 1,
            interconnect: "nvlink",
            replicas: vec![
                ReplicaReport {
                    index: 0,
                    device: "a",
                    role: ReplicaRole::Unified,
                    requests_assigned: 1,
                    requests_finished: 1,
                    tokens_generated: 100,
                    mean_occupancy: None,
                    decode_occupancy_samples: 0,
                    tpot: None,
                    ttft: None,
                    throughput_tok_s: 0.0,
                    wall_us: 0,
                    rejected_backpressure: 0,
                    goodput_tokens: 0,
                    preemptions: 0,
                    requests_shed: 0,
                },
                ReplicaReport {
                    index: 1,
                    device: "a",
                    role: ReplicaRole::Unified,
                    requests_assigned: 1,
                    requests_finished: 1,
                    tokens_generated: 100,
                    mean_occupancy: None,
                    decode_occupancy_samples: 0,
                    tpot: None,
                    ttft: None,
                    throughput_tok_s: 0.0,
                    wall_us: 0,
                    rejected_backpressure: 0,
                    goodput_tokens: 0,
                    preemptions: 0,
                    requests_shed: 0,
                },
            ],
            assignments: Vec::new(),
            prefill_assignments: Vec::new(),
            finished: Vec::new(),
            ttft: None,
            tpot: None,
            decode_pool_tpot: None,
            handoffs: 0,
            handoffs_cancelled: 0,
            transferred_blocks: 0,
            transfer_wire_us: 0,
            total_tokens: 200,
            goodput_tokens: 0,
            wall_us: 0,
            aggregate_tok_s: 0.0,
            goodput_tok_s: 0.0,
            rejected: 0,
        };
        assert_eq!(even.imbalance(), 0.0);
        let mut skewed = even.clone();
        skewed.replicas[1].tokens_generated = 0;
        assert!(skewed.imbalance() > 0.9, "{}", skewed.imbalance());

        // Affinity accounting counts violating SESSIONS, not switches:
        // session 1 ping-pongs A→B→A (one violation), session 2 is whole.
        let mut pingpong = even;
        pingpong.assignments = vec![
            Assignment { request: 0, session: 1, replica: 0 },
            Assignment { request: 1, session: 1, replica: 1 },
            Assignment { request: 2, session: 1, replica: 0 },
            Assignment { request: 3, session: 2, replica: 1 },
        ];
        assert_eq!(pingpong.affinity_violations(), 1);
        assert_eq!(pingpong.rejected_backpressure(), 0);
    }

    #[test]
    fn disaggregated_run_hands_off_and_merges() {
        use crate::cluster::router::Disaggregated;
        use crate::cluster::topology::Interconnect;
        let topo = ClusterTopology::builder(AttnGeometry {
            h_q: 64,
            h_kv: 8,
            d: 128,
            max_seq: 1024,
        })
        .tp(TpConfig::new(8))
        .pool(1, DeviceProfile::H100_SXM, ReplicaRole::Prefill)
        .pool(2, DeviceProfile::H100_SXM, ReplicaRole::Decode)
        .interconnect(Interconnect::NVLINK)
        .build()
        .unwrap();
        let mut f =
            Fleet::new(topo, Box::new(Disaggregated::new()), FleetConfig::default()).unwrap();
        let stream = ChatWorkload { n_requests: 6, ..Default::default() }.generate();
        let report = f.run(&stream).unwrap();
        assert_eq!(report.finished.len(), 6);
        assert_eq!(report.rejected, 0);
        assert!(report.is_disaggregated());
        // Every multi-token request crossed the wire exactly once.
        assert!(report.handoffs > 0 && report.handoffs <= 6, "{}", report.handoffs);
        assert_eq!(report.handoffs_cancelled, 0);
        assert!(report.transferred_blocks > 0);
        assert!(report.transfer_wire_us > 0, "NVLink still costs base latency");
        assert!(f.ledger().drained());
        f.ledger().check_invariants().unwrap();
        // Legs land in their own pools.
        assert_eq!(report.prefill_assignments.len(), 6);
        assert!(report.prefill_assignments.iter().all(|a| a.replica == 0));
        assert_eq!(report.assignments.len(), report.handoffs);
        assert!(report.assignments.iter().all(|a| [1, 2].contains(&a.replica)));
        // Merged requests carry their full budget of tokens, and the
        // decode-side TPOT summary exists for multi-token continuations.
        for (fin, g) in report.finished.iter().zip(&stream) {
            assert_eq!(fin.id, g.request.id);
            assert_eq!(fin.tokens.len(), g.request.max_new_tokens);
            assert_eq!(fin.prompt_len, g.request.prompt.len());
        }
        assert!(report.decode_pool_tpot.is_some());
        assert!(report.pool_tokens(ReplicaRole::Decode) > report.pool_tokens(ReplicaRole::Prefill));
        let rendered = report.render();
        assert!(rendered.contains("pools: prefill 1 replica(s)"), "{rendered}");
        assert!(rendered.contains("handoffs:"), "{rendered}");
    }

    #[test]
    fn disaggregated_topology_rejects_single_stage_routers() {
        let topo = ClusterTopology::builder(AttnGeometry {
            h_q: 64,
            h_kv: 8,
            d: 128,
            max_seq: 1024,
        })
        .tp(TpConfig::new(8))
        .pool(1, DeviceProfile::H100_SXM, ReplicaRole::Prefill)
        .pool(1, DeviceProfile::H100_SXM, ReplicaRole::Decode)
        .build()
        .unwrap();
        let err =
            Fleet::new(topo, Box::new(SessionAffinity::new()), FleetConfig::default()).unwrap_err();
        assert!(err.to_string().contains("single-stage"), "{err}");
    }

    #[test]
    fn unknown_policy_surfaces_registry_error() {
        let topo = ClusterTopology::builder(AttnGeometry {
            h_q: 64,
            h_kv: 8,
            d: 128,
            max_seq: 1024,
        })
        .tp(TpConfig::new(8))
        .replicas(1, DeviceProfile::H100_SXM)
        .build()
        .unwrap();
        let err = Fleet::new(
            topo,
            Box::new(RoundRobin::new()),
            FleetConfig::default().policy("nope"),
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown policy 'nope'"));
    }
}
