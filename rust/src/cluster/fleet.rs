//! The fleet driver: fan a chat stream across replicas on the simulated
//! virtual clock and aggregate fleet-level metrics.
//!
//! Data flow (DESIGN.md §Cluster):
//!
//! ```text
//! ClusterTopology ──derives──► per-shard AttnGeometry
//!        │                            │
//!        ▼                            ▼
//! Fleet::new ── per replica: PolicyRegistry planner(device) + SimBackend(device)
//!        │
//! Fleet::run(stream):
//!   for each arrival (time-ordered):
//!     advance every replica's virtual clock to the arrival instant
//!     snapshot replicas ──► Router::route ──► Replica::submit_at
//!   drain all replicas ──► FleetReport (per-replica + pooled metrics)
//! ```
//!
//! Routing therefore happens **before** each replica's admission
//! controller: the router picks placement from live load snapshots, the
//! replica's bounded queues still decide acceptance, and rejected
//! submissions are counted, never retried elsewhere (a retry would make
//! the A/B benches sensitive to rejection order; explicit is better).

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{EngineConfig, FinishedRequest, RequestId};
use crate::planner::PolicyRegistry;
use crate::util::stats::Summary;
use crate::util::table::{Align, Table};
use crate::workload::GeneratedRequest;

use super::replica::Replica;
use super::router::{ReplicaSnapshot, RouteError, Router};
use super::topology::ClusterTopology;

/// Fleet-wide configuration.
pub struct FleetConfig {
    /// Split-policy name resolved through the [`PolicyRegistry`] for each
    /// replica's device (so device-dependent policies tune per replica).
    pub policy: String,
    /// Default engine configuration (replica specs may override).
    pub engine: EngineConfig,
    /// Registry the policy is resolved from.
    pub registry: PolicyRegistry,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            policy: "sequence-aware".to_string(),
            engine: EngineConfig::default(),
            registry: PolicyRegistry::builtin(),
        }
    }
}

impl FleetConfig {
    /// Select the split policy every replica's planner is built with.
    pub fn policy(mut self, name: impl Into<String>) -> FleetConfig {
        self.policy = name.into();
        self
    }

    /// Set the default engine configuration (replica specs may override).
    pub fn engine(mut self, cfg: EngineConfig) -> FleetConfig {
        self.engine = cfg;
        self
    }
}

/// One routing decision, recorded for affinity/balance assertions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub request: RequestId,
    pub session: u64,
    pub replica: usize,
}

/// The fleet: replicas + router + recorded assignments.
pub struct Fleet {
    topology: ClusterTopology,
    replicas: Vec<Replica>,
    router: Box<dyn Router>,
    policy: String,
    assignments: Vec<Assignment>,
    rejected: usize,
    /// Latest arrival placed so far — `submit_at` enforces monotone
    /// arrivals (an out-of-order arrival would race replicas whose
    /// virtual clocks already fast-forwarded past it).
    last_arrival_us: u64,
    /// `run` is one-shot: per-replica engine metrics accumulate for the
    /// fleet's lifetime, so a second run would report contaminated
    /// aggregates. Enforced, not just documented.
    ran: bool,
    /// Routing scratch: per-arrival load snapshots, reused across the
    /// whole stream (with every replica's step loop now allocation-free
    /// in steady state, a fresh Vec per arrival would be the fleet tick's
    /// only remaining heap traffic).
    snaps: Vec<ReplicaSnapshot>,
}

impl Fleet {
    /// Build every replica: a planner for the replica's device (via the
    /// registry, so e.g. `extended` tunes against the right part) over a
    /// `SimBackend` of the same profile, all planning the topology's
    /// sharded geometry.
    pub fn new(
        topology: ClusterTopology,
        router: Box<dyn Router>,
        cfg: FleetConfig,
    ) -> Result<Fleet> {
        let shard = topology.shard_geometry();
        let mut replicas = Vec::with_capacity(topology.num_replicas());
        for (index, spec) in topology.replicas().iter().enumerate() {
            let planner = cfg
                .registry
                .builder_for(&cfg.policy, &spec.device)
                .map_err(|e| anyhow!(e))?
                .build();
            replicas.push(Replica::new(index, spec, shard, planner, &cfg.engine)?);
        }
        let num_replicas = replicas.len();
        Ok(Fleet {
            topology,
            replicas,
            router,
            policy: cfg.policy,
            assignments: Vec::new(),
            rejected: 0,
            last_arrival_us: 0,
            ran: false,
            snaps: Vec::with_capacity(num_replicas),
        })
    }

    /// The topology this fleet was built from.
    pub fn topology(&self) -> &ClusterTopology {
        &self.topology
    }

    /// The fleet's replicas, in index order.
    pub fn replicas(&self) -> &[Replica] {
        &self.replicas
    }

    /// The routing policy's registry name.
    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// The split policy every replica plans with.
    pub fn policy_name(&self) -> &str {
        &self.policy
    }

    /// Every routing decision made so far, in arrival order.
    pub fn assignments(&self) -> &[Assignment] {
        &self.assignments
    }

    /// Route and place one arrival at `arrival_us` on the fleet timeline.
    /// Every replica is first advanced to the arrival instant so the
    /// router sees true load. Arrivals must be monotone: replica clocks
    /// only move forward, so a past-dated arrival would be served out of
    /// order against requests the fleet already placed.
    ///
    /// Returns `Ok(Some(replica))` when placed, `Ok(None)` when the
    /// request was *refused* (unroutable, or the replica rejected the
    /// submission) — refusals are counted in the report, never fatal: one
    /// impossible request must not discard every already-served result of
    /// a one-shot run. `Err` is reserved for real failures (ordering
    /// violations, router contract breaches, engine errors).
    pub fn submit_at(&mut self, g: &GeneratedRequest, arrival_us: u64) -> Result<Option<usize>> {
        if arrival_us < self.last_arrival_us {
            bail!(
                "arrivals must be time-ordered: request {} at {arrival_us}µs after one at {}µs",
                g.request.id,
                self.last_arrival_us
            );
        }
        self.last_arrival_us = arrival_us;
        for r in &mut self.replicas {
            r.advance_to(arrival_us)?;
        }
        // Refill the reused snapshot scratch (ReplicaSnapshot is Copy).
        // Snapshots are prefix-aware: each replica probes the request's
        // prompt against its own block index, so the router sees where
        // the prefix already lives.
        self.snaps.clear();
        for r in &self.replicas {
            self.snaps.push(r.snapshot_for(&g.request));
        }
        let idx = match self.router.route(&g.request, g.session, &self.snaps) {
            Ok(idx) => idx,
            Err(RouteError::Unroutable { .. }) => {
                self.rejected += 1;
                return Ok(None);
            }
            Err(e @ RouteError::NoReplicas) => return Err(e.into()),
        };
        // Router contract (DESIGN.md §Cluster invariant 1). `get` rather
        // than indexing: a misbehaving custom Router returning an
        // out-of-range replica hits this error path, not a panic.
        let eligible = self.snaps.get(idx).is_some_and(|s| s.can_ever_admit);
        if !eligible {
            bail!(
                "router '{}' violated its contract: replica {idx} {} request {}",
                self.router.name(),
                if idx < self.snaps.len() { "can never admit" } else { "does not exist for" },
                g.request.id
            );
        }
        match self.replicas[idx].submit_at(g.request.clone(), arrival_us) {
            Ok(()) => {
                self.assignments.push(Assignment {
                    request: g.request.id,
                    session: g.session,
                    replica: idx,
                });
                Ok(Some(idx))
            }
            Err(_refused) => {
                self.rejected += 1;
                Ok(None)
            }
        }
    }

    /// Fan a generated stream (time-ordered, as `ChatWorkload::generate`
    /// produces) across the fleet, drain every replica, and report.
    /// One-shot: build a fresh fleet per run (engine metrics and routing
    /// state accumulate for the fleet's lifetime).
    ///
    /// ```
    /// use fa3_split::backend::AttnGeometry;
    /// use fa3_split::cluster::{ClusterTopology, Fleet, FleetConfig, SessionAffinity, TpConfig};
    /// use fa3_split::planner::DeviceProfile;
    /// use fa3_split::workload::ChatWorkload;
    ///
    /// let topology = ClusterTopology::builder(
    ///     AttnGeometry { h_q: 64, h_kv: 8, d: 128, max_seq: 1024 },
    /// )
    /// .tp(TpConfig::new(8)) // per-shard H_KV = 1: the paper's regime
    /// .replicas(2, DeviceProfile::H100_SXM)
    /// .build()
    /// .unwrap();
    /// let mut fleet =
    ///     Fleet::new(topology, Box::new(SessionAffinity::new()), FleetConfig::default()).unwrap();
    /// let stream = ChatWorkload { n_requests: 4, turns_per_session: 2, ..Default::default() };
    /// let report = fleet.run(&stream.generate()).unwrap();
    /// assert_eq!(report.finished.len(), 4);
    /// assert_eq!(report.affinity_violations(), 0);
    /// ```
    pub fn run(&mut self, stream: &[GeneratedRequest]) -> Result<FleetReport> {
        if self.ran {
            bail!("Fleet::run is one-shot (aggregates would mix runs); build a new Fleet");
        }
        self.ran = true;
        // Arrival ordering is enforced per submission by `submit_at`
        // (`ChatWorkload::generate` produces ordered streams by
        // construction).
        for g in stream {
            self.submit_at(g, g.arrival_offset_us)?;
        }
        let mut finished: Vec<Vec<FinishedRequest>> = Vec::with_capacity(self.replicas.len());
        for r in &mut self.replicas {
            finished.push(r.run_until_idle()?);
        }
        Ok(self.report(finished))
    }

    /// Merge every replica's flight-recorder ring into one Chrome trace
    /// (one trace process per replica, labeled with its device). Replicas
    /// built without `trace_capacity` contribute only metadata. The CLI's
    /// `cluster --trace-out` writes this.
    pub fn chrome_trace(&self) -> crate::util::json::Json {
        let traces: Vec<crate::obs::ReplicaTrace> = self
            .replicas
            .iter()
            .map(|r| crate::obs::ReplicaTrace {
                pid: r.index() as u32,
                name: format!("replica {} ({})", r.index(), r.device_name()),
                recorder: r.recorder(),
            })
            .collect();
        crate::obs::fleet_trace(&traces)
    }

    /// Prometheus text exposition of every replica's metrics registry,
    /// one commented section per replica (each replica is its own scrape
    /// target in a real deployment; the file form keeps the sections
    /// adjacent). The CLI's `cluster --metrics-out` writes this.
    pub fn prometheus(&mut self) -> String {
        let mut out = String::new();
        for r in &mut self.replicas {
            out.push_str(&format!("# replica {} ({})\n", r.index(), r.device_name()));
            out.push_str(&r.metrics_mut().to_prometheus());
        }
        out
    }

    fn report(&self, finished: Vec<Vec<FinishedRequest>>) -> FleetReport {
        let mut replica_reports = Vec::with_capacity(self.replicas.len());
        let mut ttfts: Vec<f64> = Vec::new();
        let mut tpots: Vec<f64> = Vec::new();
        for (r, fin) in self.replicas.iter().zip(&finished) {
            let m = r.metrics();
            for f in fin {
                if f.reason.is_natural() {
                    ttfts.push(f.timing.ttft_us() as f64);
                    if f.timing.n_generated >= 2 {
                        tpots.push(f.timing.tpot_us());
                    }
                }
            }
            replica_reports.push(ReplicaReport {
                index: r.index(),
                device: r.device_name(),
                requests_assigned: r.assigned(),
                requests_finished: m.requests_finished,
                tokens_generated: m.tokens_generated,
                mean_occupancy: m.mean_occupancy(),
                decode_occupancy_samples: m.decode_occupancy_samples() as usize,
                tpot: m.tpot(),
                ttft: m.ttft(),
                throughput_tok_s: m.throughput_tok_s(),
                wall_us: m.wall_us,
                rejected_backpressure: m.rejected_backpressure,
                goodput_tokens: m.goodput_tokens,
                preemptions: m.preemptions,
                requests_shed: m.requests_shed,
            });
        }
        let total_tokens: usize = replica_reports.iter().map(|r| r.tokens_generated).sum();
        let goodput_tokens: usize = replica_reports.iter().map(|r| r.goodput_tokens).sum();
        // Replicas run concurrently in a real deployment: fleet wall time
        // is the slowest replica's, and aggregate throughput follows.
        let wall_us = replica_reports.iter().map(|r| r.wall_us).max().unwrap_or(0);
        let aggregate_tok_s =
            if wall_us == 0 { 0.0 } else { total_tokens as f64 / (wall_us as f64 / 1e6) };
        let goodput_tok_s =
            if wall_us == 0 { 0.0 } else { goodput_tokens as f64 / (wall_us as f64 / 1e6) };
        FleetReport {
            policy: self.policy.clone(),
            router: self.router.name(),
            tp_degree: self.topology.tp().degree,
            shard_h_q: self.topology.shard_geometry().h_q,
            shard_h_kv: self.topology.shard_geometry().h_kv,
            replicas: replica_reports,
            assignments: self.assignments.clone(),
            finished: finished.into_iter().flatten().collect(),
            ttft: (!ttfts.is_empty()).then(|| Summary::of(&ttfts)),
            tpot: (!tpots.is_empty()).then(|| Summary::of(&tpots)),
            total_tokens,
            goodput_tokens,
            wall_us,
            aggregate_tok_s,
            goodput_tok_s,
            rejected: self.rejected,
        }
    }
}

/// Per-replica slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub index: usize,
    pub device: &'static str,
    pub requests_assigned: usize,
    pub requests_finished: usize,
    pub tokens_generated: usize,
    /// Mean planned first-wave SM occupancy over decode steps — the §2.1
    /// quantity TP sharding collapses. `None` when the replica ran no
    /// decode steps (an idle replica is not a measured 0%).
    pub mean_occupancy: Option<f64>,
    /// Decode-occupancy observations behind `mean_occupancy` — the weight
    /// the fleet-level pooled mean uses (a replica that decoded 10 steps
    /// must not count as much as one that decoded 10 000).
    pub decode_occupancy_samples: usize,
    pub tpot: Option<Summary>,
    pub ttft: Option<Summary>,
    pub throughput_tok_s: f64,
    pub wall_us: u64,
    /// Assigned arrivals the replica's bounded admission queue refused
    /// when they came due (they were routed but never served — without
    /// this counter they would silently vanish from the report).
    pub rejected_backpressure: usize,
    /// Tokens of naturally-finished requests that met their class's SLOs
    /// (zero when the replica ran without an SLO config).
    pub goodput_tokens: usize,
    /// Running requests the replica evicted for higher-priority heads.
    pub preemptions: usize,
    /// Queued requests the replica shed as hopeless.
    pub requests_shed: usize,
}

/// What a fleet run produced.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub policy: String,
    pub router: &'static str,
    pub tp_degree: usize,
    pub shard_h_q: usize,
    pub shard_h_kv: usize,
    pub replicas: Vec<ReplicaReport>,
    pub assignments: Vec<Assignment>,
    pub finished: Vec<FinishedRequest>,
    /// Pooled across replicas, naturally-finished requests only.
    pub ttft: Option<Summary>,
    pub tpot: Option<Summary>,
    pub total_tokens: usize,
    /// SLO-meeting tokens summed over replicas (zero without SLO config).
    pub goodput_tokens: usize,
    /// Slowest replica's clock (replicas run concurrently).
    pub wall_us: u64,
    pub aggregate_tok_s: f64,
    /// Fleet goodput rate over the same wall time as `aggregate_tok_s`.
    pub goodput_tok_s: f64,
    /// Requests refused at routing time: unroutable (no eligible replica,
    /// or a pinned replica that can't take the turn) plus never-fits
    /// shapes the chosen replica refused at submission.
    pub rejected: usize,
}

impl FleetReport {
    /// Routed arrivals later refused by a replica's bounded queue
    /// (summed over replicas). `rejected + rejected_backpressure()` is
    /// the full count of requests that entered the fleet but were never
    /// served.
    pub fn rejected_backpressure(&self) -> usize {
        self.replicas.iter().map(|r| r.rejected_backpressure).sum()
    }
}

impl FleetReport {
    /// Load-imbalance coefficient: coefficient of variation (std/mean) of
    /// per-replica generated tokens. 0 = perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        let n = self.replicas.len();
        if n < 2 {
            return 0.0;
        }
        let tokens: Vec<f64> = self.replicas.iter().map(|r| r.tokens_generated as f64).collect();
        let mean = tokens.iter().sum::<f64>() / n as f64;
        if mean == 0.0 {
            return 0.0;
        }
        let var = tokens.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / n as f64;
        var.sqrt() / mean
    }

    /// Sessions whose requests landed on more than one replica (must be 0
    /// under [`super::SessionAffinity`]). Counts *sessions*, not replica
    /// switches: an A→B→A session is one violation.
    pub fn affinity_violations(&self) -> usize {
        use std::collections::{HashMap, HashSet};
        let mut first: HashMap<u64, usize> = HashMap::new();
        let mut violators: HashSet<u64> = HashSet::new();
        for a in &self.assignments {
            match first.insert(a.session, a.replica) {
                Some(prev) if prev != a.replica => {
                    violators.insert(a.session);
                }
                _ => {}
            }
        }
        violators.len()
    }

    /// Pooled mean decode occupancy across replicas that actually decoded
    /// (idle replicas carry no sample and must not dilute the mean).
    /// Weighted by each replica's observation count — the mean of the
    /// merged samples, not a mean of per-replica means, so a lightly
    /// loaded replica cannot skew the fleet number (the same pooling
    /// discipline the fleet TTFT/TPOT summaries follow).
    pub fn mean_occupancy(&self) -> f64 {
        let mut weighted = 0.0;
        let mut n = 0usize;
        for r in &self.replicas {
            if let Some(occ) = r.mean_occupancy {
                weighted += occ * r.decode_occupancy_samples as f64;
                n += r.decode_occupancy_samples;
            }
        }
        if n == 0 {
            return 0.0;
        }
        weighted / n as f64
    }

    /// ASCII rendering for the CLI.
    pub fn render(&self) -> String {
        let mut out = format!(
            "fleet: {} replicas, tp={} (shard H_Q={} H_KV={}), policy '{}', router '{}'\n",
            self.replicas.len(),
            self.tp_degree,
            self.shard_h_q,
            self.shard_h_kv,
            self.policy,
            self.router
        );
        let mut t = Table::new(&[
            "Replica",
            "Device",
            "Assigned",
            "Finished",
            "Tokens",
            "Occupancy",
            "TPOT p50",
            "TTFT p99",
            "tok/s",
        ])
        .align(&[
            Align::Right,
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
        for r in &self.replicas {
            t.row(&[
                r.index.to_string(),
                r.device.to_string(),
                r.requests_assigned.to_string(),
                r.requests_finished.to_string(),
                r.tokens_generated.to_string(),
                r.mean_occupancy
                    .map(|o| format!("{:.1}%", o * 100.0))
                    .unwrap_or_else(|| "-".into()),
                r.tpot.as_ref().map(|s| format!("{:.1}", s.p50)).unwrap_or_else(|| "-".into()),
                r.ttft.as_ref().map(|s| format!("{:.1}", s.p99)).unwrap_or_else(|| "-".into()),
                format!("{:.0}", r.throughput_tok_s),
            ]);
        }
        out.push_str(&t.render());
        out.push_str(&format!(
            "aggregate: {} tokens, {:.0} tok/s, imbalance {:.3}, affinity violations {}, \
             rejected {} (+{} backpressure)\n",
            self.total_tokens,
            self.aggregate_tok_s,
            self.imbalance(),
            self.affinity_violations(),
            self.rejected,
            self.rejected_backpressure()
        ));
        // Overload-survival line only when something happened: keeps the
        // default (no-SLO, no-preemption) rendering byte-identical.
        let preemptions: usize = self.replicas.iter().map(|r| r.preemptions).sum();
        let shed: usize = self.replicas.iter().map(|r| r.requests_shed).sum();
        if self.goodput_tokens + preemptions + shed > 0 {
            out.push_str(&format!(
                "goodput: {} tokens ({:.0} tok/s), preemptions {}, shed {}\n",
                self.goodput_tokens, self.goodput_tok_s, preemptions, shed
            ));
        }
        if let Some(s) = &self.tpot {
            out.push_str(&format!(
                "fleet TPOT µs: mean={:.1} p50={:.1} p99={:.1}\n",
                s.mean, s.p50, s.p99
            ));
        }
        if let Some(s) = &self.ttft {
            out.push_str(&format!(
                "fleet TTFT µs: mean={:.1} p50={:.1} p99={:.1}\n",
                s.mean, s.p50, s.p99
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::AttnGeometry;
    use crate::cluster::router::{RoundRobin, SessionAffinity};
    use crate::cluster::topology::TpConfig;
    use crate::planner::DeviceProfile;
    use crate::workload::ChatWorkload;

    fn fleet(n: usize, tp: usize, router: Box<dyn Router>, policy: &str) -> Fleet {
        let topo = ClusterTopology::builder(AttnGeometry {
            h_q: 64,
            h_kv: 8,
            d: 128,
            max_seq: 1024,
        })
        .tp(TpConfig::new(tp))
        .replicas(n, DeviceProfile::H100_SXM)
        .build()
        .unwrap();
        Fleet::new(topo, router, FleetConfig::default().policy(policy)).unwrap()
    }

    #[test]
    fn closed_loop_stream_completes_and_balances() {
        let mut f = fleet(2, 8, Box::new(RoundRobin::new()), "sequence-aware");
        let stream = ChatWorkload { n_requests: 8, ..Default::default() }.generate();
        let report = f.run(&stream).unwrap();
        assert_eq!(report.finished.len(), 8);
        assert_eq!(report.rejected, 0);
        let assigned: Vec<usize> = report.replicas.iter().map(|r| r.requests_assigned).collect();
        assert_eq!(assigned, vec![4, 4], "round-robin splits evenly");
        assert!(report.total_tokens > 0);
        assert!(report.aggregate_tok_s > 0.0);
        assert!(report.mean_occupancy() > 0.0);
        assert!(report.render().contains("fleet TPOT"));
    }

    #[test]
    fn open_loop_arrivals_advance_replica_clocks() {
        let mut f = fleet(2, 8, Box::new(SessionAffinity::new()), "sequence-aware");
        let stream = ChatWorkload {
            n_requests: 12,
            mean_gap_us: 2_000,
            turns_per_session: 3,
            ..Default::default()
        }
        .generate();
        let report = f.run(&stream).unwrap();
        assert_eq!(report.finished.len(), 12);
        assert_eq!(report.affinity_violations(), 0);
        // Arrivals span the timeline, so the fleet wall covers them.
        let last = stream.last().unwrap().arrival_offset_us;
        assert!(report.wall_us >= last);
    }

    #[test]
    fn imbalance_is_zero_when_even_and_positive_when_skewed() {
        let even = FleetReport {
            policy: "p".into(),
            router: "r",
            tp_degree: 1,
            shard_h_q: 8,
            shard_h_kv: 1,
            replicas: vec![
                ReplicaReport {
                    index: 0,
                    device: "a",
                    requests_assigned: 1,
                    requests_finished: 1,
                    tokens_generated: 100,
                    mean_occupancy: None,
                    decode_occupancy_samples: 0,
                    tpot: None,
                    ttft: None,
                    throughput_tok_s: 0.0,
                    wall_us: 0,
                    rejected_backpressure: 0,
                    goodput_tokens: 0,
                    preemptions: 0,
                    requests_shed: 0,
                },
                ReplicaReport {
                    index: 1,
                    device: "a",
                    requests_assigned: 1,
                    requests_finished: 1,
                    tokens_generated: 100,
                    mean_occupancy: None,
                    decode_occupancy_samples: 0,
                    tpot: None,
                    ttft: None,
                    throughput_tok_s: 0.0,
                    wall_us: 0,
                    rejected_backpressure: 0,
                    goodput_tokens: 0,
                    preemptions: 0,
                    requests_shed: 0,
                },
            ],
            assignments: Vec::new(),
            finished: Vec::new(),
            ttft: None,
            tpot: None,
            total_tokens: 200,
            goodput_tokens: 0,
            wall_us: 0,
            aggregate_tok_s: 0.0,
            goodput_tok_s: 0.0,
            rejected: 0,
        };
        assert_eq!(even.imbalance(), 0.0);
        let mut skewed = even.clone();
        skewed.replicas[1].tokens_generated = 0;
        assert!(skewed.imbalance() > 0.9, "{}", skewed.imbalance());

        // Affinity accounting counts violating SESSIONS, not switches:
        // session 1 ping-pongs A→B→A (one violation), session 2 is whole.
        let mut pingpong = even;
        pingpong.assignments = vec![
            Assignment { request: 0, session: 1, replica: 0 },
            Assignment { request: 1, session: 1, replica: 1 },
            Assignment { request: 2, session: 1, replica: 0 },
            Assignment { request: 3, session: 2, replica: 1 },
        ];
        assert_eq!(pingpong.affinity_violations(), 1);
        assert_eq!(pingpong.rejected_backpressure(), 0);
    }

    #[test]
    fn unknown_policy_surfaces_registry_error() {
        let topo = ClusterTopology::builder(AttnGeometry {
            h_q: 64,
            h_kv: 8,
            d: 128,
            max_seq: 1024,
        })
        .tp(TpConfig::new(8))
        .replicas(1, DeviceProfile::H100_SXM)
        .build()
        .unwrap();
        let err = Fleet::new(
            topo,
            Box::new(RoundRobin::new()),
            FleetConfig::default().policy("nope"),
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown policy 'nope'"));
    }
}
