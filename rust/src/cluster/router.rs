//! Fleet routing: which replica serves which request.
//!
//! The router sits **in front of** each replica's admission controller —
//! it decides *placement*, the replica's bounded queues still decide
//! *acceptance*. Routers see a per-replica [`ReplicaSnapshot`] (queue
//! depth, running set, KV-block pressure from the replica's
//! `BlockManager`, and the request's *prefix-cache* footprint — how many
//! of its prompt blocks are already resident there) taken at the
//! request's arrival instant on the fleet's virtual clock. The prefix
//! term makes KV pressure *request-relative*: a replica already holding
//! a chat's system prompt is cheaper for that chat than an equally
//! loaded stranger, which is how [`LeastLoaded`] (and
//! [`SessionAffinity`]'s first-turn placement over it) keeps fan-outs of
//! a shared prefix co-located.
//!
//! Invariants every router upholds (asserted by the fleet, tested in
//! `rust/tests/cluster_fleet.rs`):
//!
//! 1. **Never route to a replica that can never admit** — a request whose
//!    worst-case KV demand exceeds a replica's entire block budget
//!    (`can_ever_admit == false`) must not be placed there; it would be
//!    refused at submission. [`LeastLoaded`] and [`RoundRobin`] skip such
//!    replicas; if none qualifies the route fails explicitly
//!    ([`RouteError::Unroutable`]) instead of wedging a queue.
//! 2. **Session stickiness is absolute** — once [`SessionAffinity`] pins a
//!    session, every later turn routes to the same replica (its KV history
//!    lives there; moving mid-session would imply a cache migration this
//!    stack doesn't model). A pinned replica that cannot take the next
//!    turn is an explicit [`RouteError::Unroutable`], never a silent
//!    re-pin.
//! 3. **Determinism** — same snapshots, same state, same decision (ties
//!    break toward the lowest replica index), so fleet runs are exactly
//!    reproducible.
//! 4. **Routers speak global indices** — the returned value is always a
//!    [`ReplicaSnapshot::index`], never a position in the candidate
//!    slice. A disaggregated fleet routes over *pool subsets* of its
//!    replicas, so the slice a router sees may be `[3, 5, 9]`; position
//!    arithmetic would silently land requests on the wrong replica.
//!    Corollary for [`SessionAffinity`]: a pin is resolved by searching
//!    the slice for its index, and a pin whose replica is absent from
//!    the slice (a session pinned in another pool) is an explicit
//!    refusal — stickiness is pool-scoped, never a cross-pool re-pin.
//!
//! Disaggregated fleets use [`Disaggregated`], a two-stage composite:
//! a load/prefix-aware stage places the *prefill* leg, and a
//! [`SessionAffinity`] stage places the *decode* leg after the KV
//! handoff, keeping later turns of a session glued to the decode
//! replica that already holds its history. All four policies are
//! exercised by the shared invariant harness in
//! `rust/tests/router_conformance.rs`.

use std::collections::HashMap;
use std::fmt;

use crate::coordinator::Request;

/// Per-replica load facts the fleet snapshots before each routing
/// decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaSnapshot {
    pub index: usize,
    /// Waiting in admission + open-loop arrivals not yet due.
    pub queue_depth: usize,
    /// Requests in the running set.
    pub running: usize,
    /// Free KV blocks in the replica's `BlockManager`.
    pub free_blocks: usize,
    pub total_blocks: usize,
    /// Whether the replica's `BlockManager` could admit this request right
    /// now (spare blocks at this instant).
    pub can_admit_now: bool,
    /// Whether it could EVER admit it (fits `max_seq` and the whole block
    /// budget on an empty manager). `false` means routing there is a
    /// guaranteed refusal.
    pub can_ever_admit: bool,
    /// Full prompt blocks of *this request* already resident on the
    /// replica (live or evictable — the prefix-cache probe). Routing a
    /// request to the replica that holds its prefix turns its prompt
    /// into a cache hit: admission charges only the remainder and
    /// prefill skips the shared tokens.
    pub shared_blocks: usize,
    /// Worst-case block demand of this request (`prompt + max_new`,
    /// rounded up to blocks) — the denominator of the prefix hit ratio.
    pub demand_blocks: usize,
}

impl ReplicaSnapshot {
    /// KV-block pressure in `[0, 1]`.
    pub fn kv_pressure(&self) -> f64 {
        if self.total_blocks == 0 {
            return 1.0;
        }
        1.0 - self.free_blocks as f64 / self.total_blocks as f64
    }

    /// Fraction of this request's block demand already resident on the
    /// replica, in `[0, 1]`.
    pub fn prefix_hit_ratio(&self) -> f64 {
        if self.demand_blocks == 0 {
            return 0.0;
        }
        (self.shared_blocks.min(self.demand_blocks)) as f64 / self.demand_blocks as f64
    }

    /// The [`LeastLoaded`] score: outstanding requests weighted with KV
    /// pressure, minus the prefix-affinity bonus. Pressure breaks ties
    /// between equally-queued replicas and dominates once a replica's
    /// cache is nearly full; the prefix term (bounded by 1, like
    /// pressure) steers a request toward the replica already holding its
    /// prefix — effectively the request's KV demand *as seen by that
    /// replica* — without ever outweighing a whole queued request.
    pub fn load_score(&self) -> f64 {
        (self.queue_depth + self.running) as f64 + self.kv_pressure() - self.prefix_hit_ratio()
    }
}

/// Why a request could not be placed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    /// The fleet has no replicas (snapshot list was empty).
    NoReplicas,
    /// No eligible replica: every candidate can never admit the request,
    /// or the session's pinned replica can't take it.
    Unroutable { request: u64, reason: String },
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::NoReplicas => write!(f, "no replicas to route to"),
            RouteError::Unroutable { request, reason } => {
                write!(f, "request {request} unroutable: {reason}")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// The routing policy contract. `&mut self` because policies carry state
/// (round-robin cursor, affinity map); `Send` so a fleet can move onto a
/// worker thread like an engine can.
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Choose a replica for `req` (belonging to chat `session`) among
    /// `replicas`, returning its **global** [`ReplicaSnapshot::index`]
    /// (invariant 4 — `replicas` may be a pool subset). Must uphold the
    /// module-level invariants.
    fn route(
        &mut self,
        req: &Request,
        session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError>;

    /// Downcast hook for the two-stage disaggregated router: a fleet with
    /// prefill/decode pools needs both stages, so `Fleet::new` rejects
    /// single-stage routers on a disaggregated topology via this probe.
    fn two_stage(&mut self) -> Option<&mut Disaggregated> {
        None
    }
}

fn no_eligible(req: &Request) -> RouteError {
    RouteError::Unroutable {
        request: req.id,
        reason: format!(
            "no replica can ever admit {} tokens (prompt {} + max_new {})",
            req.prompt.len() + req.max_new_tokens,
            req.prompt.len(),
            req.max_new_tokens
        ),
    }
}

/// Cycle through replicas in index order, skipping ones that can never
/// admit the request.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    /// A fresh cycle starting at replica 0.
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(
        &mut self,
        req: &Request,
        _session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError> {
        if replicas.is_empty() {
            return Err(RouteError::NoReplicas);
        }
        let n = replicas.len();
        for off in 0..n {
            let i = (self.next + off) % n;
            if replicas[i].can_ever_admit {
                self.next = (i + 1) % n;
                // Invariant 4: hand back the replica's global index, not
                // its position in this (possibly pool-subset) slice.
                return Ok(replicas[i].index);
            }
        }
        Err(no_eligible(req))
    }
}

/// Route to the eligible replica with the lowest [`ReplicaSnapshot::
/// load_score`] (queue depth + running + KV pressure); ties break toward
/// the lowest index.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl LeastLoaded {
    /// The stateless least-loaded policy.
    pub fn new() -> LeastLoaded {
        LeastLoaded
    }
}

impl Router for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(
        &mut self,
        req: &Request,
        _session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError> {
        if replicas.is_empty() {
            return Err(RouteError::NoReplicas);
        }
        replicas
            .iter()
            .filter(|s| s.can_ever_admit)
            .min_by(|a, b| {
                a.load_score()
                    .partial_cmp(&b.load_score())
                    .expect("load scores are finite")
                    .then(a.index.cmp(&b.index))
            })
            .map(|s| s.index)
            .ok_or_else(|| no_eligible(req))
    }
}

/// Sticky session routing: the first turn of a session places it via the
/// inner router; every later turn goes to the same replica, where the
/// session's KV history lives.
pub struct SessionAffinity {
    inner: Box<dyn Router>,
    pinned: HashMap<u64, usize>,
}

impl SessionAffinity {
    /// Affinity over [`LeastLoaded`] first-turn placement (the default).
    pub fn new() -> SessionAffinity {
        SessionAffinity::over(Box::new(LeastLoaded::new()))
    }

    /// Affinity over any first-turn placement policy.
    pub fn over(inner: Box<dyn Router>) -> SessionAffinity {
        SessionAffinity { inner, pinned: HashMap::new() }
    }

    /// The replica a session is pinned to, if it has been seen.
    pub fn pin_of(&self, session: u64) -> Option<usize> {
        self.pinned.get(&session).copied()
    }
}

impl Default for SessionAffinity {
    fn default() -> Self {
        SessionAffinity::new()
    }
}

impl Router for SessionAffinity {
    fn name(&self) -> &'static str {
        "session-affinity"
    }

    fn route(
        &mut self,
        req: &Request,
        session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError> {
        if replicas.is_empty() {
            return Err(RouteError::NoReplicas);
        }
        if let Some(&idx) = self.pinned.get(&session) {
            // Resolve the pin by global index (invariant 4). A pin whose
            // replica is not in this candidate slice means the session
            // was placed in a different pool: refusing keeps stickiness
            // pool-scoped instead of silently re-pinning across pools.
            let snap = replicas.iter().find(|s| s.index == idx).ok_or_else(|| {
                RouteError::Unroutable {
                    request: req.id,
                    reason: format!(
                        "session {session} is pinned to replica {idx}, outside this candidate \
                         pool"
                    ),
                }
            })?;
            if !snap.can_ever_admit {
                // Stickiness is absolute: refusing is correct, re-pinning
                // would orphan the session's KV (invariant 2).
                return Err(RouteError::Unroutable {
                    request: req.id,
                    reason: format!(
                        "session {session} is pinned to replica {idx}, which can never admit \
                         this turn"
                    ),
                });
            }
            return Ok(idx);
        }
        let idx = self.inner.route(req, session, replicas)?;
        self.pinned.insert(session, idx);
        Ok(idx)
    }
}

/// The two-stage router for disaggregated fleets: a load/prefix-aware
/// stage places the **prefill** leg of each request, and a
/// [`SessionAffinity`] stage places the **decode** leg after the KV
/// handoff. Decode stickiness means every later turn of a session lands
/// on the decode replica that already holds its KV history — and because
/// the affinity stage only ever sees decode-pool snapshots, its pins are
/// pool-scoped by construction (a prefill replica can never be pinned).
///
/// On a *colocated* topology (no pools) both stages see the full
/// replica set and the router degenerates to its decode stage — which is
/// exactly `SessionAffinity` over `LeastLoaded`. The differential tests
/// in `rust/tests/disaggregation.rs` pin that equivalence down.
pub struct Disaggregated {
    prefill: Box<dyn Router>,
    decode: SessionAffinity,
}

impl Disaggregated {
    /// Least-loaded prefill placement + sticky decode placement (the
    /// default, and the only composition the CLI exposes).
    pub fn new() -> Disaggregated {
        Disaggregated::over(Box::new(LeastLoaded::new()))
    }

    /// Custom prefill stage; the decode stage is always
    /// [`SessionAffinity`] over [`LeastLoaded`].
    pub fn over(prefill: Box<dyn Router>) -> Disaggregated {
        Disaggregated { prefill, decode: SessionAffinity::new() }
    }

    /// Stage 1: place the prefill leg among `replicas` (the prefill
    /// pool's snapshots). Load/prefix-aware, no stickiness — prefill is
    /// a one-shot pass and benefits most from balance + prefix reuse.
    pub fn route_prefill(
        &mut self,
        req: &Request,
        session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError> {
        self.prefill.route(req, session, replicas)
    }

    /// The decode replica a session is pinned to, if any — for the
    /// cross-pool regression tests.
    pub fn decode_pin_of(&self, session: u64) -> Option<usize> {
        self.decode.pin_of(session)
    }
}

impl Default for Disaggregated {
    fn default() -> Self {
        Disaggregated::new()
    }
}

impl Router for Disaggregated {
    fn name(&self) -> &'static str {
        "disaggregated"
    }

    /// Stage 2 (and the whole policy on a colocated topology): sticky
    /// decode placement among `replicas` (the decode pool's snapshots).
    fn route(
        &mut self,
        req: &Request,
        session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError> {
        self.decode.route(req, session, replicas)
    }

    fn two_stage(&mut self) -> Option<&mut Disaggregated> {
        Some(self)
    }
}

/// Router names accepted by [`by_name`] — the single source the CLI help
/// and unknown-value errors are generated from.
pub const ROUTER_NAMES: [&str; 4] =
    ["round-robin", "least-loaded", "session-affinity", "disaggregated"];

/// `round-robin|least-loaded|session-affinity|disaggregated` — for CLI
/// help.
pub fn help_line() -> String {
    ROUTER_NAMES.join("|")
}

/// Construct a router by CLI-friendly name.
pub fn by_name(name: &str) -> Option<Box<dyn Router>> {
    match name {
        "round-robin" | "rr" => Some(Box::new(RoundRobin::new())),
        "least-loaded" | "ll" => Some(Box::new(LeastLoaded::new())),
        "session-affinity" | "sticky" => Some(Box::new(SessionAffinity::new())),
        "disaggregated" | "disagg" => Some(Box::new(Disaggregated::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(index: usize, queue: usize, running: usize, free: usize) -> ReplicaSnapshot {
        ReplicaSnapshot {
            index,
            queue_depth: queue,
            running,
            free_blocks: free,
            total_blocks: 100,
            can_admit_now: free > 0,
            can_ever_admit: true,
            shared_blocks: 0,
            demand_blocks: 6,
        }
    }

    fn req(id: u64) -> Request {
        Request::new(id, vec![1; 64], 32)
    }

    #[test]
    fn round_robin_cycles_and_skips_ineligible() {
        let mut rr = RoundRobin::new();
        let mut snaps = vec![snap(0, 0, 0, 100), snap(1, 0, 0, 100), snap(2, 0, 0, 100)];
        let picks: Vec<usize> =
            (0..6).map(|i| rr.route(&req(i), i, &snaps).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // Replica 1 drops out: the cycle skips it without stalling.
        snaps[1].can_ever_admit = false;
        let picks: Vec<usize> =
            (0..4).map(|i| rr.route(&req(i), i, &snaps).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn least_loaded_prefers_low_score_and_breaks_ties_low_index() {
        let mut ll = LeastLoaded::new();
        let snaps = vec![snap(0, 3, 2, 50), snap(1, 0, 1, 80), snap(2, 0, 1, 80)];
        assert_eq!(ll.route(&req(1), 1, &snaps).unwrap(), 1, "tie → lowest index");
        // KV pressure separates equally-queued replicas.
        let snaps = vec![snap(0, 1, 1, 10), snap(1, 1, 1, 90)];
        assert_eq!(ll.route(&req(2), 2, &snaps).unwrap(), 1);
    }

    #[test]
    fn least_loaded_steers_toward_resident_prefixes() {
        let mut ll = LeastLoaded::new();
        // Equal load: the replica holding the request's prefix wins even
        // against a lower index.
        let mut snaps = vec![snap(0, 1, 1, 80), snap(1, 1, 1, 80)];
        snaps[1].shared_blocks = 6; // full prefix hit (demand 6)
        assert_eq!(ll.route(&req(1), 1, &snaps).unwrap(), 1);
        // Bounded bonus: a whole queued request still outweighs it.
        snaps[0] = snap(0, 0, 0, 80);
        assert_eq!(ll.route(&req(2), 2, &snaps).unwrap(), 0, "hit never beats a 2-deep gap");
        // Session affinity inherits the steer for first-turn placement.
        let mut sa = SessionAffinity::new();
        let mut snaps = vec![snap(0, 1, 1, 80), snap(1, 1, 1, 80)];
        snaps[1].shared_blocks = 6;
        assert_eq!(sa.route(&req(3), 9, &snaps).unwrap(), 1);
        assert_eq!(sa.pin_of(9), Some(1));
    }

    #[test]
    fn routers_never_pick_never_admit_replicas() {
        let mut full = snap(0, 0, 0, 100);
        full.can_ever_admit = false;
        let ok = snap(1, 9, 9, 1); // heavily loaded but eligible
        let mut routers: Vec<Box<dyn Router>> =
            vec![Box::new(RoundRobin::new()), Box::new(LeastLoaded::new())];
        for router in &mut routers {
            assert_eq!(router.route(&req(7), 7, &[full, ok]).unwrap(), 1);
        }
        // Nobody eligible: explicit error naming the demand.
        let mut also_full = ok;
        also_full.can_ever_admit = false;
        let mut ll = LeastLoaded::new();
        let err = ll.route(&req(7), 7, &[full, also_full]).unwrap_err();
        assert!(matches!(err, RouteError::Unroutable { request: 7, .. }), "{err}");
        assert!(err.to_string().contains("96 tokens"));
    }

    #[test]
    fn session_affinity_pins_and_stays_pinned() {
        let mut sa = SessionAffinity::new();
        let mut snaps = vec![snap(0, 5, 4, 10), snap(1, 0, 0, 100)];
        // First turn: least-loaded picks replica 1 and pins the session.
        assert_eq!(sa.route(&req(0), 42, &snaps).unwrap(), 1);
        assert_eq!(sa.pin_of(42), Some(1));
        // Later turns stay put even when the load picture inverts.
        snaps[1] = snap(1, 9, 4, 2);
        snaps[0] = snap(0, 0, 0, 100);
        assert_eq!(sa.route(&req(1), 42, &snaps).unwrap(), 1);
        // A different session is free to go elsewhere.
        assert_eq!(sa.route(&req(2), 43, &snaps).unwrap(), 0);
    }

    #[test]
    fn session_affinity_refuses_rather_than_repins() {
        let mut sa = SessionAffinity::new();
        let mut snaps = vec![snap(0, 0, 0, 100), snap(1, 0, 0, 100)];
        assert_eq!(sa.route(&req(0), 5, &snaps).unwrap(), 0);
        snaps[0].can_ever_admit = false;
        let err = sa.route(&req(1), 5, &snaps).unwrap_err();
        assert!(err.to_string().contains("pinned to replica 0"), "{err}");
        assert_eq!(sa.pin_of(5), Some(0), "the pin survives the refusal");
    }

    #[test]
    fn routers_return_global_indices_on_pool_subsets() {
        // A pool-subset slice with non-contiguous indices: every router
        // must hand back a member's global index, never a slice position.
        let snaps = vec![snap(3, 0, 0, 100), snap(5, 0, 0, 100), snap(9, 0, 0, 100)];
        let mut routers: Vec<Box<dyn Router>> = vec![
            Box::new(RoundRobin::new()),
            Box::new(LeastLoaded::new()),
            Box::new(SessionAffinity::new()),
            Box::new(Disaggregated::new()),
        ];
        for router in &mut routers {
            for turn in 0..4 {
                let idx = router.route(&req(turn), turn, &snaps).unwrap();
                assert!([3, 5, 9].contains(&idx), "{} returned {idx}", router.name());
            }
        }
        // Round-robin specifically cycles through the *members*.
        let mut rr = RoundRobin::new();
        let picks: Vec<usize> =
            (0..4).map(|i| rr.route(&req(i), i, &snaps).unwrap()).collect();
        assert_eq!(picks, vec![3, 5, 9, 3]);
    }

    #[test]
    fn session_affinity_refuses_pins_outside_the_candidate_pool() {
        let mut sa = SessionAffinity::new();
        let decode_pool = vec![snap(2, 0, 0, 100), snap(3, 0, 0, 100)];
        assert_eq!(sa.route(&req(0), 11, &decode_pool).unwrap(), 2);
        // The same session shown a different pool: refusal, not a re-pin.
        let other_pool = vec![snap(0, 0, 0, 100), snap(1, 0, 0, 100)];
        let err = sa.route(&req(1), 11, &other_pool).unwrap_err();
        assert!(err.to_string().contains("outside this candidate pool"), "{err}");
        assert_eq!(sa.pin_of(11), Some(2), "the pin survives untouched");
        // Back in its own pool the session routes home again.
        assert_eq!(sa.route(&req(2), 11, &decode_pool).unwrap(), 2);
    }

    #[test]
    fn disaggregated_stages_are_independent() {
        let mut d = Disaggregated::new();
        let prefill_pool = vec![snap(0, 2, 1, 50), snap(1, 0, 0, 100)];
        let decode_pool = vec![snap(2, 0, 0, 100), snap(3, 1, 1, 80)];
        // Stage 1 balances without pinning.
        assert_eq!(d.route_prefill(&req(0), 7, &prefill_pool).unwrap(), 1);
        assert_eq!(d.decode_pin_of(7), None, "prefill placement must not pin");
        // Stage 2 pins within the decode pool and sticks there.
        assert_eq!(d.route(&req(0), 7, &decode_pool).unwrap(), 2);
        assert_eq!(d.decode_pin_of(7), Some(2));
        let inverted = vec![snap(2, 9, 9, 1), snap(3, 0, 0, 100)];
        assert_eq!(d.route(&req(1), 7, &inverted).unwrap(), 2, "stickiness holds");
        // Only the two-stage router advertises itself as such.
        assert!(d.two_stage().is_some());
        assert!(RoundRobin::new().two_stage().is_none());
        assert!(LeastLoaded::new().two_stage().is_none());
        assert!(SessionAffinity::new().two_stage().is_none());
    }

    #[test]
    fn name_registry_round_trips() {
        for name in ROUTER_NAMES {
            assert_eq!(by_name(name).unwrap().name(), name);
        }
        assert_eq!(by_name("rr").unwrap().name(), "round-robin");
        assert_eq!(by_name("sticky").unwrap().name(), "session-affinity");
        assert_eq!(by_name("disagg").unwrap().name(), "disaggregated");
        assert!(by_name("random").is_none());
        for name in ROUTER_NAMES {
            assert!(help_line().contains(name));
        }
    }
}
