//! Fleet routing: which replica serves which request.
//!
//! The router sits **in front of** each replica's admission controller —
//! it decides *placement*, the replica's bounded queues still decide
//! *acceptance*. Routers see a per-replica [`ReplicaSnapshot`] (queue
//! depth, running set, KV-block pressure from the replica's
//! `BlockManager`, and the request's *prefix-cache* footprint — how many
//! of its prompt blocks are already resident there) taken at the
//! request's arrival instant on the fleet's virtual clock. The prefix
//! term makes KV pressure *request-relative*: a replica already holding
//! a chat's system prompt is cheaper for that chat than an equally
//! loaded stranger, which is how [`LeastLoaded`] (and
//! [`SessionAffinity`]'s first-turn placement over it) keeps fan-outs of
//! a shared prefix co-located.
//!
//! Invariants every router upholds (asserted by the fleet, tested in
//! `rust/tests/cluster_fleet.rs`):
//!
//! 1. **Never route to a replica that can never admit** — a request whose
//!    worst-case KV demand exceeds a replica's entire block budget
//!    (`can_ever_admit == false`) must not be placed there; it would be
//!    refused at submission. [`LeastLoaded`] and [`RoundRobin`] skip such
//!    replicas; if none qualifies the route fails explicitly
//!    ([`RouteError::Unroutable`]) instead of wedging a queue.
//! 2. **Session stickiness is absolute** — once [`SessionAffinity`] pins a
//!    session, every later turn routes to the same replica (its KV history
//!    lives there; moving mid-session would imply a cache migration this
//!    stack doesn't model). A pinned replica that cannot take the next
//!    turn is an explicit [`RouteError::Unroutable`], never a silent
//!    re-pin.
//! 3. **Determinism** — same snapshots, same state, same decision (ties
//!    break toward the lowest replica index), so fleet runs are exactly
//!    reproducible.

use std::collections::HashMap;
use std::fmt;

use crate::coordinator::Request;

/// Per-replica load facts the fleet snapshots before each routing
/// decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaSnapshot {
    pub index: usize,
    /// Waiting in admission + open-loop arrivals not yet due.
    pub queue_depth: usize,
    /// Requests in the running set.
    pub running: usize,
    /// Free KV blocks in the replica's `BlockManager`.
    pub free_blocks: usize,
    pub total_blocks: usize,
    /// Whether the replica's `BlockManager` could admit this request right
    /// now (spare blocks at this instant).
    pub can_admit_now: bool,
    /// Whether it could EVER admit it (fits `max_seq` and the whole block
    /// budget on an empty manager). `false` means routing there is a
    /// guaranteed refusal.
    pub can_ever_admit: bool,
    /// Full prompt blocks of *this request* already resident on the
    /// replica (live or evictable — the prefix-cache probe). Routing a
    /// request to the replica that holds its prefix turns its prompt
    /// into a cache hit: admission charges only the remainder and
    /// prefill skips the shared tokens.
    pub shared_blocks: usize,
    /// Worst-case block demand of this request (`prompt + max_new`,
    /// rounded up to blocks) — the denominator of the prefix hit ratio.
    pub demand_blocks: usize,
}

impl ReplicaSnapshot {
    /// KV-block pressure in `[0, 1]`.
    pub fn kv_pressure(&self) -> f64 {
        if self.total_blocks == 0 {
            return 1.0;
        }
        1.0 - self.free_blocks as f64 / self.total_blocks as f64
    }

    /// Fraction of this request's block demand already resident on the
    /// replica, in `[0, 1]`.
    pub fn prefix_hit_ratio(&self) -> f64 {
        if self.demand_blocks == 0 {
            return 0.0;
        }
        (self.shared_blocks.min(self.demand_blocks)) as f64 / self.demand_blocks as f64
    }

    /// The [`LeastLoaded`] score: outstanding requests weighted with KV
    /// pressure, minus the prefix-affinity bonus. Pressure breaks ties
    /// between equally-queued replicas and dominates once a replica's
    /// cache is nearly full; the prefix term (bounded by 1, like
    /// pressure) steers a request toward the replica already holding its
    /// prefix — effectively the request's KV demand *as seen by that
    /// replica* — without ever outweighing a whole queued request.
    pub fn load_score(&self) -> f64 {
        (self.queue_depth + self.running) as f64 + self.kv_pressure() - self.prefix_hit_ratio()
    }
}

/// Why a request could not be placed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    /// The fleet has no replicas (snapshot list was empty).
    NoReplicas,
    /// No eligible replica: every candidate can never admit the request,
    /// or the session's pinned replica can't take it.
    Unroutable { request: u64, reason: String },
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::NoReplicas => write!(f, "no replicas to route to"),
            RouteError::Unroutable { request, reason } => {
                write!(f, "request {request} unroutable: {reason}")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// The routing policy contract. `&mut self` because policies carry state
/// (round-robin cursor, affinity map); `Send` so a fleet can move onto a
/// worker thread like an engine can.
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Choose a replica index for `req` (belonging to chat `session`)
    /// among `replicas`. Must uphold the module-level invariants.
    fn route(
        &mut self,
        req: &Request,
        session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError>;
}

fn no_eligible(req: &Request) -> RouteError {
    RouteError::Unroutable {
        request: req.id,
        reason: format!(
            "no replica can ever admit {} tokens (prompt {} + max_new {})",
            req.prompt.len() + req.max_new_tokens,
            req.prompt.len(),
            req.max_new_tokens
        ),
    }
}

/// Cycle through replicas in index order, skipping ones that can never
/// admit the request.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    /// A fresh cycle starting at replica 0.
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(
        &mut self,
        req: &Request,
        _session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError> {
        if replicas.is_empty() {
            return Err(RouteError::NoReplicas);
        }
        let n = replicas.len();
        for off in 0..n {
            let i = (self.next + off) % n;
            if replicas[i].can_ever_admit {
                self.next = (i + 1) % n;
                return Ok(i);
            }
        }
        Err(no_eligible(req))
    }
}

/// Route to the eligible replica with the lowest [`ReplicaSnapshot::
/// load_score`] (queue depth + running + KV pressure); ties break toward
/// the lowest index.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl LeastLoaded {
    /// The stateless least-loaded policy.
    pub fn new() -> LeastLoaded {
        LeastLoaded
    }
}

impl Router for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(
        &mut self,
        req: &Request,
        _session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError> {
        if replicas.is_empty() {
            return Err(RouteError::NoReplicas);
        }
        replicas
            .iter()
            .filter(|s| s.can_ever_admit)
            .min_by(|a, b| {
                a.load_score()
                    .partial_cmp(&b.load_score())
                    .expect("load scores are finite")
                    .then(a.index.cmp(&b.index))
            })
            .map(|s| s.index)
            .ok_or_else(|| no_eligible(req))
    }
}

/// Sticky session routing: the first turn of a session places it via the
/// inner router; every later turn goes to the same replica, where the
/// session's KV history lives.
pub struct SessionAffinity {
    inner: Box<dyn Router>,
    pinned: HashMap<u64, usize>,
}

impl SessionAffinity {
    /// Affinity over [`LeastLoaded`] first-turn placement (the default).
    pub fn new() -> SessionAffinity {
        SessionAffinity::over(Box::new(LeastLoaded::new()))
    }

    /// Affinity over any first-turn placement policy.
    pub fn over(inner: Box<dyn Router>) -> SessionAffinity {
        SessionAffinity { inner, pinned: HashMap::new() }
    }

    /// The replica a session is pinned to, if it has been seen.
    pub fn pin_of(&self, session: u64) -> Option<usize> {
        self.pinned.get(&session).copied()
    }
}

impl Default for SessionAffinity {
    fn default() -> Self {
        SessionAffinity::new()
    }
}

impl Router for SessionAffinity {
    fn name(&self) -> &'static str {
        "session-affinity"
    }

    fn route(
        &mut self,
        req: &Request,
        session: u64,
        replicas: &[ReplicaSnapshot],
    ) -> Result<usize, RouteError> {
        if replicas.is_empty() {
            return Err(RouteError::NoReplicas);
        }
        if let Some(&idx) = self.pinned.get(&session) {
            let snap = replicas.get(idx).ok_or_else(|| RouteError::Unroutable {
                request: req.id,
                reason: format!("session {session} pinned to missing replica {idx}"),
            })?;
            if !snap.can_ever_admit {
                // Stickiness is absolute: refusing is correct, re-pinning
                // would orphan the session's KV (invariant 2).
                return Err(RouteError::Unroutable {
                    request: req.id,
                    reason: format!(
                        "session {session} is pinned to replica {idx}, which can never admit \
                         this turn"
                    ),
                });
            }
            return Ok(idx);
        }
        let idx = self.inner.route(req, session, replicas)?;
        self.pinned.insert(session, idx);
        Ok(idx)
    }
}

/// Router names accepted by [`by_name`] — the single source the CLI help
/// and unknown-value errors are generated from.
pub const ROUTER_NAMES: [&str; 3] = ["round-robin", "least-loaded", "session-affinity"];

/// `round-robin|least-loaded|session-affinity` — for CLI help.
pub fn help_line() -> String {
    ROUTER_NAMES.join("|")
}

/// Construct a router by CLI-friendly name.
pub fn by_name(name: &str) -> Option<Box<dyn Router>> {
    match name {
        "round-robin" | "rr" => Some(Box::new(RoundRobin::new())),
        "least-loaded" | "ll" => Some(Box::new(LeastLoaded::new())),
        "session-affinity" | "sticky" => Some(Box::new(SessionAffinity::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(index: usize, queue: usize, running: usize, free: usize) -> ReplicaSnapshot {
        ReplicaSnapshot {
            index,
            queue_depth: queue,
            running,
            free_blocks: free,
            total_blocks: 100,
            can_admit_now: free > 0,
            can_ever_admit: true,
            shared_blocks: 0,
            demand_blocks: 6,
        }
    }

    fn req(id: u64) -> Request {
        Request::new(id, vec![1; 64], 32)
    }

    #[test]
    fn round_robin_cycles_and_skips_ineligible() {
        let mut rr = RoundRobin::new();
        let mut snaps = vec![snap(0, 0, 0, 100), snap(1, 0, 0, 100), snap(2, 0, 0, 100)];
        let picks: Vec<usize> =
            (0..6).map(|i| rr.route(&req(i), i, &snaps).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // Replica 1 drops out: the cycle skips it without stalling.
        snaps[1].can_ever_admit = false;
        let picks: Vec<usize> =
            (0..4).map(|i| rr.route(&req(i), i, &snaps).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn least_loaded_prefers_low_score_and_breaks_ties_low_index() {
        let mut ll = LeastLoaded::new();
        let snaps = vec![snap(0, 3, 2, 50), snap(1, 0, 1, 80), snap(2, 0, 1, 80)];
        assert_eq!(ll.route(&req(1), 1, &snaps).unwrap(), 1, "tie → lowest index");
        // KV pressure separates equally-queued replicas.
        let snaps = vec![snap(0, 1, 1, 10), snap(1, 1, 1, 90)];
        assert_eq!(ll.route(&req(2), 2, &snaps).unwrap(), 1);
    }

    #[test]
    fn least_loaded_steers_toward_resident_prefixes() {
        let mut ll = LeastLoaded::new();
        // Equal load: the replica holding the request's prefix wins even
        // against a lower index.
        let mut snaps = vec![snap(0, 1, 1, 80), snap(1, 1, 1, 80)];
        snaps[1].shared_blocks = 6; // full prefix hit (demand 6)
        assert_eq!(ll.route(&req(1), 1, &snaps).unwrap(), 1);
        // Bounded bonus: a whole queued request still outweighs it.
        snaps[0] = snap(0, 0, 0, 80);
        assert_eq!(ll.route(&req(2), 2, &snaps).unwrap(), 0, "hit never beats a 2-deep gap");
        // Session affinity inherits the steer for first-turn placement.
        let mut sa = SessionAffinity::new();
        let mut snaps = vec![snap(0, 1, 1, 80), snap(1, 1, 1, 80)];
        snaps[1].shared_blocks = 6;
        assert_eq!(sa.route(&req(3), 9, &snaps).unwrap(), 1);
        assert_eq!(sa.pin_of(9), Some(1));
    }

    #[test]
    fn routers_never_pick_never_admit_replicas() {
        let mut full = snap(0, 0, 0, 100);
        full.can_ever_admit = false;
        let ok = snap(1, 9, 9, 1); // heavily loaded but eligible
        let mut routers: Vec<Box<dyn Router>> =
            vec![Box::new(RoundRobin::new()), Box::new(LeastLoaded::new())];
        for router in &mut routers {
            assert_eq!(router.route(&req(7), 7, &[full, ok]).unwrap(), 1);
        }
        // Nobody eligible: explicit error naming the demand.
        let mut also_full = ok;
        also_full.can_ever_admit = false;
        let mut ll = LeastLoaded::new();
        let err = ll.route(&req(7), 7, &[full, also_full]).unwrap_err();
        assert!(matches!(err, RouteError::Unroutable { request: 7, .. }), "{err}");
        assert!(err.to_string().contains("96 tokens"));
    }

    #[test]
    fn session_affinity_pins_and_stays_pinned() {
        let mut sa = SessionAffinity::new();
        let mut snaps = vec![snap(0, 5, 4, 10), snap(1, 0, 0, 100)];
        // First turn: least-loaded picks replica 1 and pins the session.
        assert_eq!(sa.route(&req(0), 42, &snaps).unwrap(), 1);
        assert_eq!(sa.pin_of(42), Some(1));
        // Later turns stay put even when the load picture inverts.
        snaps[1] = snap(1, 9, 4, 2);
        snaps[0] = snap(0, 0, 0, 100);
        assert_eq!(sa.route(&req(1), 42, &snaps).unwrap(), 1);
        // A different session is free to go elsewhere.
        assert_eq!(sa.route(&req(2), 43, &snaps).unwrap(), 0);
    }

    #[test]
    fn session_affinity_refuses_rather_than_repins() {
        let mut sa = SessionAffinity::new();
        let mut snaps = vec![snap(0, 0, 0, 100), snap(1, 0, 0, 100)];
        assert_eq!(sa.route(&req(0), 5, &snaps).unwrap(), 0);
        snaps[0].can_ever_admit = false;
        let err = sa.route(&req(1), 5, &snaps).unwrap_err();
        assert!(err.to_string().contains("pinned to replica 0"), "{err}");
        assert_eq!(sa.pin_of(5), Some(0), "the pin survives the refusal");
    }

    #[test]
    fn name_registry_round_trips() {
        for name in ROUTER_NAMES {
            assert_eq!(by_name(name).unwrap().name(), name);
        }
        assert_eq!(by_name("rr").unwrap().name(), "round-robin");
        assert_eq!(by_name("sticky").unwrap().name(), "session-affinity");
        assert!(by_name("random").is_none());
        for name in ROUTER_NAMES {
            assert!(help_line().contains(name));
        }
    }
}
