//! Continuous batcher: the bounded running set and its per-step plans.
//!
//! Since the admission redesign the batcher no longer owns a waiting
//! queue — [`super::admission::AdmissionController`] holds the bounded
//! priority queues and calls [`Batcher::install`] when a request clears
//! the KV-budget check. The batcher's job is slots and step shape: which
//! rows still need prompt ingestion, which rows decode this step, and
//! which artifact batch bucket the decode call packs into (static-shape
//! routing).

use super::request::{RequestId, RunningRequest};

/// Batcher configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Maximum concurrently-running requests (the largest decode bucket).
    pub max_batch: usize,
    /// Available artifact batch buckets, ascending (e.g. [1, 2, 4]).
    pub batch_buckets: Vec<usize>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, batch_buckets: vec![1, 2, 4] }
    }
}

impl BatcherConfig {
    /// Power-of-two bucket ladder capped by `max_batch` (which is always
    /// the final bucket). The shared constructor for every surface that
    /// exposes a `--max-batch`-style knob — one derivation, not N copies.
    pub fn for_max_batch(max_batch: usize) -> BatcherConfig {
        let max_batch = max_batch.max(1);
        let mut buckets: Vec<usize> =
            std::iter::successors(Some(1usize), |b| b.checked_mul(2))
                .take_while(|&b| b < max_batch)
                .collect();
        buckets.push(max_batch);
        BatcherConfig { max_batch, batch_buckets: buckets }
    }
}

/// What the engine should do this step. The engine owns one as scratch
/// and refills it via [`Batcher::plan_into`] every step; `Default` is the
/// empty scratch.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StepPlan {
    /// Requests (by slot) that still need prompt ingestion.
    pub prefill_slots: Vec<usize>,
    /// Requests (by slot) ready for one decode step.
    pub decode_slots: Vec<usize>,
    /// Bucket chosen for the decode call (>= decode_slots.len()).
    pub decode_bucket: Option<usize>,
}

/// The running set. Owns the slots; admission owns the queue.
pub struct Batcher {
    cfg: BatcherConfig,
    running: Vec<Option<RunningRequest>>, // indexed by slot
}

impl Batcher {
    /// A batcher with every slot free.
    pub fn new(cfg: BatcherConfig) -> Batcher {
        assert!(!cfg.batch_buckets.is_empty());
        assert!(cfg.batch_buckets.windows(2).all(|w| w[0] < w[1]), "buckets must ascend");
        assert_eq!(*cfg.batch_buckets.last().unwrap(), cfg.max_batch);
        let running = (0..cfg.max_batch).map(|_| None).collect();
        Batcher { cfg, running }
    }

    /// Slot capacity (the largest decode bucket).
    pub fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }

    /// Available artifact batch buckets, ascending. The step composer
    /// picks the decode bucket from these; `plan_into` remains the
    /// equivalent legacy derivation.
    pub fn buckets(&self) -> &[usize] {
        &self.cfg.batch_buckets
    }

    /// Number of slots (== `max_batch`): the engine's per-step sweeps scan
    /// `0..num_slots()` directly instead of collecting an occupied-slot
    /// Vec on the hot path.
    pub fn num_slots(&self) -> usize {
        self.running.len()
    }

    /// Occupied slots.
    pub fn running_len(&self) -> usize {
        self.running.iter().filter(|r| r.is_some()).count()
    }

    /// Whether no request is running.
    pub fn is_empty(&self) -> bool {
        self.running_len() == 0
    }

    /// Lowest free slot, if any.
    pub fn free_slot(&self) -> Option<usize> {
        self.running.iter().position(|r| r.is_none())
    }

    /// Install an admitted request into its (pre-assigned) slot.
    pub(crate) fn install(&mut self, r: RunningRequest) {
        assert!(self.running[r.slot].is_none(), "slot {} already occupied", r.slot);
        let slot = r.slot;
        self.running[slot] = Some(r);
    }

    /// Occupied slots, ascending (cancellation sweeps).
    pub(crate) fn occupied_slots(&self) -> Vec<usize> {
        self.running
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.as_ref().map(|_| i))
            .collect()
    }

    /// Build the step plan into caller-owned scratch (cleared first):
    /// prefill-first (prompt ingestion finishes before a request joins the
    /// decode batch), then one decode call for every prompt-complete
    /// request, packed into the smallest bucket that fits. The engine
    /// reuses one `StepPlan` across steps, so the steady state fills
    /// existing capacity without allocating.
    pub fn plan_into(&self, plan: &mut StepPlan) {
        plan.prefill_slots.clear();
        plan.decode_slots.clear();
        plan.decode_bucket = None;
        for r in self.running.iter().flatten() {
            if !r.prompt_done() {
                plan.prefill_slots.push(r.slot);
            } else if !r.done() {
                plan.decode_slots.push(r.slot);
            }
        }
        if !plan.decode_slots.is_empty() {
            plan.decode_bucket = self
                .cfg
                .batch_buckets
                .iter()
                .copied()
                .find(|&b| b >= plan.decode_slots.len());
        }
    }

    /// Allocating convenience over [`Batcher::plan_into`].
    pub fn plan(&self) -> StepPlan {
        let mut plan = StepPlan::default();
        self.plan_into(&mut plan);
        plan
    }

    pub(crate) fn running(&self, slot: usize) -> Option<&RunningRequest> {
        self.running.get(slot).and_then(|r| r.as_ref())
    }

    pub(crate) fn running_mut(&mut self, slot: usize) -> Option<&mut RunningRequest> {
        self.running.get_mut(slot).and_then(|r| r.as_mut())
    }

    pub(crate) fn take(&mut self, slot: usize) -> Option<RunningRequest> {
        self.running.get_mut(slot).and_then(|r| r.take())
    }

    /// Slot of a running request by id.
    pub(crate) fn slot_of(&self, id: RequestId) -> Option<usize> {
        self.running
            .iter()
            .flatten()
            .find(|r| r.req.id == id)
            .map(|r| r.slot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lifecycle::{SubmitOptions, Ticket};
    use crate::coordinator::request::Request;

    fn batcher(max_batch: usize) -> Batcher {
        let buckets: Vec<usize> = [1, 2, 4, 8].into_iter().filter(|&b| b <= max_batch).collect();
        Batcher::new(BatcherConfig { max_batch, batch_buckets: buckets })
    }

    fn install(b: &mut Batcher, id: u64, prompt_len: usize, max_new: usize) -> usize {
        let slot = b.free_slot().expect("free slot");
        b.install(RunningRequest::new(
            Request::new(id, vec![1; prompt_len], max_new),
            Ticket::detached(&SubmitOptions::default()),
            slot,
            0,
        ));
        slot
    }

    #[test]
    fn for_max_batch_ladder_is_valid() {
        for max in [1usize, 2, 3, 4, 7, 8, 16, 33] {
            let cfg = BatcherConfig::for_max_batch(max);
            assert_eq!(*cfg.batch_buckets.last().unwrap(), max);
            assert!(cfg.batch_buckets.windows(2).all(|w| w[0] < w[1]), "max={max}");
            // Must satisfy the Batcher constructor's own asserts.
            Batcher::new(cfg);
        }
        assert_eq!(BatcherConfig::for_max_batch(8).batch_buckets, vec![1, 2, 4, 8]);
        assert_eq!(BatcherConfig::for_max_batch(3).batch_buckets, vec![1, 2, 3]);
        assert_eq!(BatcherConfig::for_max_batch(0).batch_buckets, vec![1]);
    }

    #[test]
    fn slots_fill_lowest_first_and_recycle() {
        let mut b = batcher(2);
        assert_eq!(install(&mut b, 1, 4, 4), 0);
        assert_eq!(install(&mut b, 2, 4, 4), 1);
        assert_eq!(b.free_slot(), None);
        assert_eq!(b.running_len(), 2);
        let r = b.take(0).unwrap();
        assert_eq!(r.req.id, 1);
        assert_eq!(b.free_slot(), Some(0));
        assert_eq!(install(&mut b, 3, 4, 4), 0);
        assert_eq!(b.slot_of(3), Some(0));
        assert_eq!(b.slot_of(1), None);
    }

    #[test]
    fn plan_separates_prefill_and_decode() {
        let mut b = batcher(4);
        install(&mut b, 1, 4, 4);
        install(&mut b, 2, 4, 4);
        // Initially both need prefill.
        let p = b.plan();
        assert_eq!(p.prefill_slots.len(), 2);
        assert!(p.decode_slots.is_empty());
        assert_eq!(p.decode_bucket, None);
        // Mark slot 0 prefilled: it moves to the decode set.
        b.running_mut(0).unwrap().prefilled = 4;
        let p = b.plan();
        assert_eq!(p.prefill_slots.len(), 1);
        assert_eq!(p.decode_slots, vec![0]);
        assert_eq!(p.decode_bucket, Some(1));
    }

    #[test]
    fn decode_bucket_is_smallest_fit() {
        let mut b = batcher(4);
        for id in 1..=3 {
            install(&mut b, id, 2, 4);
        }
        for slot in 0..3 {
            b.running_mut(slot).unwrap().prefilled = 2;
        }
        let p = b.plan();
        assert_eq!(p.decode_slots.len(), 3);
        assert_eq!(p.decode_bucket, Some(4)); // buckets are 1,2,4
    }

    #[test]
    fn plan_into_reuses_scratch_and_matches_plan() {
        let mut b = batcher(4);
        install(&mut b, 1, 4, 4);
        install(&mut b, 2, 4, 4);
        b.running_mut(0).unwrap().prefilled = 4;
        let mut scratch = StepPlan::default();
        b.plan_into(&mut scratch);
        assert_eq!(scratch, b.plan());
        let (cap_p, cap_d) = (scratch.prefill_slots.capacity(), scratch.decode_slots.capacity());
        // Refill into the same scratch: identical result, same buffers.
        b.plan_into(&mut scratch);
        assert_eq!(scratch, b.plan());
        assert_eq!(scratch.prefill_slots.capacity(), cap_p);
        assert_eq!(scratch.decode_slots.capacity(), cap_d);
        assert_eq!(b.num_slots(), 4);
    }

    #[test]
    fn monolithic_composer_matches_plan_into() {
        // The byte-identity foundation: under ChunkPolicy::Monolithic the
        // step composer's plan is a 1:1 mapping of this batcher's own
        // plan_into — chunks ↔ prefill_slots (whole remaining prompts),
        // identical decode set, identical bucket choice.
        use crate::schedule::{MixedStepPlan, ScheduleConfig, SlotView, StepComposer};
        let mut b = batcher(4);
        install(&mut b, 1, 8, 4);
        install(&mut b, 2, 8, 4);
        install(&mut b, 3, 8, 4);
        b.running_mut(0).unwrap().prefilled = 8; // decoding
        b.running_mut(1).unwrap().prefilled = 3; // mid-prefill
        let composer = StepComposer::new(ScheduleConfig::default());
        let mut mixed = MixedStepPlan::default();
        let slots = (0..b.num_slots()).filter_map(|slot| {
            b.running(slot).map(|r| SlotView {
                slot,
                prompt_len: r.req.prompt.len(),
                prefilled: r.prefilled,
                cached_tokens: r.cached_prompt_tokens,
                done: r.done(),
            })
        });
        composer.compose_into(slots, b.buckets(), &mut mixed);
        let plan = b.plan();
        let chunk_slots: Vec<usize> = mixed.chunks.iter().map(|c| c.slot).collect();
        assert_eq!(chunk_slots, plan.prefill_slots);
        for c in &mixed.chunks {
            let r = b.running(c.slot).unwrap();
            assert_eq!(c.start, r.prefilled, "span resumes where ingestion stopped");
            assert_eq!(c.end(), r.req.prompt.len(), "monolithic spans finish the prompt");
        }
        assert_eq!(mixed.decode_slots, plan.decode_slots);
        assert_eq!(mixed.decode_bucket, plan.decode_bucket);
    }

    #[test]
    fn occupied_slots_track_the_running_set() {
        let mut b = batcher(4);
        install(&mut b, 1, 2, 2);
        install(&mut b, 2, 2, 2);
        install(&mut b, 3, 2, 2);
        b.take(1);
        assert_eq!(b.occupied_slots(), vec![0, 2]);
        assert!(!b.is_empty());
    }

    #[test]
    #[should_panic]
    fn double_install_in_one_slot_panics() {
        let mut b = batcher(2);
        let r1 = RunningRequest::new(
            Request::new(1, vec![1; 2], 2),
            Ticket::detached(&SubmitOptions::default()),
            0,
            0,
        );
        let r2 = RunningRequest::new(
            Request::new(2, vec![1; 2], 2),
            Ticket::detached(&SubmitOptions::default()),
            0,
            0,
        );
        b.install(r1);
        b.install(r2);
    }
}
