//! Continuous batcher: FCFS admission into a bounded running set, with
//! per-step plans that pack the running set into the artifact batch
//! buckets (static-shape routing).

use std::collections::VecDeque;

use super::kv_cache::BlockManager;
use super::request::{Request, RequestId, RunningRequest};

/// Batcher configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Maximum concurrently-running requests (the largest decode bucket).
    pub max_batch: usize,
    /// Available artifact batch buckets, ascending (e.g. [1, 2, 4]).
    pub batch_buckets: Vec<usize>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, batch_buckets: vec![1, 2, 4] }
    }
}

/// What the engine should do this step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepPlan {
    /// Requests (by slot) that still need prompt ingestion.
    pub prefill_slots: Vec<usize>,
    /// Requests (by slot) ready for one decode step.
    pub decode_slots: Vec<usize>,
    /// Bucket chosen for the decode call (>= decode_slots.len()).
    pub decode_bucket: Option<usize>,
}

/// The continuous batcher. Owns the waiting queue and running set.
pub struct Batcher {
    cfg: BatcherConfig,
    waiting: VecDeque<Request>,
    running: Vec<Option<RunningRequest>>, // indexed by slot
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        assert!(!cfg.batch_buckets.is_empty());
        assert!(cfg.batch_buckets.windows(2).all(|w| w[0] < w[1]), "buckets must ascend");
        assert_eq!(*cfg.batch_buckets.last().unwrap(), cfg.max_batch);
        let running = (0..cfg.max_batch).map(|_| None).collect();
        Batcher { cfg, waiting: VecDeque::new(), running }
    }

    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.iter().filter(|r| r.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running_len() == 0
    }

    /// Admit waiting requests into free slots while the block manager
    /// accepts them (FCFS — head-of-line blocking is intentional, matching
    /// vLLM's default scheduler).
    pub fn admit(&mut self, blocks: &mut BlockManager, now_us: u64) -> Vec<RequestId> {
        let mut admitted = Vec::new();
        while self.running_len() < self.cfg.max_batch {
            let Some(front) = self.waiting.front() else { break };
            if !blocks.can_admit(front.prompt.len(), front.max_new_tokens) {
                break;
            }
            let req = self.waiting.pop_front().unwrap();
            blocks
                .admit(req.id, req.prompt.len(), req.max_new_tokens)
                .expect("can_admit checked");
            let slot = self
                .running
                .iter()
                .position(|r| r.is_none())
                .expect("running_len < max_batch implies a free slot");
            admitted.push(req.id);
            self.running[slot] = Some(RunningRequest::new(req, slot, now_us));
        }
        admitted
    }

    /// Build the step plan: prefill-first (prompt ingestion finishes before
    /// a request joins the decode batch), then one decode call for every
    /// prompt-complete request, packed into the smallest bucket that fits.
    pub fn plan(&self) -> StepPlan {
        let mut prefill_slots = Vec::new();
        let mut decode_slots = Vec::new();
        for r in self.running.iter().flatten() {
            if !r.prompt_done() {
                prefill_slots.push(r.slot);
            } else if !r.done() {
                decode_slots.push(r.slot);
            }
        }
        let decode_bucket = if decode_slots.is_empty() {
            None
        } else {
            self.cfg
                .batch_buckets
                .iter()
                .copied()
                .find(|&b| b >= decode_slots.len())
        };
        StepPlan { prefill_slots, decode_slots, decode_bucket }
    }

    pub(crate) fn running(&self, slot: usize) -> Option<&RunningRequest> {
        self.running.get(slot).and_then(|r| r.as_ref())
    }

    pub(crate) fn running_mut(&mut self, slot: usize) -> Option<&mut RunningRequest> {
        self.running.get_mut(slot).and_then(|r| r.as_mut())
    }

    pub(crate) fn take(&mut self, slot: usize) -> Option<RunningRequest> {
        self.running.get_mut(slot).and_then(|r| r.take())
    }

    /// Drain every request (engine shutdown).
    pub(crate) fn drain(&mut self) -> (Vec<Request>, Vec<RunningRequest>) {
        let waiting = self.waiting.drain(..).collect();
        let running = self.running.iter_mut().filter_map(|r| r.take()).collect();
        (waiting, running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::BlockManagerConfig;

    fn setup(max_batch: usize, num_blocks: usize) -> (Batcher, BlockManager) {
        let buckets: Vec<usize> = [1, 2, 4, 8].into_iter().filter(|&b| b <= max_batch).collect();
        let b = Batcher::new(BatcherConfig { max_batch, batch_buckets: buckets });
        let m = BlockManager::new(BlockManagerConfig {
            block_size: 16,
            num_blocks,
            max_seq: 1024,
        });
        (b, m)
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(id, vec![1; prompt_len], max_new)
    }

    #[test]
    fn fcfs_admission_respects_batch_and_blocks() {
        let (mut b, mut m) = setup(2, 8); // 128-token budget
        b.submit(req(1, 32, 16)); // 3 blocks
        b.submit(req(2, 32, 16)); // 3 blocks
        b.submit(req(3, 32, 16)); // would fit blocks (2 left? 8-6=2 < 3) -> no
        let admitted = b.admit(&mut m, 0);
        assert_eq!(admitted, vec![1, 2]);
        assert_eq!(b.running_len(), 2);
        assert_eq!(b.waiting_len(), 1);
        // Slot freed => next admit picks up request 3.
        let r = b.take(0).unwrap();
        m.release(r.req.id).unwrap();
        let admitted = b.admit(&mut m, 1);
        assert_eq!(admitted, vec![3]);
    }

    #[test]
    fn head_of_line_blocking_is_fcfs() {
        let (mut b, mut m) = setup(4, 4); // tiny: 64 tokens
        b.submit(req(1, 60, 4)); // 4 blocks — fits alone
        b.submit(req(2, 8, 8));  // 1 block — would fit, but behind #1
        let admitted = b.admit(&mut m, 0);
        assert_eq!(admitted, vec![1]);
        // #2 must NOT leapfrog even though it fits.
        assert_eq!(b.admit(&mut m, 0), Vec::<u64>::new());
        assert_eq!(b.waiting_len(), 1);
    }

    #[test]
    fn plan_separates_prefill_and_decode() {
        let (mut b, mut m) = setup(4, 64);
        b.submit(req(1, 4, 4));
        b.submit(req(2, 4, 4));
        b.admit(&mut m, 0);
        // Initially both need prefill.
        let p = b.plan();
        assert_eq!(p.prefill_slots.len(), 2);
        assert!(p.decode_slots.is_empty());
        assert_eq!(p.decode_bucket, None);
        // Mark slot 0 prefilled: it moves to the decode set.
        b.running_mut(0).unwrap().prefilled = 4;
        let p = b.plan();
        assert_eq!(p.prefill_slots.len(), 1);
        assert_eq!(p.decode_slots, vec![0]);
        assert_eq!(p.decode_bucket, Some(1));
    }

    #[test]
    fn decode_bucket_is_smallest_fit() {
        let (mut b, mut m) = setup(4, 64);
        for id in 1..=3 {
            b.submit(req(id, 2, 4));
        }
        b.admit(&mut m, 0);
        for slot in 0..3 {
            b.running_mut(slot).unwrap().prefilled = 2;
        }
        let p = b.plan();
        assert_eq!(p.decode_slots.len(), 3);
        assert_eq!(p.decode_bucket, Some(4)); // buckets are 1,2,4
    }

    #[test]
    fn drain_empties_everything() {
        let (mut b, mut m) = setup(2, 64);
        b.submit(req(1, 2, 2));
        b.submit(req(2, 2, 2));
        b.submit(req(3, 2, 2));
        b.admit(&mut m, 0);
        let (waiting, running) = b.drain();
        assert_eq!(waiting.len(), 1);
        assert_eq!(running.len(), 2);
        assert!(b.is_idle());
    }
}
