//! Serving metrics: per-request timing and engine-level aggregates.

use crate::util::stats::Summary;

use super::kv_cache::PrefixCacheStats;
use super::lifecycle::{Priority, PRIORITY_CLASSES};

/// Timing of one completed request (all µs, relative to engine start).
#[derive(Debug, Clone, Default)]
pub struct RequestTiming {
    pub arrival_us: u64,
    pub scheduled_us: u64,
    pub first_token_us: u64,
    pub finished_us: u64,
    pub n_generated: usize,
}

impl RequestTiming {
    /// Queueing delay before the request entered the running set.
    pub fn queue_us(&self) -> u64 {
        self.scheduled_us.saturating_sub(self.arrival_us)
    }

    /// Time to first token from arrival.
    pub fn ttft_us(&self) -> u64 {
        self.first_token_us.saturating_sub(self.arrival_us)
    }

    /// Time per output token after the first (the paper's §3.1 target
    /// metric). Zero if fewer than 2 tokens.
    pub fn tpot_us(&self) -> f64 {
        if self.n_generated < 2 {
            return 0.0;
        }
        self.finished_us.saturating_sub(self.first_token_us) as f64
            / (self.n_generated - 1) as f64
    }

    /// End-to-end latency from arrival to completion, µs.
    pub fn e2e_us(&self) -> u64 {
        self.finished_us.saturating_sub(self.arrival_us)
    }
}

/// Rolling engine metrics.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub steps: usize,
    pub decode_steps: usize,
    /// Steps that interleaved chunked-prefill rows with decode rows (a
    /// subset of `steps`; zero under monolithic prefill).
    pub mixed_steps: usize,
    /// Rows executed per kind, summed over all steps: chunk/prefill rows
    /// ingest prompt tokens, decode rows emit one token each. The
    /// continuous-batching bench reports the interleave ratio from these.
    pub prefill_rows: usize,
    pub decode_rows: usize,
    pub prefill_calls: usize,
    pub tokens_generated: usize,
    pub requests_finished: usize,
    /// Requests cut short by cancellation, deadline, or shutdown (not
    /// counted in `requests_finished` and excluded from TTFT/TPOT).
    pub requests_cancelled: usize,
    /// Of the cancelled, those whose cause was a missed deadline.
    pub deadline_misses: usize,
    /// Submissions refused by the bounded admission queues.
    pub rejected_backpressure: usize,
    /// Submissions refused because they can never fit the KV budget.
    pub rejected_unschedulable: usize,
    /// Prefix-cache counters, mirrored by copy from the block manager
    /// every step (hit-rate, blocks saved, tokens whose prefill was
    /// skipped, COW forks — the single source of truth stays
    /// `BlockManager::prefix_stats`).
    pub prefix: PrefixCacheStats,
    step_latencies_us: Vec<f64>,
    tpots_us: Vec<f64>,
    ttfts_us: Vec<f64>,
    /// TTFT/TPOT samples split by admission class (index =
    /// `Priority::index()`), so mixed-load runs can gate interactive
    /// latency separately from batch-lane latency. The flat `ttfts_us` /
    /// `tpots_us` remain the all-classes aggregate.
    ttfts_class_us: [Vec<f64>; PRIORITY_CLASSES],
    tpots_class_us: [Vec<f64>; PRIORITY_CLASSES],
    /// Histogram of split counts chosen by the scheduler (index = splits).
    pub split_histogram: Vec<usize>,
    /// Sum of planned first-wave SM occupancy over decode steps (the §2.1
    /// quantity; divide by `decode_steps` for the mean). Per-replica
    /// occupancy is what the cluster fleet aggregates to show TP sharding
    /// entering the paper's starved regime.
    decode_occupancy_sum: f64,
    /// Sum/count of planned first-wave occupancy over chunk waves — the
    /// `q_len > 1` side of the split heuristic's evidence. Chunk rows pack
    /// `l_q * group` query rows per M-block, so their occupancy sits far
    /// above the starved decode regime; reporting the two separately keeps
    /// the decode mean honest under mixed steps.
    chunk_occupancy_sum: f64,
    chunk_waves: usize,
    pub wall_us: u64,
}

impl EngineMetrics {
    /// Pre-reserve the aggregate sample buffers so a measured window of
    /// `steps` steps / `requests` completions records without growing any
    /// Vec. The allocation-guard test and the decode hot-path bench call
    /// this between warmup and their measured window; ordinary callers
    /// never need it (growth is amortized).
    pub fn reserve_capacity(&mut self, steps: usize, requests: usize) {
        self.step_latencies_us.reserve(steps);
        self.tpots_us.reserve(requests);
        self.ttfts_us.reserve(requests);
        for class in 0..PRIORITY_CLASSES {
            self.ttfts_class_us[class].reserve(requests);
            self.tpots_class_us[class].reserve(requests);
        }
        // Headroom for any split count a device can choose (caps are
        // <= 128 on every preset), so a first-seen split mid-window
        // resizes within capacity instead of reallocating.
        let want = 257usize;
        self.split_histogram.reserve(want.saturating_sub(self.split_histogram.len()));
    }

    /// Record one engine step (`decoded` = tokens emitted).
    pub fn record_step(&mut self, latency_us: f64, decoded: usize) {
        self.steps += 1;
        if decoded > 0 {
            self.decode_steps += 1;
            self.tokens_generated += decoded;
        }
        self.step_latencies_us.push(latency_us);
    }

    /// Record the scheduler's split choice for one decode step.
    pub fn record_split(&mut self, num_splits: usize) {
        if self.split_histogram.len() <= num_splits {
            self.split_histogram.resize(num_splits + 1, 0);
        }
        self.split_histogram[num_splits] += 1;
    }

    /// Record the planned first-wave occupancy of one decode launch.
    pub fn record_decode_occupancy(&mut self, occupancy: f64) {
        self.decode_occupancy_sum += occupancy;
    }

    /// Mean planned SM occupancy across decode steps, if any ran.
    pub fn mean_occupancy(&self) -> Option<f64> {
        (self.decode_steps > 0).then(|| self.decode_occupancy_sum / self.decode_steps as f64)
    }

    /// Record the row mix of one executed step (chunk/prefill rows vs
    /// decode rows).
    pub fn record_rows(&mut self, prefill: usize, decode: usize) {
        self.prefill_rows += prefill;
        self.decode_rows += decode;
    }

    /// Record the planned first-wave occupancy of one chunk wave
    /// (`q_len > 1` rows inside a mixed step).
    pub fn record_chunk_wave(&mut self, occupancy: f64) {
        self.chunk_occupancy_sum += occupancy;
        self.chunk_waves += 1;
    }

    /// Mean planned SM occupancy across chunk waves, if any ran.
    pub fn mean_chunk_occupancy(&self) -> Option<f64> {
        (self.chunk_waves > 0).then(|| self.chunk_occupancy_sum / self.chunk_waves as f64)
    }

    /// Record a naturally-finished request's timing under its admission
    /// class.
    pub fn record_finished(&mut self, timing: &RequestTiming, priority: Priority) {
        self.requests_finished += 1;
        if timing.n_generated >= 2 {
            self.tpots_us.push(timing.tpot_us());
            self.tpots_class_us[priority.index()].push(timing.tpot_us());
        }
        self.ttfts_us.push(timing.ttft_us() as f64);
        self.ttfts_class_us[priority.index()].push(timing.ttft_us() as f64);
    }

    /// Record a request cut short (cancel, shutdown, or deadline).
    pub fn record_cancelled(&mut self, deadline_miss: bool) {
        self.requests_cancelled += 1;
        if deadline_miss {
            self.deadline_misses += 1;
        }
    }

    /// Step-latency distribution, if any step ran.
    pub fn step_latency(&self) -> Option<Summary> {
        (!self.step_latencies_us.is_empty()).then(|| Summary::of(&self.step_latencies_us))
    }

    /// Time-per-output-token distribution over finished requests.
    pub fn tpot(&self) -> Option<Summary> {
        (!self.tpots_us.is_empty()).then(|| Summary::of(&self.tpots_us))
    }

    /// Time-to-first-token distribution over finished requests.
    pub fn ttft(&self) -> Option<Summary> {
        (!self.ttfts_us.is_empty()).then(|| Summary::of(&self.ttfts_us))
    }

    /// TTFT distribution for one admission class.
    pub fn ttft_for(&self, priority: Priority) -> Option<Summary> {
        let samples = &self.ttfts_class_us[priority.index()];
        (!samples.is_empty()).then(|| Summary::of(samples))
    }

    /// TPOT distribution for one admission class.
    pub fn tpot_for(&self, priority: Priority) -> Option<Summary> {
        let samples = &self.tpots_class_us[priority.index()];
        (!samples.is_empty()).then(|| Summary::of(samples))
    }

    /// Generated tokens per second of wall time.
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_us == 0 {
            return 0.0;
        }
        self.tokens_generated as f64 / (self.wall_us as f64 / 1e6)
    }

    /// Multi-line human-readable report (the CLI's output).
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "steps={} (decode={} prefill_calls={}) tokens={} finished={}\n",
            self.steps, self.decode_steps, self.prefill_calls, self.tokens_generated, self.requests_finished
        ));
        if self.mixed_steps > 0 {
            out.push_str(&format!(
                "mixed steps={} rows: prefill={} decode={}\n",
                self.mixed_steps, self.prefill_rows, self.decode_rows
            ));
        }
        if self.requests_cancelled + self.rejected_backpressure + self.rejected_unschedulable > 0 {
            out.push_str(&format!(
                "cancelled={} (deadline={}) rejected: backpressure={} unschedulable={}\n",
                self.requests_cancelled,
                self.deadline_misses,
                self.rejected_backpressure,
                self.rejected_unschedulable
            ));
        }
        if let Some(s) = self.step_latency() {
            out.push_str(&format!(
                "step latency µs: mean={:.1} p50={:.1} p99={:.1}\n",
                s.mean, s.p50, s.p99
            ));
        }
        if let Some(s) = self.tpot() {
            out.push_str(&format!("TPOT µs: mean={:.1} p50={:.1} p99={:.1}\n", s.mean, s.p50, s.p99));
        }
        if let Some(s) = self.ttft() {
            out.push_str(&format!("TTFT µs: mean={:.1} p50={:.1} p99={:.1}\n", s.mean, s.p50, s.p99));
        }
        // Per-class split only when the run actually mixed classes.
        let classes_seen =
            Priority::all().iter().filter(|p| self.ttft_for(**p).is_some()).count();
        if classes_seen > 1 {
            for p in Priority::all() {
                if let Some(s) = self.ttft_for(p) {
                    out.push_str(&format!(
                        "  {} TTFT µs: mean={:.1} p50={:.1} p99={:.1}",
                        p.name(),
                        s.mean,
                        s.p50,
                        s.p99
                    ));
                    if let Some(t) = self.tpot_for(p) {
                        out.push_str(&format!("  TPOT p50={:.1}", t.p50));
                    }
                    out.push('\n');
                }
            }
        }
        out.push_str(&format!("throughput: {:.1} tok/s\n", self.throughput_tok_s()));
        if self.prefix.lookups > 0 {
            out.push_str(&format!(
                "prefix cache: hit-rate {:.1}% ({}/{} blocks), saved {} blocks / {} tokens, \
                 cow forks {}, revived {}, evictions {}\n",
                self.prefix.hit_rate() * 100.0,
                self.prefix.hits,
                self.prefix.lookups,
                self.prefix.blocks_saved(),
                self.prefix.tokens_cached,
                self.prefix.cow_forks,
                self.prefix.revived,
                self.prefix.evictions
            ));
        }
        if let Some(occ) = self.mean_occupancy() {
            out.push_str(&format!("mean decode SM occupancy: {:.1}%\n", occ * 100.0));
        }
        if let Some(occ) = self.mean_chunk_occupancy() {
            out.push_str(&format!("mean chunk-wave SM occupancy: {:.1}%\n", occ * 100.0));
        }
        let hist: Vec<String> = self
            .split_histogram
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(s, c)| format!("s={s}:{c}"))
            .collect();
        if !hist.is_empty() {
            out.push_str(&format!("split histogram: {}\n", hist.join(" ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_derivations() {
        let t = RequestTiming {
            arrival_us: 100,
            scheduled_us: 150,
            first_token_us: 400,
            finished_us: 1400,
            n_generated: 11,
        };
        assert_eq!(t.queue_us(), 50);
        assert_eq!(t.ttft_us(), 300);
        assert_eq!(t.e2e_us(), 1300);
        assert!((t.tpot_us() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn tpot_needs_two_tokens() {
        let t = RequestTiming { n_generated: 1, ..Default::default() };
        assert_eq!(t.tpot_us(), 0.0);
    }

    #[test]
    fn occupancy_mean_over_decode_steps() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.mean_occupancy(), None);
        m.record_step(10.0, 1); // decode step
        m.record_decode_occupancy(0.02);
        m.record_step(12.0, 1);
        m.record_decode_occupancy(0.04);
        m.record_step(500.0, 0); // prefill step: no occupancy sample
        let occ = m.mean_occupancy().unwrap();
        assert!((occ - 0.03).abs() < 1e-12, "occ={occ}");
        assert!(m.report().contains("mean decode SM occupancy"));
    }

    #[test]
    fn per_class_latency_split() {
        let mut m = EngineMetrics::default();
        let timing = |arrival: u64, first: u64| RequestTiming {
            arrival_us: arrival,
            scheduled_us: arrival,
            first_token_us: first,
            finished_us: first + 900,
            n_generated: 10,
        };
        m.record_finished(&timing(0, 100), Priority::Interactive);
        m.record_finished(&timing(0, 5000), Priority::Batch);
        assert_eq!(m.requests_finished, 2);
        // Aggregate sees both; each class sees only its own.
        assert_eq!(m.ttft().unwrap().max, 5000.0);
        assert_eq!(m.ttft_for(Priority::Interactive).unwrap().max, 100.0);
        assert_eq!(m.ttft_for(Priority::Batch).unwrap().p50, 5000.0);
        assert_eq!(m.ttft_for(Priority::Standard), None);
        assert!((m.tpot_for(Priority::Interactive).unwrap().p50 - 100.0).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("interactive TTFT"), "{rep}");
        assert!(rep.contains("batch TTFT"), "{rep}");
        assert!(!rep.contains("standard TTFT"), "{rep}");
    }

    #[test]
    fn single_class_report_skips_the_split() {
        let mut m = EngineMetrics::default();
        let t = RequestTiming { first_token_us: 100, finished_us: 200, n_generated: 2, ..Default::default() };
        m.record_finished(&t, Priority::Standard);
        assert!(!m.report().contains("standard TTFT"), "{}", m.report());
    }

    #[test]
    fn chunk_waves_and_row_mix() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.mean_chunk_occupancy(), None);
        m.mixed_steps = 2;
        m.record_rows(3, 5);
        m.record_rows(1, 6);
        m.record_chunk_wave(0.5);
        m.record_chunk_wave(0.7);
        assert_eq!(m.prefill_rows, 4);
        assert_eq!(m.decode_rows, 11);
        let occ = m.mean_chunk_occupancy().unwrap();
        assert!((occ - 0.6).abs() < 1e-12, "occ={occ}");
        let rep = m.report();
        assert!(rep.contains("mixed steps=2 rows: prefill=4 decode=11"), "{rep}");
        assert!(rep.contains("mean chunk-wave SM occupancy: 60.0%"), "{rep}");
    }

    #[test]
    fn metrics_aggregate() {
        let mut m = EngineMetrics::default();
        m.record_step(10.0, 2);
        m.record_step(20.0, 0);
        m.record_split(1);
        m.record_split(3);
        m.record_split(3);
        m.wall_us = 1_000_000;
        assert_eq!(m.steps, 2);
        assert_eq!(m.decode_steps, 1);
        assert_eq!(m.tokens_generated, 2);
        assert_eq!(m.split_histogram[3], 2);
        assert!((m.throughput_tok_s() - 2.0).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("s=3:2"));
    }
}
