//! Serving metrics: per-request timing and engine-level aggregates.

use crate::util::stats::Summary;

use super::kv_cache::PrefixCacheStats;

/// Timing of one completed request (all µs, relative to engine start).
#[derive(Debug, Clone, Default)]
pub struct RequestTiming {
    pub arrival_us: u64,
    pub scheduled_us: u64,
    pub first_token_us: u64,
    pub finished_us: u64,
    pub n_generated: usize,
}

impl RequestTiming {
    /// Queueing delay before the request entered the running set.
    pub fn queue_us(&self) -> u64 {
        self.scheduled_us.saturating_sub(self.arrival_us)
    }

    /// Time to first token from arrival.
    pub fn ttft_us(&self) -> u64 {
        self.first_token_us.saturating_sub(self.arrival_us)
    }

    /// Time per output token after the first (the paper's §3.1 target
    /// metric). Zero if fewer than 2 tokens.
    pub fn tpot_us(&self) -> f64 {
        if self.n_generated < 2 {
            return 0.0;
        }
        self.finished_us.saturating_sub(self.first_token_us) as f64
            / (self.n_generated - 1) as f64
    }

    /// End-to-end latency from arrival to completion, µs.
    pub fn e2e_us(&self) -> u64 {
        self.finished_us.saturating_sub(self.arrival_us)
    }
}

/// Rolling engine metrics.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub steps: usize,
    pub decode_steps: usize,
    pub prefill_calls: usize,
    pub tokens_generated: usize,
    pub requests_finished: usize,
    /// Requests cut short by cancellation, deadline, or shutdown (not
    /// counted in `requests_finished` and excluded from TTFT/TPOT).
    pub requests_cancelled: usize,
    /// Of the cancelled, those whose cause was a missed deadline.
    pub deadline_misses: usize,
    /// Submissions refused by the bounded admission queues.
    pub rejected_backpressure: usize,
    /// Submissions refused because they can never fit the KV budget.
    pub rejected_unschedulable: usize,
    /// Prefix-cache counters, mirrored by copy from the block manager
    /// every step (hit-rate, blocks saved, tokens whose prefill was
    /// skipped, COW forks — the single source of truth stays
    /// `BlockManager::prefix_stats`).
    pub prefix: PrefixCacheStats,
    step_latencies_us: Vec<f64>,
    tpots_us: Vec<f64>,
    ttfts_us: Vec<f64>,
    /// Histogram of split counts chosen by the scheduler (index = splits).
    pub split_histogram: Vec<usize>,
    /// Sum of planned first-wave SM occupancy over decode steps (the §2.1
    /// quantity; divide by `decode_steps` for the mean). Per-replica
    /// occupancy is what the cluster fleet aggregates to show TP sharding
    /// entering the paper's starved regime.
    decode_occupancy_sum: f64,
    pub wall_us: u64,
}

impl EngineMetrics {
    /// Pre-reserve the aggregate sample buffers so a measured window of
    /// `steps` steps / `requests` completions records without growing any
    /// Vec. The allocation-guard test and the decode hot-path bench call
    /// this between warmup and their measured window; ordinary callers
    /// never need it (growth is amortized).
    pub fn reserve_capacity(&mut self, steps: usize, requests: usize) {
        self.step_latencies_us.reserve(steps);
        self.tpots_us.reserve(requests);
        self.ttfts_us.reserve(requests);
        // Headroom for any split count a device can choose (caps are
        // <= 128 on every preset), so a first-seen split mid-window
        // resizes within capacity instead of reallocating.
        let want = 257usize;
        self.split_histogram.reserve(want.saturating_sub(self.split_histogram.len()));
    }

    /// Record one engine step (`decoded` = tokens emitted).
    pub fn record_step(&mut self, latency_us: f64, decoded: usize) {
        self.steps += 1;
        if decoded > 0 {
            self.decode_steps += 1;
            self.tokens_generated += decoded;
        }
        self.step_latencies_us.push(latency_us);
    }

    /// Record the scheduler's split choice for one decode step.
    pub fn record_split(&mut self, num_splits: usize) {
        if self.split_histogram.len() <= num_splits {
            self.split_histogram.resize(num_splits + 1, 0);
        }
        self.split_histogram[num_splits] += 1;
    }

    /// Record the planned first-wave occupancy of one decode launch.
    pub fn record_decode_occupancy(&mut self, occupancy: f64) {
        self.decode_occupancy_sum += occupancy;
    }

    /// Mean planned SM occupancy across decode steps, if any ran.
    pub fn mean_occupancy(&self) -> Option<f64> {
        (self.decode_steps > 0).then(|| self.decode_occupancy_sum / self.decode_steps as f64)
    }

    /// Record a naturally-finished request's timing.
    pub fn record_finished(&mut self, timing: &RequestTiming) {
        self.requests_finished += 1;
        if timing.n_generated >= 2 {
            self.tpots_us.push(timing.tpot_us());
        }
        self.ttfts_us.push(timing.ttft_us() as f64);
    }

    /// Record a request cut short (cancel, shutdown, or deadline).
    pub fn record_cancelled(&mut self, deadline_miss: bool) {
        self.requests_cancelled += 1;
        if deadline_miss {
            self.deadline_misses += 1;
        }
    }

    /// Step-latency distribution, if any step ran.
    pub fn step_latency(&self) -> Option<Summary> {
        (!self.step_latencies_us.is_empty()).then(|| Summary::of(&self.step_latencies_us))
    }

    /// Time-per-output-token distribution over finished requests.
    pub fn tpot(&self) -> Option<Summary> {
        (!self.tpots_us.is_empty()).then(|| Summary::of(&self.tpots_us))
    }

    /// Time-to-first-token distribution over finished requests.
    pub fn ttft(&self) -> Option<Summary> {
        (!self.ttfts_us.is_empty()).then(|| Summary::of(&self.ttfts_us))
    }

    /// Generated tokens per second of wall time.
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_us == 0 {
            return 0.0;
        }
        self.tokens_generated as f64 / (self.wall_us as f64 / 1e6)
    }

    /// Multi-line human-readable report (the CLI's output).
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "steps={} (decode={} prefill_calls={}) tokens={} finished={}\n",
            self.steps, self.decode_steps, self.prefill_calls, self.tokens_generated, self.requests_finished
        ));
        if self.requests_cancelled + self.rejected_backpressure + self.rejected_unschedulable > 0 {
            out.push_str(&format!(
                "cancelled={} (deadline={}) rejected: backpressure={} unschedulable={}\n",
                self.requests_cancelled,
                self.deadline_misses,
                self.rejected_backpressure,
                self.rejected_unschedulable
            ));
        }
        if let Some(s) = self.step_latency() {
            out.push_str(&format!(
                "step latency µs: mean={:.1} p50={:.1} p99={:.1}\n",
                s.mean, s.p50, s.p99
            ));
        }
        if let Some(s) = self.tpot() {
            out.push_str(&format!("TPOT µs: mean={:.1} p50={:.1} p99={:.1}\n", s.mean, s.p50, s.p99));
        }
        if let Some(s) = self.ttft() {
            out.push_str(&format!("TTFT µs: mean={:.1} p50={:.1} p99={:.1}\n", s.mean, s.p50, s.p99));
        }
        out.push_str(&format!("throughput: {:.1} tok/s\n", self.throughput_tok_s()));
        if self.prefix.lookups > 0 {
            out.push_str(&format!(
                "prefix cache: hit-rate {:.1}% ({}/{} blocks), saved {} blocks / {} tokens, \
                 cow forks {}, revived {}, evictions {}\n",
                self.prefix.hit_rate() * 100.0,
                self.prefix.hits,
                self.prefix.lookups,
                self.prefix.blocks_saved(),
                self.prefix.tokens_cached,
                self.prefix.cow_forks,
                self.prefix.revived,
                self.prefix.evictions
            ));
        }
        if let Some(occ) = self.mean_occupancy() {
            out.push_str(&format!("mean decode SM occupancy: {:.1}%\n", occ * 100.0));
        }
        let hist: Vec<String> = self
            .split_histogram
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(s, c)| format!("s={s}:{c}"))
            .collect();
        if !hist.is_empty() {
            out.push_str(&format!("split histogram: {}\n", hist.join(" ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_derivations() {
        let t = RequestTiming {
            arrival_us: 100,
            scheduled_us: 150,
            first_token_us: 400,
            finished_us: 1400,
            n_generated: 11,
        };
        assert_eq!(t.queue_us(), 50);
        assert_eq!(t.ttft_us(), 300);
        assert_eq!(t.e2e_us(), 1300);
        assert!((t.tpot_us() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn tpot_needs_two_tokens() {
        let t = RequestTiming { n_generated: 1, ..Default::default() };
        assert_eq!(t.tpot_us(), 0.0);
    }

    #[test]
    fn occupancy_mean_over_decode_steps() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.mean_occupancy(), None);
        m.record_step(10.0, 1); // decode step
        m.record_decode_occupancy(0.02);
        m.record_step(12.0, 1);
        m.record_decode_occupancy(0.04);
        m.record_step(500.0, 0); // prefill step: no occupancy sample
        let occ = m.mean_occupancy().unwrap();
        assert!((occ - 0.03).abs() < 1e-12, "occ={occ}");
        assert!(m.report().contains("mean decode SM occupancy"));
    }

    #[test]
    fn metrics_aggregate() {
        let mut m = EngineMetrics::default();
        m.record_step(10.0, 2);
        m.record_step(20.0, 0);
        m.record_split(1);
        m.record_split(3);
        m.record_split(3);
        m.wall_us = 1_000_000;
        assert_eq!(m.steps, 2);
        assert_eq!(m.decode_steps, 1);
        assert_eq!(m.tokens_generated, 2);
        assert_eq!(m.split_histogram[3], 2);
        assert!((m.throughput_tok_s() - 2.0).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("s=3:2"));
    }
}
