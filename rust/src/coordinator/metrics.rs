//! Serving metrics: per-request timing and engine-level aggregates.
//!
//! Since the observability PR, `EngineMetrics` is implemented *over* the
//! [`crate::obs::MetricsRegistry`]: every distribution (step latency,
//! TTFT/TPOT, decode/chunk occupancy, occupancy keyed by policy × h_kv ×
//! nblk bucket) is a pre-registered histogram updated by index handle —
//! alloc-free in the measured window — and the whole snapshot renders to
//! Prometheus text exposition via [`EngineMetrics::to_prometheus`]. The
//! raw sample vectors are kept alongside the histograms so `report()`
//! still quotes exact interpolated percentiles ([`Summary`]), not
//! bucket-resolution estimates.

use crate::heuristics::tiles::KV_BLOCK;
use crate::obs::{CounterId, HistId, MetricsRegistry, PreemptClass};
use crate::util::stats::{Histogram, Summary};

use super::admission::AdmissionStats;
use super::kv_cache::PrefixCacheStats;
use super::lifecycle::{Priority, PRIORITY_CLASSES};

/// Timing of one completed request (all µs, relative to engine start).
#[derive(Debug, Clone, Default)]
pub struct RequestTiming {
    pub arrival_us: u64,
    pub scheduled_us: u64,
    pub first_token_us: u64,
    pub finished_us: u64,
    pub n_generated: usize,
}

impl RequestTiming {
    /// Queueing delay before the request entered the running set.
    pub fn queue_us(&self) -> u64 {
        self.scheduled_us.saturating_sub(self.arrival_us)
    }

    /// Time to first token from arrival.
    pub fn ttft_us(&self) -> u64 {
        self.first_token_us.saturating_sub(self.arrival_us)
    }

    /// Time per output token after the first (the paper's §3.1 target
    /// metric). Zero if fewer than 2 tokens.
    pub fn tpot_us(&self) -> f64 {
        if self.n_generated < 2 {
            return 0.0;
        }
        self.finished_us.saturating_sub(self.first_token_us) as f64
            / (self.n_generated - 1) as f64
    }

    /// End-to-end latency from arrival to completion, µs.
    pub fn e2e_us(&self) -> u64 {
        self.finished_us.saturating_sub(self.arrival_us)
    }
}

/// Per-class latency targets defining *goodput*: a naturally-finished
/// request's tokens count as goodput iff its TTFT and TPOT both landed
/// inside its class's targets; everything else is throughput the user
/// stopped waiting for. `None` on `EngineConfig::slo` disables the whole
/// accounting (and shedding), which is the byte-identity default.
#[derive(Debug, Clone, PartialEq)]
pub struct SloConfig {
    /// TTFT targets, µs, indexed by `Priority::index()`.
    pub ttft_us: [u64; PRIORITY_CLASSES],
    /// TPOT targets, µs/token, indexed by `Priority::index()`.
    pub tpot_us: [f64; PRIORITY_CLASSES],
    /// Shed queued requests whose slack went negative (they can no
    /// longer produce goodput) instead of letting them burn KV.
    pub shed_hopeless: bool,
}

impl Default for SloConfig {
    fn default() -> Self {
        // Anchored to the simulated H100: an uncontended request sees
        // ~50–90 µs prefill and ~12–30 µs/token decode, so these targets
        // are generous in the small and bind only under real overload —
        // interactive tight, standard medium, batch loose.
        SloConfig {
            ttft_us: [5_000, 20_000, 100_000],
            tpot_us: [100.0, 300.0, 2_000.0],
            shed_hopeless: true,
        }
    }
}

impl SloConfig {
    /// Did this finished request land inside its class's SLOs?
    // pallas-lint: no_alloc
    pub fn met(&self, timing: &RequestTiming, priority: Priority) -> bool {
        timing.ttft_us() <= self.ttft_us[priority.index()]
            && (timing.n_generated < 2 || timing.tpot_us() <= self.tpot_us[priority.index()])
    }
}

/// The nblk (KV blocks of 128) bucket edges for keyed occupancy
/// histograms: the guard region of the paper lives at `nblk <= 4`, so
/// the ladder is dense there and geometric above.
const NBLK_BUCKETS: &[usize] = &[1, 2, 4, 8, 16, 32];

/// Stable label for a bucket index (`NBLK_BUCKETS.len()` = overflow).
fn nblk_bucket_label(i: usize) -> String {
    if i < NBLK_BUCKETS.len() {
        format!("le{}", NBLK_BUCKETS[i])
    } else {
        "inf".to_string()
    }
}

/// Bucket index for an nblk value.
fn nblk_bucket(nblk: usize) -> usize {
    NBLK_BUCKETS.iter().position(|&b| nblk <= b).unwrap_or(NBLK_BUCKETS.len())
}

/// Registry handles for every pre-registered instrument. Created once in
/// `Default::default()`; hot-path updates index through these.
#[derive(Debug, Clone)]
struct Instruments {
    steps: CounterId,
    decode_steps: CounterId,
    mixed_steps: CounterId,
    tokens: CounterId,
    finished: CounterId,
    cancelled: CounterId,
    rejected_backpressure: CounterId,
    rejected_unschedulable: CounterId,
    prefix_hits: CounterId,
    prefix_lookups: CounterId,
    cow_forks: CounterId,
    preemptions: CounterId,
    resumes_swap: CounterId,
    resumes_recompute: CounterId,
    shed: CounterId,
    goodput_tokens: CounterId,
    /// `fa3_admission_rejected_total{class,reason}`:
    /// `[class][reason]`, reasons = backpressure, unschedulable, shed.
    admission_rejected: [[CounterId; 3]; PRIORITY_CLASSES],
    step_us: HistId,
    ttft_us: HistId,
    tpot_us: HistId,
    decode_occ: HistId,
    chunk_occ: HistId,
}

/// Rolling engine metrics.
#[derive(Debug)]
pub struct EngineMetrics {
    pub steps: usize,
    pub decode_steps: usize,
    /// Steps that interleaved chunked-prefill rows with decode rows (a
    /// subset of `steps`; zero under monolithic prefill).
    pub mixed_steps: usize,
    /// Rows executed per kind, summed over all steps: chunk/prefill rows
    /// ingest prompt tokens, decode rows emit one token each. The
    /// continuous-batching bench reports the interleave ratio from these.
    pub prefill_rows: usize,
    pub decode_rows: usize,
    pub prefill_calls: usize,
    pub tokens_generated: usize,
    pub requests_finished: usize,
    /// Requests cut short by cancellation, deadline, or shutdown (not
    /// counted in `requests_finished` and excluded from TTFT/TPOT).
    pub requests_cancelled: usize,
    /// Of the cancelled, those whose cause was a missed deadline.
    pub deadline_misses: usize,
    /// Submissions refused by the bounded admission queues.
    pub rejected_backpressure: usize,
    /// Submissions refused because they can never fit the KV budget.
    pub rejected_unschedulable: usize,
    /// Running requests evicted for a higher-priority blocked head.
    pub preemptions: usize,
    /// Preempted requests re-admitted from the host-transfer ledger.
    pub resumes_swap: usize,
    /// Preempted requests re-admitted via re-prefill + regeneration.
    pub resumes_recompute: usize,
    /// Queued requests dropped as hopeless by the SLO shed pass.
    pub requests_shed: usize,
    /// Tokens of naturally-finished requests that met their class's
    /// TTFT and TPOT SLOs (`SloConfig::met`) — the numerator of
    /// [`EngineMetrics::goodput_tok_s`]. Zero when no SLO is configured.
    pub goodput_tokens: usize,
    /// Naturally-finished requests that missed an SLO (their tokens
    /// count toward throughput but not goodput).
    pub slo_misses: usize,
    /// Admission-controller counters, mirrored by copy from
    /// `AdmissionController::stats` (the per-class rejection/shed splits
    /// feed `fa3_admission_rejected_total{class,reason}`).
    pub admission: AdmissionStats,
    /// Prefix-cache counters, mirrored by copy from the block manager
    /// every step (hit-rate, blocks saved, tokens whose prefill was
    /// skipped, COW forks — the single source of truth stays
    /// `BlockManager::prefix_stats`).
    pub prefix: PrefixCacheStats,
    step_latencies_us: Vec<f64>,
    tpots_us: Vec<f64>,
    ttfts_us: Vec<f64>,
    /// TTFT/TPOT samples split by admission class (index =
    /// `Priority::index()`), so mixed-load runs can gate interactive
    /// latency separately from batch-lane latency. The flat `ttfts_us` /
    /// `tpots_us` remain the all-classes aggregate.
    ttfts_class_us: [Vec<f64>; PRIORITY_CLASSES],
    tpots_class_us: [Vec<f64>; PRIORITY_CLASSES],
    /// Histogram of split counts chosen by the scheduler (index = splits).
    pub split_histogram: Vec<usize>,
    /// The instrument registry behind every distribution here. Rendered
    /// by [`EngineMetrics::to_prometheus`].
    registry: MetricsRegistry,
    ids: Instruments,
    /// Keyed decode-occupancy histograms (policy × h_kv × nblk bucket);
    /// index = nblk bucket. Empty until
    /// [`EngineMetrics::configure_occupancy_keys`] runs at engine build.
    occ_keyed: Vec<HistId>,
    pub wall_us: u64,
}

impl Default for EngineMetrics {
    fn default() -> EngineMetrics {
        let mut registry = MetricsRegistry::new();
        // Occupancy lives in [0, 1]: 20 linear buckets resolve 5% steps,
        // enough to separate the paper's 18%-vs-54% regimes cleanly.
        let occ_buckets = || Histogram::linear(0.0, 0.05, 20);
        // Latencies span µs to seconds: geometric from 50µs, 16 doublings
        // reaches ~1.6s.
        let us_buckets = || Histogram::exponential(50.0, 2.0, 16);
        let ids = Instruments {
            steps: registry.counter("fa3_steps_total", "Engine steps executed.", &[]),
            decode_steps: registry.counter(
                "fa3_decode_steps_total",
                "Steps that emitted at least one token.",
                &[],
            ),
            mixed_steps: registry.counter(
                "fa3_mixed_steps_total",
                "Steps interleaving chunked prefill with decode.",
                &[],
            ),
            tokens: registry.counter("fa3_tokens_generated_total", "Output tokens emitted.", &[]),
            finished: registry.counter(
                "fa3_requests_finished_total",
                "Requests run to natural completion.",
                &[],
            ),
            cancelled: registry.counter(
                "fa3_requests_cancelled_total",
                "Requests cut short (cancel, deadline, shutdown).",
                &[],
            ),
            rejected_backpressure: registry.counter(
                "fa3_rejected_total",
                "Submissions refused by admission control.",
                &[("reason", "backpressure")],
            ),
            rejected_unschedulable: registry.counter(
                "fa3_rejected_total",
                "Submissions refused by admission control.",
                &[("reason", "unschedulable")],
            ),
            prefix_hits: registry.counter(
                "fa3_prefix_cache_hits_total",
                "Prefix-cache block hits.",
                &[],
            ),
            prefix_lookups: registry.counter(
                "fa3_prefix_cache_lookups_total",
                "Prefix-cache block lookups.",
                &[],
            ),
            cow_forks: registry.counter(
                "fa3_kv_cow_forks_total",
                "Copy-on-write forks of shared KV blocks.",
                &[],
            ),
            preemptions: registry.counter(
                "fa3_preemptions_total",
                "Running requests evicted for a higher-priority blocked head.",
                &[],
            ),
            resumes_swap: registry.counter(
                "fa3_resumes_total",
                "Preempted requests re-admitted, by resume kind.",
                &[("kind", "swap")],
            ),
            resumes_recompute: registry.counter(
                "fa3_resumes_total",
                "Preempted requests re-admitted, by resume kind.",
                &[("kind", "recompute")],
            ),
            shed: registry.counter(
                "fa3_shed_total",
                "Queued requests dropped as hopeless (negative SLO slack).",
                &[],
            ),
            goodput_tokens: registry.counter(
                "fa3_goodput_tokens_total",
                "Tokens delivered within their class's TTFT/TPOT SLOs.",
                &[],
            ),
            admission_rejected: std::array::from_fn(|c| {
                let class = Priority::all()[c].name();
                std::array::from_fn(|r| {
                    let reason = ["backpressure", "unschedulable", "shed"][r];
                    registry.counter(
                        "fa3_admission_rejected_total",
                        "Submissions refused or shed by admission control, by class and reason.",
                        &[("class", class), ("reason", reason)],
                    )
                })
            }),
            step_us: registry.histogram(
                "fa3_step_latency_us",
                "Engine step latency, µs.",
                &[],
                us_buckets(),
            ),
            ttft_us: registry.histogram(
                "fa3_ttft_us",
                "Time to first token, µs.",
                &[],
                us_buckets(),
            ),
            tpot_us: registry.histogram(
                "fa3_tpot_us",
                "Time per output token, µs.",
                &[],
                us_buckets(),
            ),
            decode_occ: registry.histogram(
                "fa3_decode_occupancy",
                "Planned first-wave SM occupancy of decode waves.",
                &[],
                occ_buckets(),
            ),
            chunk_occ: registry.histogram(
                "fa3_chunk_occupancy",
                "Planned first-wave SM occupancy of chunk waves.",
                &[],
                occ_buckets(),
            ),
        };
        EngineMetrics {
            steps: 0,
            decode_steps: 0,
            mixed_steps: 0,
            prefill_rows: 0,
            decode_rows: 0,
            prefill_calls: 0,
            tokens_generated: 0,
            requests_finished: 0,
            requests_cancelled: 0,
            deadline_misses: 0,
            rejected_backpressure: 0,
            rejected_unschedulable: 0,
            preemptions: 0,
            resumes_swap: 0,
            resumes_recompute: 0,
            requests_shed: 0,
            goodput_tokens: 0,
            slo_misses: 0,
            admission: AdmissionStats::default(),
            prefix: PrefixCacheStats::default(),
            step_latencies_us: Vec::new(),
            tpots_us: Vec::new(),
            ttfts_us: Vec::new(),
            ttfts_class_us: Default::default(),
            tpots_class_us: Default::default(),
            split_histogram: Vec::new(),
            registry,
            ids,
            occ_keyed: Vec::new(),
            wall_us: 0,
        }
    }
}

impl EngineMetrics {
    /// Register the keyed decode-occupancy histograms for this engine's
    /// policy and (sharded) KV head count: one histogram per nblk bucket,
    /// labeled `policy × h_kv × nblk`. Engine build time only — after
    /// this, [`EngineMetrics::record_decode_occupancy_keyed`] is
    /// alloc-free. Idempotent per metrics instance.
    pub fn configure_occupancy_keys(&mut self, policy: &str, h_kv: usize) {
        if !self.occ_keyed.is_empty() {
            return;
        }
        let h_kv_label = h_kv.to_string();
        for i in 0..=NBLK_BUCKETS.len() {
            let label = nblk_bucket_label(i);
            let id = self.registry.histogram(
                "fa3_decode_occupancy_keyed",
                "Planned decode-wave SM occupancy by policy, KV heads, and nblk bucket.",
                &[("policy", policy), ("h_kv", &h_kv_label), ("nblk", &label)],
                Histogram::linear(0.0, 0.05, 20),
            );
            self.occ_keyed.push(id);
        }
    }

    /// Pre-reserve the aggregate sample buffers so a measured window of
    /// `steps` steps / `requests` completions records without growing any
    /// Vec. The allocation-guard test and the decode hot-path bench call
    /// this between warmup and their measured window; ordinary callers
    /// never need it (growth is amortized).
    pub fn reserve_capacity(&mut self, steps: usize, requests: usize) {
        self.step_latencies_us.reserve(steps);
        self.tpots_us.reserve(requests);
        self.ttfts_us.reserve(requests);
        for class in 0..PRIORITY_CLASSES {
            self.ttfts_class_us[class].reserve(requests);
            self.tpots_class_us[class].reserve(requests);
        }
        // Headroom for any split count a device can choose (caps are
        // <= 128 on every preset), so a first-seen split mid-window
        // resizes within capacity instead of reallocating.
        let want = 257usize;
        self.split_histogram.reserve(want.saturating_sub(self.split_histogram.len()));
    }

    /// Record one engine step (`decoded` = tokens emitted).
    // pallas-lint: no_alloc
    pub fn record_step(&mut self, latency_us: f64, decoded: usize) {
        self.steps += 1;
        if decoded > 0 {
            self.decode_steps += 1;
            self.tokens_generated += decoded;
        }
        self.step_latencies_us.push(latency_us);
        self.registry.observe(self.ids.step_us, latency_us);
    }

    /// Record the scheduler's split choice for one decode step.
    pub fn record_split(&mut self, num_splits: usize) {
        if self.split_histogram.len() <= num_splits {
            self.split_histogram.resize(num_splits + 1, 0);
        }
        self.split_histogram[num_splits] += 1;
    }

    /// Record the planned first-wave occupancy of one decode launch.
    // pallas-lint: no_alloc
    pub fn record_decode_occupancy(&mut self, occupancy: f64) {
        self.registry.observe(self.ids.decode_occ, occupancy);
    }

    /// Record a decode-wave occupancy under its shape key (`max_kv` is
    /// the longest KV length in the wave; the nblk bucket derives from
    /// it). Also feeds the unkeyed aggregate. No-op keying before
    /// [`EngineMetrics::configure_occupancy_keys`].
    // pallas-lint: no_alloc
    pub fn record_decode_occupancy_keyed(&mut self, occupancy: f64, max_kv: usize) {
        self.record_decode_occupancy(occupancy);
        if self.occ_keyed.is_empty() {
            return;
        }
        let nblk = max_kv.div_ceil(KV_BLOCK);
        let id = self.occ_keyed[nblk_bucket(nblk)];
        self.registry.observe(id, occupancy);
    }

    /// Mean planned SM occupancy across decode steps, if any ran.
    /// (Exactly one occupancy sample accompanies each decode step, so
    /// the histogram's mean *is* the per-decode-step mean.)
    pub fn mean_occupancy(&self) -> Option<f64> {
        self.registry.hist(self.ids.decode_occ).mean()
    }

    /// Record the row mix of one executed step (chunk/prefill rows vs
    /// decode rows).
    pub fn record_rows(&mut self, prefill: usize, decode: usize) {
        self.prefill_rows += prefill;
        self.decode_rows += decode;
    }

    /// Record the planned first-wave occupancy of one chunk wave
    /// (`q_len > 1` rows inside a mixed step).
    // pallas-lint: no_alloc
    pub fn record_chunk_wave(&mut self, occupancy: f64) {
        self.registry.observe(self.ids.chunk_occ, occupancy);
    }

    /// Mean planned SM occupancy across chunk waves, if any ran.
    pub fn mean_chunk_occupancy(&self) -> Option<f64> {
        self.registry.hist(self.ids.chunk_occ).mean()
    }

    /// Record a naturally-finished request's timing under its admission
    /// class.
    // pallas-lint: no_alloc
    pub fn record_finished(&mut self, timing: &RequestTiming, priority: Priority) {
        self.requests_finished += 1;
        if timing.n_generated >= 2 {
            self.tpots_us.push(timing.tpot_us());
            self.tpots_class_us[priority.index()].push(timing.tpot_us());
            self.registry.observe(self.ids.tpot_us, timing.tpot_us());
        }
        self.ttfts_us.push(timing.ttft_us() as f64);
        self.ttfts_class_us[priority.index()].push(timing.ttft_us() as f64);
        self.registry.observe(self.ids.ttft_us, timing.ttft_us() as f64);
    }

    /// Record a request cut short (cancel, shutdown, or deadline).
    pub fn record_cancelled(&mut self, deadline_miss: bool) {
        self.requests_cancelled += 1;
        if deadline_miss {
            self.deadline_misses += 1;
        }
    }

    /// Record one preemption of a running request, by resume kind.
    pub fn record_preemption(&mut self, kind: PreemptClass) {
        self.preemptions += 1;
        // The eventual resume is counted separately at re-admission;
        // the kind is recorded here only through the trace event.
        let _ = kind;
    }

    /// Record one re-admission of a preempted request.
    pub fn record_resume(&mut self, kind: PreemptClass) {
        match kind {
            PreemptClass::Swap => self.resumes_swap += 1,
            PreemptClass::Recompute => self.resumes_recompute += 1,
        }
    }

    /// Record one queued request shed as hopeless.
    pub fn record_shed(&mut self) {
        self.requests_shed += 1;
    }

    /// Record a naturally-finished request's SLO outcome: its tokens
    /// count as goodput iff it met its class's targets.
    // pallas-lint: no_alloc
    pub fn record_slo_outcome(&mut self, met: bool, n_tokens: usize) {
        if met {
            self.goodput_tokens += n_tokens;
        } else {
            self.slo_misses += 1;
        }
    }

    /// Step-latency distribution, if any step ran.
    pub fn step_latency(&self) -> Option<Summary> {
        (!self.step_latencies_us.is_empty()).then(|| Summary::of(&self.step_latencies_us))
    }

    /// Time-per-output-token distribution over finished requests.
    pub fn tpot(&self) -> Option<Summary> {
        (!self.tpots_us.is_empty()).then(|| Summary::of(&self.tpots_us))
    }

    /// Time-to-first-token distribution over finished requests.
    pub fn ttft(&self) -> Option<Summary> {
        (!self.ttfts_us.is_empty()).then(|| Summary::of(&self.ttfts_us))
    }

    /// TTFT distribution for one admission class.
    pub fn ttft_for(&self, priority: Priority) -> Option<Summary> {
        let samples = &self.ttfts_class_us[priority.index()];
        (!samples.is_empty()).then(|| Summary::of(samples))
    }

    /// TPOT distribution for one admission class.
    pub fn tpot_for(&self, priority: Priority) -> Option<Summary> {
        let samples = &self.tpots_class_us[priority.index()];
        (!samples.is_empty()).then(|| Summary::of(samples))
    }

    /// Raw TTFT samples (µs) over finished requests, all classes. The
    /// fleet report pools these across replicas so its percentiles are
    /// percentiles of the merged sample, not means of per-replica
    /// percentiles.
    pub fn ttft_samples(&self) -> &[f64] {
        &self.ttfts_us
    }

    /// Raw TPOT samples (µs) over finished requests, all classes.
    pub fn tpot_samples(&self) -> &[f64] {
        &self.tpots_us
    }

    /// Decode-occupancy sample count (the weight for pooling per-replica
    /// occupancy means at the fleet level).
    pub fn decode_occupancy_samples(&self) -> u64 {
        self.registry.hist(self.ids.decode_occ).count()
    }

    /// Generated tokens per second of wall time.
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_us == 0 {
            return 0.0;
        }
        self.tokens_generated as f64 / (self.wall_us as f64 / 1e6)
    }

    /// SLO-meeting tokens per second of wall time — the overload
    /// scheduler's objective (raw tok/s counts tokens nobody was still
    /// waiting for; goodput doesn't).
    pub fn goodput_tok_s(&self) -> f64 {
        if self.wall_us == 0 {
            return 0.0;
        }
        self.goodput_tokens as f64 / (self.wall_us as f64 / 1e6)
    }

    /// Prometheus text exposition of the full registry snapshot. The
    /// public counter fields stay the source of truth; this syncs them
    /// into their registry mirrors (mirror-by-copy) and renders.
    pub fn to_prometheus(&mut self) -> String {
        self.registry.set_counter(self.ids.steps, self.steps as u64);
        self.registry.set_counter(self.ids.decode_steps, self.decode_steps as u64);
        self.registry.set_counter(self.ids.mixed_steps, self.mixed_steps as u64);
        self.registry.set_counter(self.ids.tokens, self.tokens_generated as u64);
        self.registry.set_counter(self.ids.finished, self.requests_finished as u64);
        self.registry.set_counter(self.ids.cancelled, self.requests_cancelled as u64);
        self.registry
            .set_counter(self.ids.rejected_backpressure, self.rejected_backpressure as u64);
        self.registry
            .set_counter(self.ids.rejected_unschedulable, self.rejected_unschedulable as u64);
        self.registry.set_counter(self.ids.prefix_hits, self.prefix.hits as u64);
        self.registry.set_counter(self.ids.prefix_lookups, self.prefix.lookups as u64);
        self.registry.set_counter(self.ids.cow_forks, self.prefix.cow_forks as u64);
        self.registry.set_counter(self.ids.preemptions, self.preemptions as u64);
        self.registry.set_counter(self.ids.resumes_swap, self.resumes_swap as u64);
        self.registry.set_counter(self.ids.resumes_recompute, self.resumes_recompute as u64);
        self.registry.set_counter(self.ids.shed, self.requests_shed as u64);
        self.registry.set_counter(self.ids.goodput_tokens, self.goodput_tokens as u64);
        for c in 0..PRIORITY_CLASSES {
            let by_reason = [
                self.admission.rejected_backpressure_class[c],
                self.admission.rejected_unschedulable_class[c],
                self.admission.shed_class[c],
            ];
            for (r, &count) in by_reason.iter().enumerate() {
                self.registry.set_counter(self.ids.admission_rejected[c][r], count as u64);
            }
        }
        self.registry.render()
    }

    /// Multi-line human-readable report (the CLI's output).
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "steps={} (decode={} prefill_calls={}) tokens={} finished={}\n",
            self.steps, self.decode_steps, self.prefill_calls, self.tokens_generated, self.requests_finished
        ));
        if self.mixed_steps > 0 {
            out.push_str(&format!(
                "mixed steps={} rows: prefill={} decode={}\n",
                self.mixed_steps, self.prefill_rows, self.decode_rows
            ));
        }
        if self.requests_cancelled + self.rejected_backpressure + self.rejected_unschedulable > 0 {
            out.push_str(&format!(
                "cancelled={} (deadline={}) rejected: backpressure={} unschedulable={}\n",
                self.requests_cancelled,
                self.deadline_misses,
                self.rejected_backpressure,
                self.rejected_unschedulable
            ));
        }
        if let Some(s) = self.step_latency() {
            out.push_str(&format!(
                "step latency µs: mean={:.1} p50={:.1} p99={:.1}\n",
                s.mean, s.p50, s.p99
            ));
        }
        if let Some(s) = self.tpot() {
            out.push_str(&format!("TPOT µs: mean={:.1} p50={:.1} p99={:.1}\n", s.mean, s.p50, s.p99));
        }
        if let Some(s) = self.ttft() {
            out.push_str(&format!("TTFT µs: mean={:.1} p50={:.1} p99={:.1}\n", s.mean, s.p50, s.p99));
        }
        // Per-class split only when the run actually mixed classes.
        let classes_seen =
            Priority::all().iter().filter(|p| self.ttft_for(**p).is_some()).count();
        if classes_seen > 1 {
            for p in Priority::all() {
                if let Some(s) = self.ttft_for(p) {
                    out.push_str(&format!(
                        "  {} TTFT µs: mean={:.1} p50={:.1} p99={:.1}",
                        p.name(),
                        s.mean,
                        s.p50,
                        s.p99
                    ));
                    if let Some(t) = self.tpot_for(p) {
                        out.push_str(&format!("  TPOT p50={:.1}", t.p50));
                    }
                    out.push('\n');
                }
            }
        }
        if self.preemptions + self.requests_shed > 0 {
            out.push_str(&format!(
                "preemptions={} (resumed: swap={} recompute={}) shed={}\n",
                self.preemptions, self.resumes_swap, self.resumes_recompute, self.requests_shed
            ));
        }
        out.push_str(&format!("throughput: {:.1} tok/s\n", self.throughput_tok_s()));
        if self.goodput_tokens + self.slo_misses > 0 {
            out.push_str(&format!(
                "goodput: {} tok ({:.1} tok/s), slo misses={}\n",
                self.goodput_tokens,
                self.goodput_tok_s(),
                self.slo_misses
            ));
        }
        if self.prefix.lookups > 0 {
            out.push_str(&format!(
                "prefix cache: hit-rate {:.1}% ({}/{} blocks), saved {} blocks / {} tokens, \
                 cow forks {}, revived {}, evictions {}\n",
                self.prefix.hit_rate() * 100.0,
                self.prefix.hits,
                self.prefix.lookups,
                self.prefix.blocks_saved(),
                self.prefix.tokens_cached,
                self.prefix.cow_forks,
                self.prefix.revived,
                self.prefix.evictions
            ));
        }
        if let Some(occ) = self.mean_occupancy() {
            out.push_str(&format!("mean decode SM occupancy: {:.1}%\n", occ * 100.0));
        }
        if let Some(occ) = self.mean_chunk_occupancy() {
            out.push_str(&format!("mean chunk-wave SM occupancy: {:.1}%\n", occ * 100.0));
        }
        let hist: Vec<String> = self
            .split_histogram
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(s, c)| format!("s={s}:{c}"))
            .collect();
        if !hist.is_empty() {
            out.push_str(&format!("split histogram: {}\n", hist.join(" ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_derivations() {
        let t = RequestTiming {
            arrival_us: 100,
            scheduled_us: 150,
            first_token_us: 400,
            finished_us: 1400,
            n_generated: 11,
        };
        assert_eq!(t.queue_us(), 50);
        assert_eq!(t.ttft_us(), 300);
        assert_eq!(t.e2e_us(), 1300);
        assert!((t.tpot_us() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn tpot_needs_two_tokens() {
        let t = RequestTiming { n_generated: 1, ..Default::default() };
        assert_eq!(t.tpot_us(), 0.0);
    }

    #[test]
    fn occupancy_mean_over_decode_steps() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.mean_occupancy(), None);
        m.record_step(10.0, 1); // decode step
        m.record_decode_occupancy(0.02);
        m.record_step(12.0, 1);
        m.record_decode_occupancy(0.04);
        m.record_step(500.0, 0); // prefill step: no occupancy sample
        let occ = m.mean_occupancy().unwrap();
        assert!((occ - 0.03).abs() < 1e-12, "occ={occ}");
        assert!(m.report().contains("mean decode SM occupancy"));
    }

    #[test]
    fn per_class_latency_split() {
        let mut m = EngineMetrics::default();
        let timing = |arrival: u64, first: u64| RequestTiming {
            arrival_us: arrival,
            scheduled_us: arrival,
            first_token_us: first,
            finished_us: first + 900,
            n_generated: 10,
        };
        m.record_finished(&timing(0, 100), Priority::Interactive);
        m.record_finished(&timing(0, 5000), Priority::Batch);
        assert_eq!(m.requests_finished, 2);
        // Aggregate sees both; each class sees only its own.
        assert_eq!(m.ttft().unwrap().max, 5000.0);
        assert_eq!(m.ttft_for(Priority::Interactive).unwrap().max, 100.0);
        assert_eq!(m.ttft_for(Priority::Batch).unwrap().p50, 5000.0);
        assert_eq!(m.ttft_for(Priority::Standard), None);
        assert!((m.tpot_for(Priority::Interactive).unwrap().p50 - 100.0).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("interactive TTFT"), "{rep}");
        assert!(rep.contains("batch TTFT"), "{rep}");
        assert!(!rep.contains("standard TTFT"), "{rep}");
    }

    #[test]
    fn single_class_report_skips_the_split() {
        let mut m = EngineMetrics::default();
        let t = RequestTiming { first_token_us: 100, finished_us: 200, n_generated: 2, ..Default::default() };
        m.record_finished(&t, Priority::Standard);
        assert!(!m.report().contains("standard TTFT"), "{}", m.report());
    }

    #[test]
    fn chunk_waves_and_row_mix() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.mean_chunk_occupancy(), None);
        m.mixed_steps = 2;
        m.record_rows(3, 5);
        m.record_rows(1, 6);
        m.record_chunk_wave(0.5);
        m.record_chunk_wave(0.7);
        assert_eq!(m.prefill_rows, 4);
        assert_eq!(m.decode_rows, 11);
        let occ = m.mean_chunk_occupancy().unwrap();
        assert!((occ - 0.6).abs() < 1e-12, "occ={occ}");
        let rep = m.report();
        assert!(rep.contains("mixed steps=2 rows: prefill=4 decode=11"), "{rep}");
        assert!(rep.contains("mean chunk-wave SM occupancy: 60.0%"), "{rep}");
    }

    #[test]
    fn metrics_aggregate() {
        let mut m = EngineMetrics::default();
        m.record_step(10.0, 2);
        m.record_step(20.0, 0);
        m.record_split(1);
        m.record_split(3);
        m.record_split(3);
        m.wall_us = 1_000_000;
        assert_eq!(m.steps, 2);
        assert_eq!(m.decode_steps, 1);
        assert_eq!(m.tokens_generated, 2);
        assert_eq!(m.split_histogram[3], 2);
        assert!((m.throughput_tok_s() - 2.0).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("s=3:2"));
    }

    #[test]
    fn nblk_bucketing() {
        assert_eq!(nblk_bucket(1), 0);
        assert_eq!(nblk_bucket(2), 1);
        assert_eq!(nblk_bucket(3), 2);
        assert_eq!(nblk_bucket(4), 2);
        assert_eq!(nblk_bucket(5), 3);
        assert_eq!(nblk_bucket(33), NBLK_BUCKETS.len()); // overflow
        assert_eq!(nblk_bucket_label(0), "le1");
        assert_eq!(nblk_bucket_label(NBLK_BUCKETS.len()), "inf");
    }

    #[test]
    fn keyed_occupancy_lands_in_its_bucket() {
        let mut m = EngineMetrics::default();
        m.configure_occupancy_keys("sequence-aware", 1);
        // L_K = 512 → nblk = 4 → bucket le4; L_K = 4096 → nblk 32 → le32.
        m.record_decode_occupancy_keyed(0.18, 512);
        m.record_decode_occupancy_keyed(0.54, 4096);
        // Both also feed the unkeyed aggregate.
        assert!((m.mean_occupancy().unwrap() - 0.36).abs() < 1e-9);
        let text = m.to_prometheus();
        assert!(
            text.contains(
                "fa3_decode_occupancy_keyed_count{h_kv=\"1\",nblk=\"le4\",policy=\"sequence-aware\"} 1"
            ),
            "{text}"
        );
        assert!(
            text.contains(
                "fa3_decode_occupancy_keyed_count{h_kv=\"1\",nblk=\"le32\",policy=\"sequence-aware\"} 1"
            ),
            "{text}"
        );
    }

    #[test]
    fn keying_before_configuration_is_a_safe_noop() {
        let mut m = EngineMetrics::default();
        m.record_decode_occupancy_keyed(0.5, 512);
        assert_eq!(m.decode_occupancy_samples(), 1);
        assert!(!m.to_prometheus().contains("fa3_decode_occupancy_keyed"));
    }

    #[test]
    fn prometheus_mirrors_public_counters() {
        let mut m = EngineMetrics::default();
        m.record_step(10.0, 2);
        m.rejected_backpressure = 3;
        m.prefix.lookups = 10;
        m.prefix.hits = 7;
        let text = m.to_prometheus();
        assert!(text.contains("fa3_steps_total 1\n"), "{text}");
        assert!(text.contains("fa3_tokens_generated_total 2\n"), "{text}");
        assert!(text.contains("fa3_rejected_total{reason=\"backpressure\"} 3\n"), "{text}");
        assert!(text.contains("fa3_prefix_cache_hits_total 7\n"), "{text}");
        assert!(text.contains("# TYPE fa3_step_latency_us histogram"), "{text}");
        assert!(text.contains("fa3_step_latency_us_count 1\n"), "{text}");
    }

    #[test]
    fn slo_met_checks_both_targets_per_class() {
        let slo = SloConfig::default();
        let t = |ttft: u64, total: u64, n: usize| RequestTiming {
            arrival_us: 0,
            scheduled_us: 0,
            first_token_us: ttft,
            finished_us: ttft + total,
            n_generated: n,
        };
        // Interactive: TTFT ≤ 5 ms and TPOT ≤ 100 µs.
        assert!(slo.met(&t(4_000, 900, 10), Priority::Interactive));
        assert!(!slo.met(&t(6_000, 900, 10), Priority::Interactive), "ttft miss");
        assert!(!slo.met(&t(4_000, 9_000, 10), Priority::Interactive), "tpot miss");
        // The same timings pass under batch's looser targets.
        assert!(slo.met(&t(6_000, 9_000, 10), Priority::Batch));
        // Single-token requests have no TPOT to judge.
        assert!(slo.met(&t(4_000, 0, 1), Priority::Interactive));
    }

    #[test]
    fn goodput_counts_only_slo_met_tokens() {
        let mut m = EngineMetrics::default();
        m.wall_us = 1_000_000;
        m.record_slo_outcome(true, 30);
        m.record_slo_outcome(false, 50);
        assert_eq!(m.goodput_tokens, 30);
        assert_eq!(m.slo_misses, 1);
        assert!((m.goodput_tok_s() - 30.0).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("goodput: 30 tok (30.0 tok/s), slo misses=1"), "{rep}");
    }

    #[test]
    fn prometheus_exports_overload_families() {
        let mut m = EngineMetrics::default();
        m.record_preemption(PreemptClass::Swap);
        m.record_resume(PreemptClass::Swap);
        m.record_resume(PreemptClass::Recompute);
        m.record_shed();
        m.record_slo_outcome(true, 17);
        m.admission.rejected_backpressure_class[0] = 4;
        m.admission.shed_class[2] = 2;
        let text = m.to_prometheus();
        assert!(text.contains("fa3_preemptions_total 1\n"), "{text}");
        assert!(text.contains("fa3_resumes_total{kind=\"swap\"} 1\n"), "{text}");
        assert!(text.contains("fa3_resumes_total{kind=\"recompute\"} 1\n"), "{text}");
        assert!(text.contains("fa3_shed_total 1\n"), "{text}");
        assert!(text.contains("fa3_goodput_tokens_total 17\n"), "{text}");
        assert!(
            text.contains(
                "fa3_admission_rejected_total{class=\"interactive\",reason=\"backpressure\"} 4\n"
            ),
            "{text}"
        );
        assert!(
            text.contains("fa3_admission_rejected_total{class=\"batch\",reason=\"shed\"} 2\n"),
            "{text}"
        );
        assert!(
            text.contains(
                "fa3_admission_rejected_total{class=\"standard\",reason=\"unschedulable\"} 0\n"
            ),
            "{text}"
        );
        let rep = m.report();
        assert!(rep.contains("preemptions=1 (resumed: swap=1 recompute=1) shed=1"), "{rep}");
    }

    #[test]
    fn raw_samples_expose_for_fleet_pooling() {
        let mut m = EngineMetrics::default();
        let t = RequestTiming {
            first_token_us: 100,
            finished_us: 1000,
            n_generated: 10,
            ..Default::default()
        };
        m.record_finished(&t, Priority::Standard);
        assert_eq!(m.ttft_samples(), &[100.0]);
        assert_eq!(m.tpot_samples().len(), 1);
        m.record_decode_occupancy(0.5);
        assert_eq!(m.decode_occupancy_samples(), 1);
    }
}
