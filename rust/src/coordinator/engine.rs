//! The serving engine: a continuous-batching step loop over any
//! [`ExecutionBackend`].
//!
//! The engine owns the *request* side of serving — admission
//! ([`super::admission`]), lifecycle ([`super::lifecycle`]), slot
//! management ([`super::batcher`]), KV budgeting ([`super::kv_cache`]),
//! split planning ([`super::scheduler`]), and metrics — and delegates the
//! *execution* side entirely to the backend behind the trait. The per-step
//! flow is the vLLM shape:
//!
//! ```text
//! ingest arrivals → reap cancellations/deadlines → admit →
//!   prefill one batch | decode one batch (planner metadata) →
//!   stream tokens → retire
//! ```
//!
//! Engines are built only through [`EngineBuilder`]
//! (`Engine::builder(Box<dyn ExecutionBackend>)`); nothing here knows sim
//! from PJRT — backend differences are capability flags
//! ([`crate::backend::BackendCaps`]), most importantly `virtual_clock`,
//! which selects between integrating the backend's modeled time and
//! reading the wall clock.
//!
//! Step composition is delegated to [`crate::schedule::StepComposer`]
//! (DESIGN.md §Continuous batching): each step the engine projects the
//! running set into [`SlotView`]s and the composer picks this step's work
//! — whole-prompt prefill under the default monolithic policy (the legacy
//! prefill-first step, byte for byte), or bounded prefill chunks
//! interleaved with the decode wave under `ChunkPolicy::Bounded`.
//!
//! The step loop is the serving hot path, and it is **zero-allocation in
//! steady state** (DESIGN.md §Decode hot path): the per-step
//! `MixedStepPlan`, `StepBatch`, `StepOutcome`, and retirement list live
//! in a [`StepScratch`] reused across steps; the split decision rides the
//! scheduler's `PlanCursor`; and per-request buffers are pre-sized at
//! admission. `tests/alloc_guard.rs` holds a warmed-up decode step to
//! exactly zero heap allocations under a counting global allocator, and
//! `tests/alloc_guard_chunked.rs` does the same for a warm chunking
//! window.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::backend::{
    AttnGeometry, BackendCaps, ExecutionBackend, StepBatch, StepKind, StepOutcome, StepRow,
};
use crate::obs::{CursorOutcome, EventKind, FlightRecorder, Phase, PolicyId, PreemptClass, WaveKind};
use crate::planner::{CursorStats, Planner};
use crate::schedule::{
    deadline_slack_us, min_service_us, ttft_slack_us, ChunkSpan, MixedStepPlan, ScheduleConfig,
    SlotView, StepComposer,
};
use crate::sim::{recompute_estimate_us, HostTransferModel, Simulator, DECODE_STEP_ESTIMATE_US};

use super::admission::{AdmissionConfig, AdmissionController, AdmissionStats, SubmitError};
use super::batcher::{Batcher, BatcherConfig};
use super::kv_cache::{BlockManager, BlockManagerConfig};
use super::lifecycle::{
    handle_pair, CancelKind, RequestHandle, ResumeKind, ResumeState, StreamEvent, SubmitOptions,
    TrackedRequest,
};
use super::metrics::{EngineMetrics, RequestTiming, SloConfig};
use super::request::{FinishReason, FinishedRequest, Request, RequestId};
use super::scheduler::DecodeScheduler;

/// How a preemption victim's KV state comes back at re-admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ResumePolicy {
    /// Per victim: swap iff the modeled host round trip
    /// ([`HostTransferModel::round_trip_us`] over the blocks it holds) is
    /// cheaper than re-prefilling its prompt and regenerating its tokens
    /// ([`recompute_estimate_us`]).
    #[default]
    Auto,
    /// Always park KV on the host-transfer ledger.
    Swap,
    /// Always discard KV and recompute after re-admission.
    Recompute,
}

impl ResumePolicy {
    /// CLI-facing name.
    pub fn name(self) -> &'static str {
        match self {
            ResumePolicy::Auto => "auto",
            ResumePolicy::Swap => "swap",
            ResumePolicy::Recompute => "recompute",
        }
    }

    /// Parse a CLI flag value.
    pub fn parse(s: &str) -> Option<ResumePolicy> {
        match s {
            "auto" => Some(ResumePolicy::Auto),
            "swap" => Some(ResumePolicy::Swap),
            "recompute" => Some(ResumePolicy::Recompute),
            _ => None,
        }
    }
}

/// Priority preemption under KV/slot pressure (DESIGN.md §Overload
/// survival). Disabled by default: an engine with `enabled = false` is
/// byte-identical to the pre-preemption engine.
#[derive(Debug, Clone, PartialEq)]
pub struct PreemptionConfig {
    /// Master switch. Off = strict head-of-line blocking only.
    pub enabled: bool,
    /// Most victims evicted per engine step (bounds per-step eviction
    /// work and the KV churn a single overloaded step can cause).
    pub max_per_step: usize,
    /// How victims resume.
    pub resume: ResumePolicy,
    /// The modeled host-transfer costs behind swap decisions.
    pub transfer: HostTransferModel,
}

impl Default for PreemptionConfig {
    fn default() -> Self {
        PreemptionConfig {
            enabled: false,
            max_per_step: 1,
            resume: ResumePolicy::Auto,
            transfer: HostTransferModel::default(),
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone, Default)]
pub struct EngineConfig {
    pub batcher: BatcherConfig,
    pub blocks: BlockManagerConfig,
    pub admission: AdmissionConfig,
    /// Priority preemption of running requests. The default (disabled)
    /// preserves pre-PR behavior exactly.
    pub preemption: PreemptionConfig,
    /// Per-class TTFT/TPOT targets for goodput accounting and the
    /// hopeless-request shed pass. `None` (the default) disables both.
    pub slo: Option<SloConfig>,
    /// Step composition: chunked prefill + per-step token budget. The
    /// default ([`ScheduleConfig::default`], monolithic/unbounded) is
    /// byte-identical to the pre-composer engine.
    pub schedule: ScheduleConfig,
    /// Flight-recorder ring capacity in events (the CLI's
    /// `--trace-capacity`). 0 — the default — disables tracing entirely:
    /// the record path reduces to one branch and the step loop stays
    /// byte-identical to an untraced engine. When the ring fills, the
    /// oldest events are overwritten (most recent window wins) and a drop
    /// counter runs up; recording never blocks the step loop.
    pub trace_capacity: usize,
}

/// Builder: the only way to construct an [`Engine`]. The backend is
/// mandatory; geometry and split variants come from the backend's
/// topology when it has one (PJRT derives them from its manifest) and
/// must be supplied explicitly otherwise (sim).
pub struct EngineBuilder {
    backend: Box<dyn ExecutionBackend>,
    planner: Option<Planner>,
    geometry: Option<AttnGeometry>,
    available_splits: Option<Vec<usize>>,
    cfg: EngineConfig,
}

impl EngineBuilder {
    /// Select the split planner (default: sequence-aware on H100).
    pub fn planner(mut self, planner: Planner) -> EngineBuilder {
        self.planner = Some(planner);
        self
    }

    /// Attention geometry (required unless the backend's topology has it).
    pub fn geometry(mut self, geometry: AttnGeometry) -> EngineBuilder {
        self.geometry = Some(geometry);
        self
    }

    /// Split variants the scheduler may request (must contain 1).
    /// Overrides the backend topology's variants.
    pub fn available_splits(mut self, splits: Vec<usize>) -> EngineBuilder {
        self.available_splits = Some(splits);
        self
    }

    /// Set batcher, block-manager, and admission configuration.
    pub fn config(mut self, cfg: EngineConfig) -> EngineBuilder {
        self.cfg = cfg;
        self
    }

    /// Build the engine, deriving geometry/splits from the backend's topology when present.
    pub fn build(self) -> Result<Engine> {
        let topology = self.backend.topology();
        let geometry = self
            .geometry
            .or_else(|| topology.as_ref().map(|t| t.geometry))
            .context("no geometry: the backend has no topology and none was supplied")?;
        let available_splits = self
            .available_splits
            .or_else(|| {
                topology
                    .as_ref()
                    .map(|t| t.available_splits.clone())
                    .filter(|s| !s.is_empty())
            })
            .unwrap_or_else(|| vec![1]);
        let planner = self.planner.unwrap_or_else(Planner::sequence_aware);
        let scheduler = DecodeScheduler::new(planner, geometry, available_splits);
        let mut blocks_cfg = self.cfg.blocks.clone();
        blocks_cfg.max_seq = blocks_cfg.max_seq.min(geometry.max_seq);
        self.cfg.schedule.validate(self.cfg.batcher.max_batch)?;
        let caps = self.backend.caps();
        // Observability setup runs here, not on the hot path: the policy
        // name is interned into the recorder once and the keyed occupancy
        // histograms are registered for this engine's (policy, h_kv).
        let mut metrics = EngineMetrics::default();
        metrics.configure_occupancy_keys(scheduler.policy_name(), geometry.h_kv);
        let mut recorder = FlightRecorder::with_capacity(self.cfg.trace_capacity);
        let policy_id = recorder.intern_policy(scheduler.policy_name());
        Ok(Engine {
            caps,
            scheduler,
            composer: StepComposer::new(self.cfg.schedule),
            batcher: Batcher::new(self.cfg.batcher.clone()),
            admission: AdmissionController::new(self.cfg.admission.clone()),
            blocks: BlockManager::new(blocks_cfg),
            preemption: self.cfg.preemption.clone(),
            slo: self.cfg.slo.clone(),
            // Cost oracle for the swap-vs-recompute decision and the shed
            // pass's service lower bound — modeled costs, same anchors as
            // the sim backend, valid for wall-clock backends too (the
            // decision only needs relative magnitudes).
            cost_sim: Simulator::h100(),
            backend: self.backend,
            metrics,
            recorder,
            policy_id,
            started: Instant::now(),
            clock_us: 0.0,
            pending_arrivals: Vec::new(),
            finished: Vec::new(),
            scratch: StepScratch::default(),
        })
    }
}

/// Per-step scratch buffers the step loop reuses instead of reallocating
/// (the zero-allocation decode hot path). Each is `mem::take`n for the
/// duration of a step (an `Option`-style move, no allocation) and put
/// back, so `&mut self` methods can run while the buffers are borrowed.
/// `batch.rows` doubles as a row pool for mixed steps: chunk rows reuse
/// the prompt buffers of previous steps' rows instead of reallocating.
#[derive(Default)]
struct StepScratch {
    mixed: MixedStepPlan,
    batch: StepBatch,
    outcome: StepOutcome,
    to_retire: Vec<(usize, FinishReason)>,
}

/// The engine.
pub struct Engine {
    backend: Box<dyn ExecutionBackend>,
    caps: BackendCaps,
    scheduler: DecodeScheduler,
    composer: StepComposer,
    batcher: Batcher,
    admission: AdmissionController,
    blocks: BlockManager,
    /// Priority-preemption policy (disabled by default).
    preemption: PreemptionConfig,
    /// Goodput SLOs; `None` disables goodput accounting and shedding.
    slo: Option<SloConfig>,
    /// Modeled cost oracle for resume decisions and slack bounds.
    cost_sim: Simulator,
    pub metrics: EngineMetrics,
    /// Flight recorder: fixed-capacity event ring on the engine clock.
    /// Disabled (capacity 0) unless [`EngineConfig::trace_capacity`] set
    /// it; recording is a single branch when disabled and stays
    /// allocation-free when enabled.
    recorder: FlightRecorder,
    /// The scheduler's policy name interned into the recorder at build.
    policy_id: PolicyId,
    started: Instant,
    /// Virtual clock (µs) for virtual-clock backends.
    clock_us: f64,
    /// Open-loop arrivals not yet due (virtual clock): sorted by time.
    pending_arrivals: Vec<(u64, TrackedRequest)>,
    finished: Vec<FinishedRequest>,
    scratch: StepScratch,
}

impl Engine {
    /// Start building an engine over an execution backend — the only
    /// constructor.
    ///
    /// ```
    /// use fa3_split::backend::{AttnGeometry, SimBackend};
    /// use fa3_split::coordinator::{Engine, Request};
    /// use fa3_split::planner::Planner;
    ///
    /// let mut engine = Engine::builder(Box::new(SimBackend::h100()))
    ///     .planner(Planner::sequence_aware())
    ///     .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
    ///     .available_splits(vec![1, 3])
    ///     .build()
    ///     .unwrap();
    /// let handle = engine.submit(Request::new(1, vec![7; 64], 4)).unwrap();
    /// let done = engine.run_until_idle().unwrap();
    /// assert_eq!(done[0].tokens.len(), 4);
    /// assert_eq!(handle.drain_tokens(), done[0].tokens);
    /// ```
    pub fn builder(backend: Box<dyn ExecutionBackend>) -> EngineBuilder {
        EngineBuilder {
            backend,
            planner: None,
            geometry: None,
            available_splits: None,
            cfg: EngineConfig::default(),
        }
    }

    /// The split policy the scheduler plans with.
    pub fn policy_name(&self) -> &'static str {
        self.scheduler.policy_name()
    }

    /// The backend's capability flags.
    pub fn backend_caps(&self) -> BackendCaps {
        self.caps
    }

    /// The prefix-sharing KV block manager (read-only).
    pub fn block_manager(&self) -> &BlockManager {
        &self.blocks
    }

    /// Land a cross-pool KV handoff: import `tokens`' full prefix blocks
    /// into this engine's block manager as evictable cache entries (see
    /// [`BlockManager::import_prefix`]) and record the handoff on the
    /// flight recorder. `wire_us` is the modeled one-way interconnect
    /// time the blocks already paid — it prices the trace event, not the
    /// import (the fleet delays the continuation's arrival instead).
    ///
    /// The import is deliberately decoupled from admission: blocks park
    /// at refcount 0, so the continuation's later `submit_at` revives
    /// them as ordinary prefix hits and skips their prefill — and if
    /// memory pressure recycles them first, the continuation simply
    /// re-prefills (slower, never wrong). Returns the imported count.
    pub fn import_handoff(&mut self, request: RequestId, tokens: &[i32], wire_us: u64) -> usize {
        let imported = self.blocks.import_prefix(tokens);
        self.recorder.record(
            self.now_us(),
            EventKind::KvHandoff {
                request,
                blocks: imported as u32,
                wire_us: wire_us.min(u32::MAX as u64) as u32,
            },
        );
        imported
    }

    /// The step-composition policy this engine runs under.
    pub fn schedule(&self) -> &ScheduleConfig {
        self.composer.config()
    }

    /// Admission counters (accepted, rejected, reaped).
    pub fn admission_stats(&self) -> AdmissionStats {
        self.admission.stats
    }

    /// Hit/refill counters of the scheduler's plan cursors (the decode
    /// hot-path bench and the allocation-guard test read these to prove
    /// the steady state actually rode the cursor).
    pub fn cursor_stats(&self) -> CursorStats {
        self.scheduler.cursor_stats()
    }

    /// The flight recorder (read side: exporters, span reconstruction).
    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    /// Mutable recorder access (the fleet stamps each replica's index
    /// here before running, so merged traces keep one track per replica).
    pub fn recorder_mut(&mut self) -> &mut FlightRecorder {
        &mut self.recorder
    }

    /// Requests waiting in admission.
    pub fn waiting_len(&self) -> usize {
        self.admission.waiting_len()
    }

    /// Requests in the running set.
    pub fn running_len(&self) -> usize {
        self.batcher.running_len()
    }

    /// The engine clock: virtual µs for virtual-clock backends, wall µs
    /// since engine start otherwise. Public so external drivers (the
    /// cluster fleet) can interleave several engines on a shared timeline.
    pub fn now_us(&self) -> u64 {
        if self.caps.virtual_clock {
            self.clock_us as u64
        } else {
            self.started.elapsed().as_micros() as u64
        }
    }

    /// Open-loop arrivals submitted but not yet due on the virtual clock
    /// (part of a replica's queue depth from a router's point of view).
    pub fn pending_len(&self) -> usize {
        self.pending_arrivals.len()
    }

    // ------------------------------------------------------------------
    // Submission + lifecycle
    // ------------------------------------------------------------------

    /// Submit a request under default options ([`SubmitOptions`]).
    /// Returns a [`RequestHandle`] for streaming consumption and
    /// cancellation, or the explicit refusal
    /// ([`SubmitError::Backpressure`] when the class queue is full).
    pub fn submit(&mut self, req: Request) -> Result<RequestHandle, SubmitError> {
        self.submit_with(req, SubmitOptions::default())
    }

    /// Submit with a priority class and/or deadline.
    pub fn submit_with(
        &mut self,
        req: Request,
        opts: SubmitOptions,
    ) -> Result<RequestHandle, SubmitError> {
        let (handle, ticket) = handle_pair(req.id, &opts);
        self.submit_tracked(TrackedRequest { req, ticket, resume: None })?;
        Ok(handle)
    }

    /// Internal submission path shared by the sync API and the engine
    /// thread: stamps arrival, offers to admission, and on refusal emits
    /// the rejection on the request's stream before returning it.
    pub(crate) fn submit_tracked(&mut self, mut t: TrackedRequest) -> Result<(), SubmitError> {
        t.req.arrival_us = self.now_us();
        self.offer_tracked(t)
    }

    /// Offer without restamping `arrival_us` (open-loop arrivals keep the
    /// timestamp `submit_at` gave them).
    fn offer_tracked(&mut self, t: TrackedRequest) -> Result<(), SubmitError> {
        let id = t.req.id;
        let arrival_us = t.req.arrival_us;
        let class = t.ticket.priority.index() as u8;
        match self.admission.offer(t, &self.blocks) {
            Ok(()) => {
                // Stamped with the request's arrival time (not the offer
                // time), so span TTFT matches `RequestTiming` exactly even
                // for open-loop arrivals held in `pending_arrivals`.
                self.recorder
                    .record(arrival_us, EventKind::Lifecycle { request: id, phase: Phase::Queued });
                Ok(())
            }
            Err((t, err)) => {
                self.sync_rejection_counters();
                self.recorder.record(
                    self.now_us(),
                    EventKind::AdmissionReject {
                        class,
                        backpressure: matches!(err, SubmitError::Backpressure(_)),
                    },
                );
                t.ticket.sink.send(StreamEvent::Rejected(err));
                Err(err)
            }
        }
    }

    /// The admission controller's stats are the single source of truth for
    /// rejections; the engine-level metrics mirror them by copy (never by
    /// independent increments), so the two surfaces cannot skew.
    fn sync_rejection_counters(&mut self) {
        self.metrics.rejected_backpressure = self.admission.stats.rejected_backpressure;
        self.metrics.rejected_unschedulable = self.admission.stats.rejected_unschedulable;
        self.metrics.requests_shed = self.admission.stats.shed;
        self.metrics.admission = self.admission.stats;
    }

    /// Open-loop arrival (virtual-clock backends): the request becomes
    /// visible to admission once the virtual clock reaches `arrival_us`.
    /// This is the trace-replay path for load testing under Poisson
    /// traffic (workload::ChatWorkload::generate's arrival offsets).
    pub fn submit_at(
        &mut self,
        req: Request,
        arrival_us: u64,
    ) -> Result<RequestHandle, SubmitError> {
        self.submit_at_with(req, arrival_us, SubmitOptions::default())
    }

    /// Open-loop arrival with a priority class and/or deadline.
    pub fn submit_at_with(
        &mut self,
        mut req: Request,
        arrival_us: u64,
        opts: SubmitOptions,
    ) -> Result<RequestHandle, SubmitError> {
        assert!(
            self.caps.virtual_clock,
            "submit_at is a virtual-clock (simulated/replay backend) feature"
        );
        // Never-fitting requests are refused up front (through the
        // admission controller, so its stats stay authoritative); queue
        // capacity is checked when the arrival becomes due (the rejection
        // then arrives as a `StreamEvent::Rejected`).
        if let Err(err) = self.admission.check_schedulable(
            &req.prompt,
            req.max_new_tokens,
            opts.priority,
            &self.blocks,
        ) {
            self.sync_rejection_counters();
            self.recorder.record(
                self.now_us(),
                EventKind::AdmissionReject {
                    class: opts.priority.index() as u8,
                    backpressure: false,
                },
            );
            return Err(err);
        }
        req.arrival_us = arrival_us;
        let (handle, ticket) = handle_pair(req.id, &opts);
        let pos = self.pending_arrivals.partition_point(|(t, _)| *t <= arrival_us);
        self.pending_arrivals
            .insert(pos, (arrival_us, TrackedRequest { req, ticket, resume: None }));
        Ok(handle)
    }

    /// Move due open-loop arrivals into admission; if the engine is
    /// otherwise idle, fast-forward the virtual clock to the next arrival.
    fn ingest_arrivals(&mut self) {
        if self.pending_arrivals.is_empty() {
            return;
        }
        if self.batcher.is_empty() && self.admission.waiting_len() == 0 {
            let next = self.pending_arrivals[0].0;
            if (self.clock_us as u64) < next {
                self.clock_us = next as f64;
            }
        }
        let now = self.now_us();
        while let Some((t, _)) = self.pending_arrivals.first() {
            if *t > now {
                break;
            }
            let (_, tracked) = self.pending_arrivals.remove(0);
            // Ignore the error: the rejection already went out on the
            // request's stream and into the counters.
            let _ = self.offer_tracked(tracked);
        }
    }

    /// Cancel one request wherever it currently is (pending arrival,
    /// queued, or running). Takes effect at the next step boundary.
    /// Returns whether the request was found live.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(slot) = self.batcher.slot_of(id) {
            let r = self.batcher.running(slot).expect("slot_of said so");
            r.ticket.cancel.cancel(CancelKind::User);
            return true;
        }
        if self.admission.cancel(id, CancelKind::User) {
            return true;
        }
        if let Some((_, t)) = self.pending_arrivals.iter().find(|(_, t)| t.req.id == id) {
            t.ticket.cancel.cancel(CancelKind::User);
            return true;
        }
        false
    }

    /// Whether nothing is waiting, running, or pending arrival.
    pub fn is_idle(&self) -> bool {
        self.admission.waiting_len() == 0
            && self.batcher.is_empty()
            && self.pending_arrivals.is_empty()
    }

    /// Abort everything pending, queued, or running — a thin wrapper over
    /// the per-request cancellation primitive: every live request is
    /// marked with [`CancelKind::Shutdown`] and reaped through the same
    /// path a client cancel takes (blocks released, KV rows cleared,
    /// streams closed with `FinishReason::Aborted`). Returns the requests
    /// aborted by this call.
    pub fn abort_all(&mut self) -> Result<Vec<FinishedRequest>> {
        for (_, t) in &self.pending_arrivals {
            t.ticket.cancel.cancel(CancelKind::Shutdown);
        }
        self.admission.cancel_all(CancelKind::Shutdown);
        for slot in self.batcher.occupied_slots() {
            if let Some(r) = self.batcher.running(slot) {
                r.ticket.cancel.cancel(CancelKind::Shutdown);
            }
        }
        let before = self.finished.len();
        self.reap_cancellations()?;
        Ok(self.finished.split_off(before))
    }

    /// Retire cancelled/deadline-expired requests from every stage.
    fn reap_cancellations(&mut self) -> Result<()> {
        let now = self.now_us();
        // Pending open-loop arrivals (not yet offered).
        let mut i = 0;
        while i < self.pending_arrivals.len() {
            let (_, t) = &self.pending_arrivals[i];
            if t.ticket.past_deadline(now) {
                t.ticket.cancel.cancel(CancelKind::Deadline);
            }
            if t.ticket.cancel.is_cancelled() {
                let (_, t) = self.pending_arrivals.remove(i);
                self.finish_unstarted(t, now);
            } else {
                i += 1;
            }
        }
        // Queued.
        for t in self.admission.reap_cancelled(now) {
            self.finish_unstarted(t, now);
        }
        // Running: scan slots directly — this sweep runs every step, so it
        // must not collect an occupied-slot Vec (the old per-step
        // allocation this hot path no longer pays).
        for slot in 0..self.batcher.num_slots() {
            let kind = match self.batcher.running(slot) {
                None => None,
                Some(r) => {
                    if r.ticket.past_deadline(now) {
                        r.ticket.cancel.cancel(CancelKind::Deadline);
                    }
                    r.ticket.cancel.get()
                }
            };
            if let Some(kind) = kind {
                self.retire(slot, kind.finish_reason())?;
            }
        }
        Ok(())
    }

    /// Finish a request that never reached the running set.
    fn finish_unstarted(&mut self, t: TrackedRequest, now: u64) {
        let reason =
            t.ticket.cancel.get().map(CancelKind::finish_reason).unwrap_or(FinishReason::Aborted);
        self.metrics.record_cancelled(reason == FinishReason::DeadlineExceeded);
        self.recorder
            .record(now, EventKind::Lifecycle { request: t.req.id, phase: Phase::Cancelled });
        let fin = FinishedRequest {
            id: t.req.id,
            prompt_len: t.req.prompt.len(),
            tokens: Vec::new(),
            reason,
            priority: t.ticket.priority,
            timing: RequestTiming {
                arrival_us: t.req.arrival_us,
                finished_us: now,
                ..Default::default()
            },
        };
        t.ticket.sink.send(StreamEvent::Finished(fin.clone()));
        self.finished.push(fin);
    }

    /// Run until every submitted request completes; returns them in
    /// completion order.
    pub fn run_until_idle(&mut self) -> Result<Vec<FinishedRequest>> {
        while !self.is_idle() {
            self.step()?;
        }
        self.metrics.wall_us = self.now_us();
        Ok(std::mem::take(&mut self.finished))
    }

    /// Drain and return whatever finished since the last call.
    pub fn take_finished(&mut self) -> Vec<FinishedRequest> {
        std::mem::take(&mut self.finished)
    }

    // ------------------------------------------------------------------
    // The step loop
    // ------------------------------------------------------------------

    /// One engine step: ingest → reap → admit → compose (prefill chunks +
    /// decode wave) → execute → stream/retire. Steady-state decode
    /// performs no heap allocation: every per-step buffer comes from
    /// [`StepScratch`].
    // pallas-lint: no_alloc
    pub fn step(&mut self) -> Result<()> {
        if self.caps.virtual_clock {
            self.ingest_arrivals();
        }
        self.reap_cancellations()?;
        self.shed_hopeless();
        self.fast_forward_to_parked_resume();
        let now = self.now_us();
        if self.preemption.enabled {
            self.preempt_for_blocked_head(now)?;
        }
        let admitted = self.admission.admit(&mut self.batcher, &mut self.blocks, now);
        // Degenerate requests that are already complete on admission
        // (empty prompt + max_new_tokens = 0) appear in neither the
        // prefill nor the decode set — retire them now or they'd pin
        // their slot forever. Only freshly admitted rows can be trivially
        // done, so this costs nothing on ordinary steps.
        for id in admitted {
            if let Some(slot) = self.batcher.slot_of(id) {
                if self.recorder.enabled() {
                    let (cached, prompt_len) = self
                        .batcher
                        .running(slot)
                        .map(|r| (r.cached_prompt_tokens, r.req.prompt.len()))
                        .unwrap_or((0, 0));
                    let phase = Phase::Admitted { slot: slot as u32 };
                    self.recorder.record(now, EventKind::Lifecycle { request: id, phase });
                    self.recorder.record(
                        now,
                        EventKind::KvAdmit {
                            request: id,
                            slot: slot as u32,
                            cached_tokens: cached as u32,
                        },
                    );
                    if prompt_len > 0 {
                        self.recorder.record(
                            now,
                            EventKind::PrefixProbe {
                                request: id,
                                hit_tokens: cached as u32,
                                prompt_tokens: prompt_len as u32,
                            },
                        );
                    }
                }
                let resumed = self.batcher.running_mut(slot).and_then(|r| r.resumed.take());
                if let Some(kind) = resumed {
                    self.metrics.record_resume(kind);
                    self.recorder
                        .record(now, EventKind::Resume { request: id, slot: slot as u32, kind });
                    if matches!(kind, PreemptClass::Swap) {
                        // The first-token COW trigger has already passed
                        // for a resumed deep-decode row: fork any tail
                        // share re-admission armed, before its next write.
                        // A no-op when nothing is armed.
                        if self.blocks.cow_fork(id)? {
                            self.recorder.record(now, EventKind::KvCowFork { request: id });
                        }
                    }
                }
                if self.batcher.running(slot).is_some_and(|r| r.done()) {
                    self.retire(slot, FinishReason::Length)?;
                }
            }
        }
        // Take the plan scratch for the step (an Option-style move, no
        // allocation), compose it over the running set, and put it back
        // after — `step_with_mixed` needs `&mut self` while it's borrowed.
        let mut mixed = std::mem::take(&mut self.scratch.mixed);
        self.compose_step(&mut mixed);
        if self.recorder.enabled() && !mixed.is_empty() {
            self.recorder.record(
                now,
                EventKind::StepComposed {
                    class: mixed.step_class(),
                    chunk_rows: mixed.chunks.len() as u32,
                    decode_rows: mixed.decode_slots.len() as u32,
                    step_tokens: mixed.step_tokens() as u32,
                    kv_used_blocks: self.blocks.used_blocks() as u32,
                    queue_depth: self.admission.waiting_len() as u32,
                },
            );
        }
        let result = self.step_with_mixed(&mixed);
        self.scratch.mixed = mixed;
        // The block manager's prefix-cache counters are the single source
        // of truth; the metrics mirror them by copy (a Copy struct — no
        // allocation on the hot path), same discipline as the rejection
        // counters.
        let evicted_before = self.metrics.prefix.evictions;
        self.metrics.prefix = self.blocks.prefix_stats();
        let evicted = self.metrics.prefix.evictions.saturating_sub(evicted_before);
        if evicted > 0 {
            self.recorder
                .record(self.now_us(), EventKind::KvEvict { blocks: evicted as u32 });
        }
        result
    }

    /// Drop queued requests that can no longer produce goodput: negative
    /// deadline slack (no schedule lands them before their deadline) or —
    /// for never-admitted requests — negative TTFT slack against their
    /// class SLO. Gated on [`SloConfig::shed_hopeless`]; a shed request
    /// finishes `DeadlineExceeded` with a [`EventKind::Shed`] trace
    /// event. Cold path: the common nothing-hopeless case is one scan.
    fn shed_hopeless(&mut self) {
        let Some(slo) = &self.slo else { return };
        if !slo.shed_hopeless || self.admission.waiting_len() == 0 {
            return;
        }
        let ttft_targets = slo.ttft_us;
        let now = self.now_us();
        let sim = &self.cost_sim;
        let shed = self.admission.shed_where(|t| {
            // Conservative lower bound on remaining service: full prompt
            // prefill (the prefix cache can only make it cheaper) plus one
            // decode step per owed token.
            let prefill = sim.prefill_us(t.req.prompt.len());
            if let Some(deadline) = t.ticket.deadline_us {
                let min_service =
                    min_service_us(prefill, t.req.max_new_tokens, DECODE_STEP_ESTIMATE_US);
                if deadline_slack_us(deadline, now, min_service) < 0.0 {
                    return true;
                }
            }
            // TTFT slack applies only before the first token: a resumed
            // request already delivered tokens, so its TTFT is settled.
            t.resume.is_none()
                && ttft_slack_us(
                    t.req.arrival_us,
                    ttft_targets[t.priority().index()],
                    now,
                    prefill,
                ) < 0.0
        });
        for t in shed {
            self.recorder.record(
                now,
                EventKind::Shed {
                    request: t.req.id,
                    class: t.priority().index() as u8,
                    waited_us: now.saturating_sub(t.req.arrival_us) as u32,
                },
            );
            t.ticket.cancel.cancel(CancelKind::Deadline);
            self.finish_unstarted(t, now);
        }
        self.sync_rejection_counters();
    }

    /// When a virtual-clock engine's only runnable work is a swap-parked
    /// resume, advance the clock to the earlier of its ready time and the
    /// next open-loop arrival — without this, `run_until_idle` would spin
    /// forever on a frozen clock (time only advances via step outcomes).
    fn fast_forward_to_parked_resume(&mut self) {
        if !self.caps.virtual_clock || !self.batcher.is_empty() {
            return;
        }
        let now = self.now_us();
        let Some(ready) = self.admission.blocking_resume_ready_us(now) else { return };
        let target = match self.pending_arrivals.first() {
            Some(&(next, _)) => ready.min(next),
            None => ready,
        };
        if (self.clock_us as u64) < target {
            self.clock_us = target as f64;
        }
    }

    /// When the queue head of a higher class is blocked on capacity,
    /// evict running victims of strictly lower classes until the head
    /// fits, bounded by [`PreemptionConfig::max_per_step`]. Victim order:
    /// lowest priority class first, then fewest generated tokens (least
    /// sunk work), then most KV blocks held (frees the most).
    fn preempt_for_blocked_head(&mut self, now: u64) -> Result<()> {
        let Some(head_class) = self.admission.blocked_head_class(now) else { return Ok(()) };
        for _ in 0..self.preemption.max_per_step {
            let head_fits = {
                let Some(head) = self.admission.head_request(head_class) else { return Ok(()) };
                self.batcher.free_slot().is_some()
                    && self.blocks.can_admit_prompt(&head.req.prompt, head.req.max_new_tokens)
            };
            if head_fits {
                break;
            }
            let Some(slot) = self.pick_victim(head_class) else { break };
            self.preempt_slot(slot, now)?;
        }
        Ok(())
    }

    /// The slot to evict for a blocked head of `head_class`, if any
    /// running request belongs to a strictly lower class.
    fn pick_victim(&self, head_class: usize) -> Option<usize> {
        let mut best: Option<(usize, (usize, usize, usize))> = None;
        for slot in 0..self.batcher.num_slots() {
            let Some(r) = self.batcher.running(slot) else { continue };
            let class = r.ticket.priority.index();
            if class <= head_class {
                continue;
            }
            let blocks = self.blocks.blocks_held(r.req.id).unwrap_or(0);
            // Maximized lexicographically: lowest-priority class, then
            // fewest generated (inverted), then most blocks held.
            let key = (class, usize::MAX - r.generated.len(), blocks);
            if best.map_or(true, |(_, k)| key > k) {
                best = Some((slot, key));
            }
        }
        best.map(|(slot, _)| slot)
    }

    /// Evict one running request: release its KV blocks and backend row,
    /// decide how it resumes (swap vs recompute), and re-enqueue it at
    /// the head of its class carrying a [`ResumeState`]. The request's
    /// stream sees nothing — already-delivered tokens stand, and the
    /// resume path never re-sends an index.
    fn preempt_slot(&mut self, slot: usize, now: u64) -> Result<()> {
        let mut r = self.batcher.take(slot).context("preempt empty slot")?;
        let blocks_held = self.blocks.blocks_held(r.req.id).unwrap_or(0);
        self.blocks.release(r.req.id)?;
        self.backend.release_slot(slot)?;
        let kind = match self.preemption.resume {
            ResumePolicy::Swap => ResumeKind::Swapped {
                ready_at_us: now + self.preemption.transfer.round_trip_us(blocks_held) as u64,
            },
            ResumePolicy::Recompute => ResumeKind::Recompute,
            ResumePolicy::Auto => {
                let swap_us = self.preemption.transfer.round_trip_us(blocks_held);
                let recompute_us =
                    recompute_estimate_us(&self.cost_sim, r.req.prompt.len(), r.generated.len());
                if swap_us < recompute_us {
                    ResumeKind::Swapped { ready_at_us: now + swap_us as u64 }
                } else {
                    ResumeKind::Recompute
                }
            }
        };
        let tag = kind.tag();
        self.metrics.record_preemption(tag);
        self.recorder.record(
            now,
            EventKind::Preempt {
                request: r.req.id,
                slot: slot as u32,
                blocks: blocks_held as u32,
                kind: tag,
            },
        );
        let rs = ResumeState {
            generated: std::mem::take(&mut r.generated),
            prefilled: r.prefilled,
            emitted: r.emitted,
            first_token_us: r.first_token_us,
            scheduled_us: r.scheduled_us,
            kind,
        };
        self.admission.requeue_preempted(TrackedRequest {
            req: r.req,
            ticket: r.ticket,
            resume: Some(Box::new(rs)),
        });
        Ok(())
    }

    /// Project the running set into [`SlotView`]s and let the composer
    /// pick this step's work. Under the default monolithic policy the
    /// result is exactly [`Batcher::plan_into`]'s plan (chunks ↔
    /// prefill_slots), proven by the equivalence test in `batcher.rs`.
    // pallas-lint: no_alloc
    fn compose_step(&self, out: &mut MixedStepPlan) {
        let batcher = &self.batcher;
        let slots = (0..batcher.num_slots()).filter_map(move |slot| {
            batcher.running(slot).map(|r| SlotView {
                slot,
                prompt_len: r.req.prompt.len(),
                prefilled: r.prefilled,
                cached_tokens: r.cached_prompt_tokens,
                done: r.done(),
            })
        });
        self.composer.compose_into(slots, batcher.buckets(), out);
    }

    fn step_with_mixed(&mut self, mixed: &MixedStepPlan) -> Result<()> {
        if mixed.chunks.is_empty() {
            if mixed.decode_slots.is_empty() {
                return Ok(());
            }
            let bucket = mixed.decode_bucket.context("decode slots without a bucket")?;
            return self.run_decode(&mixed.decode_slots, bucket);
        }
        if self.composer.is_monolithic() {
            // Monolithic spans cover each remaining prompt whole, and
            // decode waits — the legacy prefill-first step, byte for byte.
            self.run_prefill(&mixed.chunks)
        } else {
            self.run_mixed(mixed)
        }
    }

    fn run_prefill(&mut self, spans: &[ChunkSpan]) -> Result<()> {
        let mut batch = std::mem::take(&mut self.scratch.batch);
        let mut outcome = std::mem::take(&mut self.scratch.outcome);
        let result = (|| {
            self.fill_prefill_batch(&mut batch, spans)?;
            let prepared = self.backend.prepare(&batch, None)?;
            self.backend.execute(&batch, &prepared, &mut outcome)?;
            self.apply_outcome(&outcome)
        })();
        self.metrics.record_rows(spans.len(), 0);
        self.scratch.batch = batch;
        self.scratch.outcome = outcome;
        result
    }

    /// One mixed step: every chunk row ingests its span, decode rows each
    /// emit a token under one shared launch plan. The decode wave is
    /// planned exactly as a pure-decode step of the same shape; the chunk
    /// wave gets its own `q_len > 1` decision (separate cursor) whose
    /// occupancy is reported via [`EngineMetrics::record_chunk_wave`].
    fn run_mixed(&mut self, mixed: &MixedStepPlan) -> Result<()> {
        let decode_decision = if mixed.decode_slots.is_empty() {
            None
        } else {
            let max_kv = mixed
                .decode_slots
                .iter()
                .map(|&s| self.batcher.running(s).map(|r| r.kv_len() + 1).unwrap_or(1))
                .max()
                .unwrap_or(1);
            let refills_before = self.scheduler.cursor_stats().refills;
            let d = self.scheduler.decide(mixed.decode_slots.len(), max_kv)?;
            self.metrics.record_split(d.plan.metadata.num_splits);
            self.metrics.record_decode_occupancy(d.plan.occupancy);
            self.metrics.record_decode_occupancy_keyed(d.plan.occupancy, max_kv);
            self.record_plan_decision(
                WaveKind::Decode,
                mixed.decode_slots.len(),
                max_kv,
                d.plan.metadata.num_splits,
                d.plan.occupancy,
                refills_before,
            );
            Some(d)
        };
        // The chunk wave's split decision: l_q = longest chunk, l_k = the
        // longest row's post-chunk context. Chunk rows are executed by the
        // backend's prefill path (no split-kernel launch yet), so only the
        // planned occupancy is recorded — the first q_len > 1 evidence the
        // heuristic produces.
        let l_q = mixed.chunks.iter().map(|c| c.len).max().unwrap_or(1);
        let max_ctx = mixed.chunks.iter().map(|c| c.end()).max().unwrap_or(1);
        let refills_before = self.scheduler.cursor_stats().refills;
        let wave = self.scheduler.decide_mixed(mixed.chunks.len(), l_q, max_ctx)?;
        self.metrics.record_chunk_wave(wave.plan.occupancy);
        self.record_plan_decision(
            WaveKind::Chunk,
            mixed.chunks.len(),
            max_ctx,
            wave.plan.metadata.num_splits,
            wave.plan.occupancy,
            refills_before,
        );
        let mut batch = std::mem::take(&mut self.scratch.batch);
        let mut outcome = std::mem::take(&mut self.scratch.outcome);
        let result = (|| {
            self.fill_mixed_batch(&mut batch, mixed)?;
            let plan = decode_decision.as_ref().map(|d| &d.plan);
            let prepared = self.backend.prepare(&batch, plan)?;
            self.backend.execute(&batch, &prepared, &mut outcome)?;
            self.apply_outcome(&outcome)
        })();
        self.metrics.mixed_steps += 1;
        self.metrics.record_rows(mixed.chunks.len(), mixed.decode_slots.len());
        self.scratch.batch = batch;
        self.scratch.outcome = outcome;
        result
    }

    // pallas-lint: no_alloc
    fn run_decode(&mut self, slots: &[usize], bucket: usize) -> Result<()> {
        // The scheduler sees the live batch shape: the longest row's KV
        // length (including the token being written this step).
        let max_kv = slots
            .iter()
            .map(|&s| self.batcher.running(s).map(|r| r.kv_len() + 1).unwrap_or(1))
            .max()
            .unwrap_or(1);
        let refills_before = self.scheduler.cursor_stats().refills;
        let decision = self.scheduler.decide(slots.len(), max_kv)?;
        self.metrics.record_split(decision.plan.metadata.num_splits);
        self.metrics.record_decode_occupancy(decision.plan.occupancy);
        self.metrics.record_decode_occupancy_keyed(decision.plan.occupancy, max_kv);
        self.record_plan_decision(
            WaveKind::Decode,
            slots.len(),
            max_kv,
            decision.plan.metadata.num_splits,
            decision.plan.occupancy,
            refills_before,
        );
        let mut batch = std::mem::take(&mut self.scratch.batch);
        let mut outcome = std::mem::take(&mut self.scratch.outcome);
        let result = (|| {
            self.fill_decode_batch(&mut batch, slots, bucket)?;
            let prepared = self.backend.prepare(&batch, Some(&decision.plan))?;
            self.backend.execute(&batch, &prepared, &mut outcome)?;
            self.apply_outcome(&outcome)
        })();
        self.metrics.record_rows(0, slots.len());
        self.scratch.batch = batch;
        self.scratch.outcome = outcome;
        result
    }

    /// Emit one [`EventKind::PlanDecision`]: the planner's split choice
    /// for a wave, with whether the scheduler's plan cursor served it from
    /// the pinned decision (`Hit`) or recomputed (`Refill` — the refill
    /// counter moved across the `decide` call).
    // pallas-lint: no_alloc
    fn record_plan_decision(
        &mut self,
        wave: WaveKind,
        batch: usize,
        max_kv: usize,
        num_splits: usize,
        occupancy: f64,
        refills_before: u64,
    ) {
        if !self.recorder.enabled() {
            return;
        }
        let cursor = if self.scheduler.cursor_stats().refills > refills_before {
            CursorOutcome::Refill
        } else {
            CursorOutcome::Hit
        };
        self.recorder.record(
            self.now_us(),
            EventKind::PlanDecision {
                wave,
                policy: self.policy_id,
                batch: batch as u32,
                max_kv: max_kv as u32,
                num_splits: num_splits as u32,
                occupancy: occupancy as f32,
                cursor,
            },
        );
    }

    fn fill_prefill_batch(&self, batch: &mut StepBatch, spans: &[ChunkSpan]) -> Result<()> {
        batch.kind = StepKind::Prefill;
        batch.bucket = self.batcher.max_batch();
        batch.rows.clear();
        for span in spans {
            let r = self.batcher.running(span.slot).context("prefill slot")?;
            batch.rows.push(StepRow {
                slot: span.slot,
                input_token: 0,
                position: r.prefilled,
                kv_len: r.kv_len(),
                prompt: r.req.prompt.clone(),
                cached_tokens: r.cached_prompt_tokens,
            });
        }
        Ok(())
    }

    /// Fill a [`StepKind::Mixed`] batch: decode rows first (they carry the
    /// launch plan's shape), then one row per chunk span. Rows are pooled —
    /// existing entries (and their prompt buffers) are overwritten in
    /// place, so a steady chunking window allocates nothing once warm.
    // pallas-lint: no_alloc
    fn fill_mixed_batch(&self, batch: &mut StepBatch, mixed: &MixedStepPlan) -> Result<()> {
        batch.kind = StepKind::Mixed;
        let n_rows = mixed.decode_slots.len() + mixed.chunks.len();
        batch.bucket = mixed.decode_bucket.unwrap_or(0).max(n_rows);
        // Pool growth is amortized; a warm window overwrites in place.
        batch.rows.resize_with(n_rows.max(batch.rows.len()), StepRow::default);
        let mut i = 0;
        for &slot in &mixed.decode_slots {
            let r = self.batcher.running(slot).context("mixed decode slot")?;
            let row = &mut batch.rows[i];
            row.slot = slot;
            row.input_token = *r.generated.last().unwrap_or(r.req.prompt.last().unwrap_or(&0));
            row.position = r.kv_len();
            row.kv_len = r.kv_len();
            row.prompt.clear();
            row.cached_tokens = 0;
            i += 1;
        }
        for span in &mixed.chunks {
            let r = self.batcher.running(span.slot).context("mixed chunk slot")?;
            let row = &mut batch.rows[i];
            row.slot = span.slot;
            row.input_token = 0;
            row.position = span.start;
            // Resident context the chunk attends over (including any
            // prefix-cache-shared blocks the first chunk skipped).
            row.kv_len = span.start;
            row.prompt.clear();
            row.prompt.extend_from_slice(&r.req.prompt[span.start..span.end()]);
            row.cached_tokens = 0;
            i += 1;
        }
        batch.rows.truncate(n_rows);
        Ok(())
    }

    // pallas-lint: no_alloc
    fn fill_decode_batch(&self, batch: &mut StepBatch, slots: &[usize], bucket: usize) -> Result<()> {
        batch.kind = StepKind::Decode;
        batch.bucket = bucket;
        batch.rows.clear();
        for &slot in slots {
            let r = self.batcher.running(slot).context("decode slot")?;
            // Next input token: last generated, or last prompt token
            // when none generated yet (the full prompt is ingested, so
            // continue from its final token).
            let input_token = *r.generated.last().unwrap_or(r.req.prompt.last().unwrap_or(&0));
            batch.rows.push(StepRow {
                slot,
                input_token,
                position: r.kv_len(),
                kv_len: r.kv_len(),
                // pallas-lint: allow(no_alloc): capacity-0 Vec::new never heap-allocates
                prompt: Vec::new(),
                cached_tokens: 0,
            });
        }
        Ok(())
    }

    /// Fold a step outcome back into request state: advance the clock,
    /// record prompt-ingestion progress, stream freshly decoded tokens,
    /// and retire rows that completed. The retirement list is scratch
    /// (`StepScratch::to_retire`) because borrowing rows out of the
    /// batcher and retiring them cannot overlap.
    // pallas-lint: no_alloc
    fn apply_outcome(&mut self, outcome: &StepOutcome) -> Result<()> {
        if self.caps.virtual_clock {
            self.clock_us += outcome.elapsed_us;
        }
        self.metrics.record_step(outcome.elapsed_us, outcome.tokens.len());
        self.metrics.prefill_calls += outcome.prefill_calls;
        let now = self.now_us();
        if self.recorder.enabled() {
            // Per-wave cost attribution (sim decomposes; wall-clock
            // backends report totals only, leaving these at 0).
            if outcome.decode_wave_us > 0.0 {
                self.recorder.record(
                    now,
                    EventKind::WaveCost {
                        wave: WaveKind::Decode,
                        rows: outcome.tokens.len() as u32,
                        elapsed_us: outcome.decode_wave_us as f32,
                    },
                );
            }
            if outcome.chunk_wave_us > 0.0 {
                self.recorder.record(
                    now,
                    EventKind::WaveCost {
                        wave: WaveKind::Chunk,
                        rows: outcome.prefilled.len() as u32,
                        elapsed_us: outcome.chunk_wave_us as f32,
                    },
                );
            }
        }

        self.scratch.to_retire.clear();
        for &(slot, prefilled) in &outcome.prefilled {
            let r = self.batcher.running_mut(slot).context("prefilled slot")?;
            let start = r.prefilled;
            let id = r.req.id;
            r.prefilled = prefilled;
            let finished_prompt = r.done();
            self.recorder.record(
                now,
                EventKind::ChunkIngested {
                    request: id,
                    slot: slot as u32,
                    start: start as u32,
                    len: prefilled.saturating_sub(start) as u32,
                },
            );
            if finished_prompt {
                // Degenerate max_new_tokens = 0: nothing to decode.
                self.scratch.to_retire.push((slot, FinishReason::Length));
            }
        }
        let max_seq = self.scheduler.geometry().max_seq;
        for &(slot, token) in &outcome.tokens {
            let r = self.batcher.running_mut(slot).context("decoded slot")?;
            r.generated.push(token);
            r.first_token_us.get_or_insert(now);
            // Stream only indices not yet delivered: a recompute-resume
            // regenerates history below `emitted`, and re-sending those
            // indices would duplicate the stream. In the never-preempted
            // case `emitted` always trails by exactly the one token just
            // pushed, so every token streams — unchanged behavior.
            let streamed = r.generated.len() > r.emitted;
            if streamed {
                r.ticket.sink.send(StreamEvent::Token {
                    token,
                    index: r.generated.len() - 1,
                    emitted_us: now,
                });
                r.emitted = r.generated.len();
            }
            let reason = if r.done() {
                Some(FinishReason::Length)
            } else if r.kv_len() + 1 > max_seq {
                Some(FinishReason::CacheFull)
            } else {
                None
            };
            // A request whose admission armed a copy-on-write tail share
            // writes into the shared block at its FIRST generated token:
            // fork now (copy, never mutate — DESIGN.md §Prefix sharing).
            // One branch per token; the fork itself runs once per request
            // and only when a tail was actually shared, so the
            // steady-state decode step stays allocation-free.
            let fork = r.generated.len() == 1;
            let id = r.req.id;
            if fork {
                // A recompute replay re-crosses index 0 with the stream's
                // first token long since delivered — the fork must still
                // run (re-admission may have armed a new tail share), but
                // the FirstToken lifecycle event must not repeat.
                if streamed {
                    self.recorder
                        .record(now, EventKind::Lifecycle { request: id, phase: Phase::FirstToken });
                }
                if self.blocks.cow_fork(id)? {
                    self.recorder.record(now, EventKind::KvCowFork { request: id });
                }
            }
            if let Some(reason) = reason {
                self.scratch.to_retire.push((slot, reason));
            }
        }
        for i in 0..self.scratch.to_retire.len() {
            let (slot, reason) = self.scratch.to_retire[i];
            self.retire(slot, reason)?;
        }
        self.scratch.to_retire.clear();
        Ok(())
    }

    /// Remove a request from its slot: release blocks, clear the backend's
    /// KV row, close the stream, account. Shared by natural completion and
    /// cancellation (the reason's `is_natural` picks the accounting).
    fn retire(&mut self, slot: usize, reason: FinishReason) -> Result<()> {
        let r = self.batcher.take(slot).context("retire empty slot")?;
        self.blocks.release(r.req.id)?;
        self.backend.release_slot(slot)?;
        let now = self.now_us();
        let timing = RequestTiming {
            arrival_us: r.req.arrival_us,
            scheduled_us: r.scheduled_us,
            first_token_us: r.first_token_us.unwrap_or(now),
            finished_us: now,
            n_generated: r.generated.len(),
        };
        let priority = r.ticket.priority;
        if reason.is_natural() {
            self.metrics.record_finished(&timing, priority);
            if let Some(slo) = &self.slo {
                let met = slo.met(&timing, priority);
                self.metrics.record_slo_outcome(met, timing.n_generated);
            }
            self.recorder.record(
                now,
                EventKind::Lifecycle {
                    request: r.req.id,
                    phase: Phase::Finished { n_generated: r.generated.len() as u32 },
                },
            );
        } else {
            self.metrics.record_cancelled(reason == FinishReason::DeadlineExceeded);
            self.recorder
                .record(now, EventKind::Lifecycle { request: r.req.id, phase: Phase::Cancelled });
        }
        let fin = FinishedRequest {
            id: r.req.id,
            prompt_len: r.req.prompt.len(),
            tokens: r.generated,
            reason,
            priority,
            timing,
        };
        r.ticket.sink.send(StreamEvent::Finished(fin.clone()));
        self.finished.push(fin);
        Ok(())
    }
}

// ----------------------------------------------------------------------
// Threaded server facade
// ----------------------------------------------------------------------

enum EngineMsg {
    Submit(TrackedRequest),
    Cancel(RequestId),
    AbortAll,
}

/// Handle to an engine running on its own thread (tokio is unavailable
/// offline; a dedicated thread + channels is the same architecture).
/// `submit` returns the same [`RequestHandle`] the synchronous API does;
/// [`EngineHandle::shutdown`] closes the submit side and *drains* every
/// in-flight request before returning, while [`EngineHandle::abort`]
/// cancels them all through the per-request primitive.
pub struct EngineHandle {
    tx: mpsc::Sender<EngineMsg>,
    /// Completion firehose (every finished request, any origin), kept
    /// alongside the per-request streams for engine-wide consumers.
    pub results: mpsc::Receiver<FinishedRequest>,
    join: Option<std::thread::JoinHandle<EngineMetrics>>,
}

impl EngineHandle {
    /// Spawn `engine` on a worker thread. The engine drains its queue,
    /// blocking when idle, until the sender is dropped.
    pub fn spawn(mut engine: Engine) -> EngineHandle {
        let (tx, rx) = mpsc::channel::<EngineMsg>();
        let (out_tx, out_rx) = mpsc::channel::<FinishedRequest>();
        let join = std::thread::spawn(move || {
            let handle_msg = |engine: &mut Engine, msg: EngineMsg,
                              out: &mpsc::Sender<FinishedRequest>| {
                match msg {
                    // Rejections already went out on the request's stream.
                    EngineMsg::Submit(t) => drop(engine.submit_tracked(t)),
                    EngineMsg::Cancel(id) => drop(engine.cancel(id)),
                    EngineMsg::AbortAll => match engine.abort_all() {
                        Ok(aborted) => {
                            for fin in aborted {
                                let _ = out.send(fin);
                            }
                        }
                        Err(e) => eprintln!("engine abort failed: {e:#}"),
                    },
                }
            };
            loop {
                // Pull everything currently queued.
                let mut disconnected = false;
                loop {
                    match rx.try_recv() {
                        Ok(msg) => handle_msg(&mut engine, msg, &out_tx),
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            disconnected = true;
                            break;
                        }
                    }
                }
                if engine.is_idle() {
                    if disconnected {
                        break;
                    }
                    // Block for the next message to avoid spinning.
                    match rx.recv() {
                        Ok(msg) => handle_msg(&mut engine, msg, &out_tx),
                        Err(_) => break,
                    }
                }
                if !engine.is_idle() {
                    if let Err(e) = engine.step() {
                        eprintln!("engine step failed: {e:#}");
                        break;
                    }
                }
                for fin in engine.take_finished() {
                    let _ = out_tx.send(fin);
                }
            }
            engine.metrics.wall_us = engine.now_us();
            engine.metrics
        });
        EngineHandle { tx, results: out_rx, join: Some(join) }
    }

    /// Submit a request; the returned handle streams its tokens.
    pub fn submit(&self, req: Request) -> Result<RequestHandle> {
        self.submit_with(req, SubmitOptions::default())
    }

    /// Submit with a priority class and/or deadline.
    pub fn submit_with(&self, req: Request, opts: SubmitOptions) -> Result<RequestHandle> {
        let (handle, ticket) = handle_pair(req.id, &opts);
        self.tx
            .send(EngineMsg::Submit(TrackedRequest { req, ticket, resume: None }))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        Ok(handle)
    }

    /// Cancel by id (equivalent to `RequestHandle::cancel`, for consumers
    /// that only kept the id).
    pub fn cancel(&self, id: RequestId) -> Result<()> {
        self.tx.send(EngineMsg::Cancel(id)).map_err(|_| anyhow::anyhow!("engine thread gone"))
    }

    /// Close the submit side and wait for the engine to DRAIN every
    /// in-flight request (graceful shutdown).
    pub fn shutdown(mut self) -> EngineMetrics {
        let EngineHandle { tx, join, .. } = &mut self;
        drop(std::mem::replace(tx, mpsc::channel().0));
        join.take().expect("joined once").join().expect("engine thread panicked")
    }

    /// Cancel everything in flight, then shut down.
    pub fn abort(mut self) -> EngineMetrics {
        let _ = self.tx.send(EngineMsg::AbortAll);
        let EngineHandle { tx, join, .. } = &mut self;
        drop(std::mem::replace(tx, mpsc::channel().0));
        join.take().expect("joined once").join().expect("engine thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SimBackend;
    use crate::coordinator::lifecycle::Priority;
    use crate::schedule::TokenBudget;

    fn sim_engine(planner: Planner) -> Engine {
        Engine::builder(Box::new(SimBackend::h100()))
            .planner(planner)
            .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
            .available_splits(vec![1, 3])
            .build()
            .unwrap()
    }

    fn chunked_engine(schedule: ScheduleConfig) -> Engine {
        let cfg = EngineConfig { schedule, ..EngineConfig::default() };
        Engine::builder(Box::new(SimBackend::h100()))
            .planner(Planner::sequence_aware())
            .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
            .available_splits(vec![1, 3])
            .config(cfg)
            .build()
            .unwrap()
    }

    #[test]
    fn simulated_generation_completes() {
        let mut e = sim_engine(Planner::sequence_aware());
        let handle = e.submit(Request::new(1, vec![7; 100], 20)).unwrap();
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 20);
        assert_eq!(done[0].reason, FinishReason::Length);
        assert!(e.metrics.tokens_generated >= 20);
        assert!(e.block_manager().check_invariants().is_ok());
        assert_eq!(e.block_manager().num_seqs(), 0, "all blocks released");
        // The handle streamed the same tokens the result carries.
        assert_eq!(handle.drain_tokens(), done[0].tokens);
    }

    #[test]
    fn patched_policy_faster_through_boundary_bucket() {
        // Decode from KV 400 to 512: inside nblk=4 bucket, tiles=1.
        let run = |planner: Planner| {
            let mut e = sim_engine(planner);
            e.submit(Request::new(1, vec![1; 400], 112)).unwrap();
            let done = e.run_until_idle().unwrap();
            (done[0].timing.tpot_us(), e.metrics.split_histogram.clone())
        };
        let (tpot_std, hist_std) = run(Planner::standard());
        let (tpot_pat, hist_pat) = run(Planner::sequence_aware());
        assert!(tpot_std / tpot_pat > 1.1, "std {tpot_std:.1} vs pat {tpot_pat:.1}");
        // Standard never splits here; patched uses s=3 throughout.
        assert!(hist_std.get(3).copied().unwrap_or(0) == 0);
        assert!(hist_pat[3] > 100);
    }

    #[test]
    fn shared_prefix_cuts_ttft_and_seeds_decode_at_full_lk() {
        let mut e = sim_engine(Planner::sequence_aware());
        let prompt = vec![7; 400]; // 25 full blocks, no tail
        e.submit(Request::new(1, prompt.clone(), 20)).unwrap();
        let first = e.run_until_idle().unwrap();
        // Identical prompt: the second request revives the freed prefix.
        e.submit(Request::new(2, prompt, 20)).unwrap();
        let second = e.run_until_idle().unwrap();
        assert_eq!(e.metrics.prefix.hits, 25, "{:?}", e.metrics.prefix);
        assert_eq!(e.metrics.prefix.tokens_cached, 400);
        // Prefill skipped the shared 400 tokens: strictly lower TTFT.
        assert!(
            second[0].timing.ttft_us() < first[0].timing.ttft_us(),
            "warm {} vs cold {}",
            second[0].timing.ttft_us(),
            first[0].timing.ttft_us()
        );
        // Decode seeded at the FULL shared L_K (401 on the first step):
        // the sequence-aware boundary override fires from token one, and
        // the token stream is byte-identical to the cold run (sharing
        // moves time, never content).
        assert!(e.metrics.split_histogram.get(3).copied().unwrap_or(0) > 0);
        assert_eq!(first[0].tokens, second[0].tokens);
        assert_eq!(e.block_manager().num_seqs(), 0);
        e.block_manager().check_invariants().unwrap();
    }

    #[test]
    fn chunked_prefill_matches_monolithic_token_streams() {
        // Chunking reshapes steps, never content: the sim's synthetic
        // token is position-pure, so any chunk schedule must reproduce the
        // monolithic run's tokens and finish reasons exactly.
        let run = |schedule: ScheduleConfig| {
            let mut e = chunked_engine(schedule);
            for (id, (plen, new)) in [(300usize, 8usize), (37, 12), (520, 8)].iter().enumerate() {
                e.submit(Request::new(id as u64, vec![1; *plen], *new)).unwrap();
            }
            let mut done = e.run_until_idle().unwrap();
            done.sort_by_key(|f| f.id);
            assert!(e.block_manager().check_invariants().is_ok());
            assert_eq!(e.block_manager().num_seqs(), 0);
            (done, e.metrics.mixed_steps, e.metrics.prefill_rows)
        };
        let (mono, mono_mixed, _) = run(ScheduleConfig::default());
        let (chunked, chunked_mixed, chunked_rows) =
            run(ScheduleConfig::bounded(64, TokenBudget::capped(256)));
        assert_eq!(mono_mixed, 0, "monolithic never composes a mixed step");
        assert!(chunked_mixed > 0, "bounded chunking must interleave");
        // 300/64 + 37/64 + 520/64 span ceilings = 5 + 1 + 9 chunk rows.
        assert!(chunked_rows >= 15, "rows={chunked_rows}");
        for (a, b) in mono.iter().zip(&chunked) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "id={}", a.id);
            assert_eq!(a.reason, b.reason);
        }
    }

    #[test]
    fn chunking_keeps_decode_flowing_during_long_prefill() {
        // A request mid-generation keeps emitting every step while a long
        // prompt ingests chunk by chunk — the head-of-line fix itself.
        let mut e = chunked_engine(ScheduleConfig::bounded(64, TokenBudget::unbounded()));
        e.submit(Request::new(1, vec![1; 20], 40)).unwrap();
        // Warm up until request 1 is decoding.
        while e.metrics.tokens_generated < 4 {
            e.step().unwrap();
        }
        e.submit(Request::new(2, vec![2; 600], 4)).unwrap();
        let mixed_before = e.metrics.mixed_steps;
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 2);
        // 600 tokens / 64-token chunks = 10 mixed steps, each carrying
        // request 1's decode row alongside the chunk.
        assert!(e.metrics.mixed_steps - mixed_before >= 10, "{}", e.metrics.mixed_steps);
        assert!(e.metrics.decode_rows > 0 && e.metrics.prefill_rows >= 10);
        let r1 = done.iter().find(|f| f.id == 1).unwrap();
        assert_eq!(r1.tokens.len(), 40);
        assert_eq!(e.block_manager().num_seqs(), 0);
    }

    #[test]
    fn token_budget_rations_chunks() {
        // Budget 64 with chunk 64 and a live decode row: the chunk shrinks
        // to budget − decode_rows = 63, so the 600-token prompt needs more
        // steps but still lands exactly.
        let mut e = chunked_engine(ScheduleConfig::bounded(64, TokenBudget::capped(64)));
        e.submit(Request::new(1, vec![1; 20], 64)).unwrap();
        while e.metrics.tokens_generated < 2 {
            e.step().unwrap();
        }
        e.submit(Request::new(2, vec![2; 600], 4)).unwrap();
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 2);
        let r2 = done.iter().find(|f| f.id == 2).unwrap();
        assert_eq!(r2.tokens.len(), 4);
        assert_eq!(r2.prompt_len, 600);
        e.block_manager().check_invariants().unwrap();
    }

    #[test]
    fn invalid_schedule_rejected_at_build() {
        let cfg = EngineConfig {
            schedule: ScheduleConfig::bounded(64, TokenBudget::capped(2)),
            ..EngineConfig::default()
        };
        // Budget 2 < max_batch 4: decode rows would be rationed.
        let err = Engine::builder(Box::new(SimBackend::h100()))
            .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
            .config(cfg)
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("decode"), "{err:#}");
    }

    #[test]
    fn cancel_mid_chunking_frees_all_blocks() {
        let mut e = chunked_engine(ScheduleConfig::bounded(32, TokenBudget::unbounded()));
        let free_before = e.block_manager().free_blocks();
        let victim = e.submit(Request::new(1, vec![3; 500], 8)).unwrap();
        // Step a few chunks in, then cancel mid-prefill.
        for _ in 0..4 {
            e.step().unwrap();
        }
        assert!(e.running_len() == 1, "still chunking");
        victim.cancel();
        let done = e.run_until_idle().unwrap();
        assert_eq!(done[0].reason, FinishReason::Cancelled);
        assert_eq!(e.block_manager().free_blocks(), free_before, "all chunk blocks freed");
        e.block_manager().check_invariants().unwrap();
    }

    #[test]
    fn batched_requests_share_steps() {
        let mut e = sim_engine(Planner::standard());
        for id in 0..4 {
            e.submit(Request::new(id, vec![1; 50], 10)).unwrap();
        }
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 4);
        // 4 requests x 10 tokens but batched: decode steps ≈ 10, not 40.
        assert!(e.metrics.decode_steps <= 12, "steps={}", e.metrics.decode_steps);
    }

    #[test]
    fn queueing_beyond_batch_capacity() {
        let mut e = sim_engine(Planner::standard());
        for id in 0..9 {
            e.submit(Request::new(id, vec![1; 10], 5)).unwrap();
        }
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 9);
        // Later requests must have queued (scheduled after arrival).
        let queued = done.iter().filter(|f| f.timing.queue_us() > 0).count();
        assert!(queued >= 1);
    }

    #[test]
    fn open_loop_arrivals_respect_virtual_time() {
        let mut e = sim_engine(Planner::sequence_aware());
        // Three arrivals spaced 10 ms apart on the virtual clock.
        for (i, t) in [0u64, 10_000, 20_000].iter().enumerate() {
            e.submit_at(Request::new(i as u64, vec![1; 40], 8), *t).unwrap();
        }
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 3);
        let mut by_id = done.clone();
        by_id.sort_by_key(|f| f.id);
        for (i, f) in by_id.iter().enumerate() {
            assert_eq!(f.timing.arrival_us, 10_000 * i as u64);
            // Scheduled at-or-after arrival on the virtual clock.
            assert!(f.timing.first_token_us >= f.timing.arrival_us);
        }
        // The clock fast-forwarded through idle gaps: total wall is at
        // least the last arrival.
        assert!(e.metrics.wall_us >= 20_000);
    }

    #[test]
    fn abort_all_releases_everything() {
        let mut e = sim_engine(Planner::standard());
        for id in 0..6 {
            e.submit(Request::new(id, vec![1; 50], 900)).unwrap();
        }
        // Run a few steps so some requests are mid-flight.
        for _ in 0..5 {
            e.step().unwrap();
        }
        let aborted = e.abort_all().unwrap();
        assert_eq!(aborted.len(), 6);
        assert!(aborted.iter().all(|f| f.reason == FinishReason::Aborted));
        assert!(e.is_idle());
        assert!(e.block_manager().check_invariants().is_ok());
        assert_eq!(e.block_manager().num_seqs(), 0);
        assert_eq!(e.metrics.requests_cancelled, 6);
    }

    #[test]
    fn cancel_mid_flight_frees_the_slot() {
        let mut e = sim_engine(Planner::standard());
        let victim = e.submit(Request::new(1, vec![1; 50], 900)).unwrap();
        e.submit(Request::new(2, vec![1; 50], 8)).unwrap();
        for _ in 0..5 {
            e.step().unwrap();
        }
        victim.cancel();
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 2);
        let v = done.iter().find(|f| f.id == 1).unwrap();
        assert_eq!(v.reason, FinishReason::Cancelled);
        let other = done.iter().find(|f| f.id == 2).unwrap();
        assert_eq!(other.reason, FinishReason::Length);
        assert_eq!(e.block_manager().num_seqs(), 0);
        // The victim's stream ended with the terminal event.
        assert!(matches!(victim.wait().finished(), Some(f) if f.reason == FinishReason::Cancelled));
    }

    #[test]
    fn deadline_cuts_a_request_short() {
        let mut e = sim_engine(Planner::standard());
        // 1 ms deadline on the virtual clock, but the request wants 800
        // tokens — it must come back DeadlineExceeded with partial output.
        let h = e
            .submit_with(
                Request::new(1, vec![1; 100], 800),
                SubmitOptions::default().deadline_us(1_000),
            )
            .unwrap();
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].reason, FinishReason::DeadlineExceeded);
        assert!(done[0].tokens.len() < 800);
        assert_eq!(e.metrics.deadline_misses, 1);
        drop(h);
    }

    #[test]
    fn degenerate_already_done_request_retires_immediately() {
        // Empty prompt + max_new_tokens = 0 is complete the moment it is
        // admitted: it must retire (Length) instead of pinning its slot
        // and spinning run_until_idle forever.
        let mut e = sim_engine(Planner::standard());
        e.submit(Request::new(1, Vec::new(), 0)).unwrap();
        e.submit(Request::new(2, vec![1; 10], 3)).unwrap();
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 2);
        let degenerate = done.iter().find(|f| f.id == 1).unwrap();
        assert_eq!(degenerate.reason, FinishReason::Length);
        assert!(degenerate.tokens.is_empty());
        assert_eq!(e.block_manager().num_seqs(), 0);
    }

    #[test]
    fn oversized_request_rejected_up_front() {
        let mut e = sim_engine(Planner::sequence_aware());
        // max_seq is 1024: this can never be admitted — explicit refusal
        // instead of wedging the queue head (the seed's behavior).
        let err = e.submit(Request::new(0, vec![1; 1000], 500)).unwrap_err();
        assert!(matches!(err, SubmitError::Unschedulable { .. }));
        // The engine stays serviceable.
        e.submit(Request::new(1, vec![1; 10], 4)).unwrap();
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(e.metrics.rejected_unschedulable, 1);
    }

    #[test]
    fn backpressure_when_the_class_queue_is_full() {
        let mut cfg = EngineConfig::default();
        cfg.admission.queue_capacity = 2;
        let mut e = Engine::builder(Box::new(SimBackend::h100()))
            .planner(Planner::standard())
            .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
            .available_splits(vec![1, 3])
            .config(cfg)
            .build()
            .unwrap();
        for id in 0..2 {
            e.submit(Request::new(id, vec![1; 10], 4)).unwrap();
        }
        let err = e.submit(Request::new(9, vec![1; 10], 4)).unwrap_err();
        match err {
            SubmitError::Backpressure(bp) => {
                assert_eq!(bp.capacity, 2);
                assert_eq!(bp.priority, Priority::Standard);
            }
            other => panic!("expected backpressure, got {other:?}"),
        }
        assert_eq!(e.metrics.rejected_backpressure, 1);
        // The queued ones still complete.
        assert_eq!(e.run_until_idle().unwrap().len(), 2);
    }

    #[test]
    fn threaded_handle_round_trip() {
        let e = sim_engine(Planner::sequence_aware());
        let handle = EngineHandle::spawn(e);
        let mut request_handles = Vec::new();
        for id in 0..3 {
            request_handles.push(handle.submit(Request::new(id, vec![2; 64], 8)).unwrap());
        }
        let mut got = 0;
        while got < 3 {
            if handle.results.recv_timeout(std::time::Duration::from_secs(10)).is_ok() {
                got += 1;
            } else {
                panic!("timed out waiting for results");
            }
        }
        // Each per-request stream carries its 8 tokens + terminal event.
        for h in request_handles {
            let fin = h.wait().finished().expect("stream finished");
            assert_eq!(fin.tokens.len(), 8);
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests_finished, 3);
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        let e = sim_engine(Planner::standard());
        let handle = EngineHandle::spawn(e);
        let hs: Vec<_> = (0..4)
            .map(|id| handle.submit(Request::new(id, vec![1; 40], 16)).unwrap())
            .collect();
        // Shut down immediately: the engine must finish all 4, not drop them.
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests_finished, 4);
        assert_eq!(metrics.requests_cancelled, 0);
        for h in hs {
            let fin = h.wait().finished().expect("drained to completion");
            assert_eq!(fin.reason, FinishReason::Length);
        }
    }

    #[test]
    fn abort_cancels_in_flight_requests() {
        let e = sim_engine(Planner::standard());
        let handle = EngineHandle::spawn(e);
        let hs: Vec<_> = (0..4)
            .map(|id| handle.submit(Request::new(id, vec![1; 40], 900)).unwrap())
            .collect();
        let metrics = handle.abort();
        assert_eq!(metrics.requests_finished + metrics.requests_cancelled, 4);
        assert!(metrics.requests_cancelled >= 1, "abort should cut long requests short");
        for h in hs {
            assert!(h.wait().finished().is_some(), "every stream gets a terminal event");
        }
    }
}
