//! The serving engine: continuous-batching decode loop over a backend.
//!
//! Two backends share the same scheduler/batcher/cache machinery:
//!
//! * **Pjrt** — real execution of the AOT artifacts on the CPU PJRT
//!   client: true logits, true KV caches, wall-clock timing. This is the
//!   end-to-end path (examples/serve_decode.rs).
//! * **Simulated** — the H100 latency model with a virtual clock: no
//!   numerics, but faithful *timing* under each split policy. This is how
//!   serving-level results are projected onto the paper's hardware
//!   (DESIGN.md §Substitutions), and it's what the A/B serving bench uses.
//!
//! Either way the per-step flow is the vLLM shape: admit → prefill →
//! decode(batch bucket, split metadata) → sample → retire.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::planner::Planner;
use crate::runtime::{HostTensor, Registry};
use crate::sim::Simulator;

use super::batcher::{Batcher, BatcherConfig};
use super::kv_cache::{BlockManager, BlockManagerConfig};
use super::metrics::{EngineMetrics, RequestTiming};
use super::request::{FinishReason, FinishedRequest, Request, RunningRequest};
use super::scheduler::{scheduler_from_manifest, AttnGeometry, DecodeScheduler};

/// Execution backend.
pub enum EngineBackend {
    /// Real PJRT execution of the AOT artifacts.
    Pjrt(Arc<Registry>),
    /// H100 latency simulation (virtual clock, synthetic tokens).
    Simulated(Simulator),
}

/// Engine configuration.
pub struct EngineConfig {
    pub batcher: BatcherConfig,
    pub blocks: BlockManagerConfig,
    /// Per-step framework overhead added in simulated mode, µs (sampler,
    /// scheduler, python-free launch path — small by construction).
    pub sim_framework_overhead_us: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batcher: BatcherConfig::default(),
            blocks: BlockManagerConfig::default(),
            sim_framework_overhead_us: 2.0,
        }
    }
}

/// Dense KV cache pair sized for the largest batch bucket.
struct CacheStore {
    n_layers: usize,
    max_batch: usize,
    max_seq: usize,
    h_kv: usize,
    d: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl CacheStore {
    fn new(n_layers: usize, max_batch: usize, max_seq: usize, h_kv: usize, d: usize) -> CacheStore {
        let n = n_layers * max_batch * max_seq * h_kv * d;
        CacheStore { n_layers, max_batch, max_seq, h_kv, d, k: vec![0.0; n], v: vec![0.0; n] }
    }

    fn row_elems(&self) -> usize {
        self.max_seq * self.h_kv * self.d
    }

    fn layer_stride(&self) -> usize {
        self.max_batch * self.row_elems()
    }

    /// True when `slots` are exactly rows 0..len in order AND the bucket
    /// width matches the store: gather/scatter degenerate to one straight
    /// memcpy of the whole store (§Perf opt-2 — the steady-state case for
    /// a full batch, which is when the copies are largest).
    fn contiguous_full(&self, slots: &[usize], bucket: usize) -> bool {
        bucket == self.max_batch && slots.len() == bucket
            && slots.iter().enumerate().all(|(i, &s)| i == s)
    }

    /// Gather `slots` rows into bucket-shaped tensors (L, b, S, H, D).
    fn gather(&self, slots: &[usize], bucket: usize) -> (HostTensor, HostTensor) {
        assert!(slots.len() <= bucket);
        let shape = [self.n_layers, bucket, self.max_seq, self.h_kv, self.d];
        if self.contiguous_full(slots, bucket) {
            return (
                HostTensor::f32(&shape, self.k.clone()).unwrap(),
                HostTensor::f32(&shape, self.v.clone()).unwrap(),
            );
        }
        let row = self.row_elems();
        let mut k = vec![0.0f32; shape.iter().product()];
        let mut v = vec![0.0f32; shape.iter().product()];
        for l in 0..self.n_layers {
            for (bi, &slot) in slots.iter().enumerate() {
                let src = l * self.layer_stride() + slot * row;
                let dst = (l * bucket + bi) * row;
                k[dst..dst + row].copy_from_slice(&self.k[src..src + row]);
                v[dst..dst + row].copy_from_slice(&self.v[src..src + row]);
            }
        }
        (
            HostTensor::f32(&shape, k).unwrap(),
            HostTensor::f32(&shape, v).unwrap(),
        )
    }

    /// Scatter bucket-shaped tensors back into `slots` rows. For the
    /// contiguous-full case the returned tensors REPLACE the store's
    /// backing vectors (move, no copy).
    fn scatter(&mut self, slots: &[usize], k: &HostTensor, v: &HostTensor) {
        let bucket = k.shape()[1];
        let kd = k.as_f32().unwrap();
        let vd = v.as_f32().unwrap();
        if self.contiguous_full(slots, bucket) {
            self.k.copy_from_slice(kd);
            self.v.copy_from_slice(vd);
            return;
        }
        let row = self.row_elems();
        for l in 0..self.n_layers {
            for (bi, &slot) in slots.iter().enumerate() {
                let dst = l * self.layer_stride() + slot * row;
                let src = (l * bucket + bi) * row;
                self.k[dst..dst + row].copy_from_slice(&kd[src..src + row]);
                self.v[dst..dst + row].copy_from_slice(&vd[src..src + row]);
            }
        }
    }

    fn clear_row(&mut self, slot: usize) {
        let row = self.row_elems();
        for l in 0..self.n_layers {
            let at = l * self.layer_stride() + slot * row;
            self.k[at..at + row].fill(0.0);
            self.v[at..at + row].fill(0.0);
        }
    }
}

/// The engine.
pub struct Engine {
    backend: EngineBackend,
    scheduler: DecodeScheduler,
    batcher: Batcher,
    blocks: BlockManager,
    pub metrics: EngineMetrics,
    cache: Option<CacheStore>,
    vocab: usize,
    started: Instant,
    /// Virtual clock (µs) for the simulated backend.
    sim_clock_us: f64,
    sim_overhead_us: f64,
    /// Open-loop arrivals not yet due (simulated backend): sorted by time.
    pending_arrivals: Vec<(u64, Request)>,
    finished: Vec<FinishedRequest>,
}

impl Engine {
    /// Real-execution engine over loaded artifacts.
    pub fn with_pjrt(
        registry: Arc<Registry>,
        planner: Planner,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        let scheduler = scheduler_from_manifest(&registry.manifest, planner)?;
        let model = registry.manifest.model.as_ref().context("no model block")?;
        let g = scheduler.geometry();
        let cache = CacheStore::new(
            model.config.n_layers,
            cfg.batcher.max_batch,
            g.max_seq,
            g.h_kv,
            g.d,
        );
        let vocab = model.config.vocab;
        let mut blocks_cfg = cfg.blocks.clone();
        blocks_cfg.max_seq = blocks_cfg.max_seq.min(g.max_seq);
        Ok(Engine {
            backend: EngineBackend::Pjrt(registry),
            scheduler,
            batcher: Batcher::new(cfg.batcher.clone()),
            blocks: BlockManager::new(blocks_cfg),
            metrics: EngineMetrics::default(),
            cache: Some(cache),
            vocab,
            started: Instant::now(),
            sim_clock_us: 0.0,
            sim_overhead_us: cfg.sim_framework_overhead_us,
            pending_arrivals: Vec::new(),
            finished: Vec::new(),
        })
    }

    /// Simulated engine: H100 latency model, synthetic tokens.
    pub fn with_simulator(
        sim: Simulator,
        planner: Planner,
        geometry: AttnGeometry,
        available_splits: Vec<usize>,
        cfg: EngineConfig,
    ) -> Engine {
        let scheduler = DecodeScheduler::new(planner, geometry, available_splits);
        let mut blocks_cfg = cfg.blocks.clone();
        blocks_cfg.max_seq = blocks_cfg.max_seq.min(geometry.max_seq);
        Engine {
            backend: EngineBackend::Simulated(sim),
            scheduler,
            batcher: Batcher::new(cfg.batcher.clone()),
            blocks: BlockManager::new(blocks_cfg),
            metrics: EngineMetrics::default(),
            cache: None,
            vocab: 1 << 15,
            started: Instant::now(),
            sim_clock_us: 0.0,
            sim_overhead_us: cfg.sim_framework_overhead_us,
            pending_arrivals: Vec::new(),
            finished: Vec::new(),
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.scheduler.policy_name()
    }

    fn now_us(&self) -> u64 {
        match self.backend {
            EngineBackend::Pjrt(_) => self.started.elapsed().as_micros() as u64,
            EngineBackend::Simulated(_) => self.sim_clock_us as u64,
        }
    }

    /// Submit a request (timestamps it on arrival).
    pub fn submit(&mut self, mut req: Request) {
        req.arrival_us = self.now_us();
        self.batcher.submit(req);
    }

    /// Open-loop arrival (simulated backend): the request becomes visible
    /// to the batcher once the virtual clock reaches `arrival_us`. This is
    /// the trace-replay path for load testing under Poisson traffic
    /// (workload::ChatWorkload::generate's arrival offsets).
    pub fn submit_at(&mut self, mut req: Request, arrival_us: u64) {
        assert!(
            matches!(self.backend, EngineBackend::Simulated(_)),
            "submit_at is a virtual-clock (simulated backend) feature"
        );
        req.arrival_us = arrival_us;
        let pos = self
            .pending_arrivals
            .partition_point(|(t, _)| *t <= arrival_us);
        self.pending_arrivals.insert(pos, (arrival_us, req));
    }

    /// Move due open-loop arrivals into the batcher; if the engine is
    /// otherwise idle, fast-forward the virtual clock to the next arrival.
    fn ingest_arrivals(&mut self) {
        if self.pending_arrivals.is_empty() {
            return;
        }
        if self.batcher.is_idle() {
            let next = self.pending_arrivals[0].0;
            if (self.sim_clock_us as u64) < next {
                self.sim_clock_us = next as f64;
            }
        }
        let now = self.now_us();
        while let Some((t, _)) = self.pending_arrivals.first() {
            if *t > now {
                break;
            }
            let (_, req) = self.pending_arrivals.remove(0);
            self.batcher.submit(req);
        }
    }

    pub fn is_idle(&self) -> bool {
        self.batcher.is_idle() && self.pending_arrivals.is_empty()
    }

    /// Abort everything queued or running (engine shutdown): releases all
    /// blocks and emits `FinishReason::Aborted` results.
    pub fn abort_all(&mut self) -> Result<Vec<FinishedRequest>> {
        let now = self.now_us();
        let (waiting, running) = self.batcher.drain();
        let mut aborted = Vec::new();
        for req in waiting {
            aborted.push(FinishedRequest {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                reason: FinishReason::Aborted,
                timing: RequestTiming { arrival_us: req.arrival_us, ..Default::default() },
            });
        }
        for r in running {
            self.blocks.release(r.req.id)?;
            if let Some(cache) = self.cache.as_mut() {
                cache.clear_row(r.slot);
            }
            aborted.push(FinishedRequest {
                id: r.req.id,
                prompt_len: r.req.prompt.len(),
                tokens: r.generated,
                reason: FinishReason::Aborted,
                timing: RequestTiming {
                    arrival_us: r.req.arrival_us,
                    scheduled_us: r.scheduled_us,
                    first_token_us: r.first_token_us.unwrap_or(now),
                    finished_us: now,
                    n_generated: 0,
                },
            });
        }
        Ok(aborted)
    }

    /// Run until every submitted request completes; returns them in
    /// completion order.
    pub fn run_until_idle(&mut self) -> Result<Vec<FinishedRequest>> {
        while !self.is_idle() {
            self.step()?;
        }
        self.metrics.wall_us = self.now_us();
        Ok(std::mem::take(&mut self.finished))
    }

    /// One engine step: admit → prefill one batch → decode one batch.
    pub fn step(&mut self) -> Result<()> {
        self.ingest_arrivals();
        let now = self.now_us();
        self.batcher.admit(&mut self.blocks, now);
        let plan = self.batcher.plan();
        let t0 = Instant::now();
        let mut decoded = 0;

        if !plan.prefill_slots.is_empty() {
            self.prefill(&plan.prefill_slots)?;
        } else if !plan.decode_slots.is_empty() {
            decoded = self.decode(&plan.decode_slots, plan.decode_bucket.context("no bucket")?)?;
        }

        let step_us = match &self.backend {
            EngineBackend::Pjrt(_) => t0.elapsed().as_micros() as f64,
            EngineBackend::Simulated(_) => 0.0, // accounted inside prefill/decode
        };
        if matches!(self.backend, EngineBackend::Pjrt(_)) {
            self.metrics.record_step(step_us, decoded);
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    fn prefill(&mut self, slots: &[usize]) -> Result<()> {
        match &self.backend {
            EngineBackend::Pjrt(reg) => {
                let reg = reg.clone();
                for &slot in slots {
                    self.prefill_one_pjrt(&reg, slot)?;
                }
            }
            EngineBackend::Simulated(_) => {
                // Prefill latency is policy-invariant (the paper's change is
                // decode-only); model it as one bulk step per request.
                for &slot in slots {
                    let r = self.batcher.running_mut(slot).context("slot")?;
                    r.prefilled = r.req.prompt.len();
                    let prompt_us = 50.0 + 0.05 * r.req.prompt.len() as f64;
                    self.sim_clock_us += prompt_us;
                    self.metrics.prefill_calls += 1;
                    self.metrics.record_step(prompt_us, 0);
                }
            }
        }
        Ok(())
    }

    fn prefill_one_pjrt(&mut self, reg: &Registry, slot: usize) -> Result<()> {
        let (id, prompt) = {
            let r = self.batcher.running(slot).context("slot")?;
            (r.req.id, r.req.prompt.clone())
        };
        let _ = id;
        let p_len = prompt.len();
        let entry = reg
            .manifest
            .find_prefill_bucket(1, p_len)
            .map(|e| e.clone());
        if let Some(entry) = entry {
            let b = entry.meta.batch.unwrap();
            let bucket_p = entry.meta.prompt_len.unwrap();
            let cache = self.cache.as_ref().context("cache")?;
            let (kv_k, kv_v) = cache.gather(&[slot], b);
            let mut tokens = vec![0i32; b * bucket_p];
            tokens[..p_len].copy_from_slice(&prompt);
            let mut lens = vec![1i32; b]; // padded rows: 1 token, ignored
            lens[0] = p_len as i32;
            let out = reg.execute_model(
                &entry.name,
                &[
                    HostTensor::s32(&[b, bucket_p], tokens)?,
                    HostTensor::s32(&[b], lens)?,
                    kv_k,
                    kv_v,
                ],
            )?;
            self.cache.as_mut().unwrap().scatter(&[slot], &out[1], &out[2]);
            let r = self.batcher.running_mut(slot).context("slot")?;
            r.prefilled = p_len;
            self.metrics.prefill_calls += 1;
        } else {
            // No prefill bucket fits: ingest via the decode path token by
            // token (slow path; exercised by tests with tiny buckets).
            self.prefill_via_decode(reg, slot)?;
        }
        Ok(())
    }

    fn prefill_via_decode(&mut self, reg: &Registry, slot: usize) -> Result<()> {
        let prompt = self.batcher.running(slot).context("slot")?.req.prompt.clone();
        let already = self.batcher.running(slot).context("slot")?.prefilled;
        for (t, &tok) in prompt.iter().enumerate().skip(already) {
            let decision = self.scheduler.decide(1, t + 1)?;
            let entry = reg
                .manifest
                .find_decode_bucket(1, decision.artifact_splits)
                .context("no decode bucket for prefill-via-decode")?
                .clone();
            let b = entry.meta.batch.unwrap();
            let cache = self.cache.as_ref().context("cache")?;
            let (kv_k, kv_v) = cache.gather(&[slot], b);
            let mut toks = vec![0i32; b];
            toks[0] = tok;
            let mut pos = vec![0i32; b];
            pos[0] = t as i32;
            let out = reg.execute_model(
                &entry.name,
                &[HostTensor::s32(&[b], toks)?, HostTensor::s32(&[b], pos)?, kv_k, kv_v],
            )?;
            self.cache.as_mut().unwrap().scatter(&[slot], &out[1], &out[2]);
        }
        let r = self.batcher.running_mut(slot).context("slot")?;
        r.prefilled = prompt.len();
        self.metrics.prefill_calls += 1;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    fn decode(&mut self, slots: &[usize], bucket: usize) -> Result<usize> {
        // The scheduler sees the live batch shape: the longest row's KV
        // length (including the token being written this step).
        let max_kv = slots
            .iter()
            .map(|&s| self.batcher.running(s).map(|r| r.kv_len() + 1).unwrap_or(1))
            .max()
            .unwrap_or(1);
        let decision = self.scheduler.decide(slots.len(), max_kv)?;
        self.metrics.record_split(decision.plan.metadata.num_splits);

        match &self.backend {
            EngineBackend::Pjrt(reg) => {
                let reg = reg.clone();
                self.decode_pjrt(&reg, slots, bucket, decision.artifact_splits)
            }
            EngineBackend::Simulated(sim) => {
                let kernel_us = sim.kernel_us(&decision.plan.metadata);
                // One attention launch per layer; use 1 layer as the unit
                // (policy comparisons are ratios, layers scale both sides).
                let step_us = kernel_us + self.sim_overhead_us;
                self.sim_clock_us += step_us;
                self.metrics.record_step(step_us, slots.len());
                let now = self.now_us();
                let mut finished = Vec::new();
                for &slot in slots {
                    let r = self.batcher.running_mut(slot).context("slot")?;
                    let synth = (r.kv_len() % 1000) as i32;
                    r.generated.push(synth);
                    r.first_token_us.get_or_insert(now);
                    if r.done() {
                        finished.push((slot, FinishReason::Length));
                    }
                }
                for (slot, reason) in finished {
                    self.retire(slot, reason)?;
                }
                Ok(slots.len())
            }
        }
    }

    fn decode_pjrt(
        &mut self,
        reg: &Registry,
        slots: &[usize],
        bucket: usize,
        artifact_splits: usize,
    ) -> Result<usize> {
        let entry = reg
            .manifest
            .find_decode_bucket(bucket, artifact_splits)
            .or_else(|| reg.manifest.find_decode_bucket(bucket, 1))
            .with_context(|| format!("no decode bucket for b={bucket}"))?
            .clone();
        let b = entry.meta.batch.unwrap();
        if slots.len() > b {
            bail!("bucket {b} smaller than batch {}", slots.len());
        }

        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        for (bi, &slot) in slots.iter().enumerate() {
            let r = self.batcher.running(slot).context("slot")?;
            // Next input token: last generated, or last prompt token when
            // none generated yet (the prefill consumed prompt[..len-1]...
            // here: full prompt ingested, so feed the last generated or a
            // BOS-continuation of the prompt).
            tokens[bi] = *r.generated.last().unwrap_or(r.req.prompt.last().unwrap_or(&0));
            positions[bi] = r.kv_len() as i32;
        }
        let cache = self.cache.as_ref().context("cache")?;
        let (kv_k, kv_v) = cache.gather(slots, b);
        let out = reg.execute_model(
            &entry.name,
            &[
                HostTensor::s32(&[b], tokens)?,
                HostTensor::s32(&[b], positions)?,
                kv_k,
                kv_v,
            ],
        )?;
        self.cache.as_mut().unwrap().scatter(slots, &out[1], &out[2]);

        let logits = out[0].as_f32()?;
        let now = self.now_us();
        let mut finished = Vec::new();
        for (bi, &slot) in slots.iter().enumerate() {
            let row = &logits[bi * self.vocab..(bi + 1) * self.vocab];
            let tok = argmax(row) as i32;
            let r = self.batcher.running_mut(slot).context("slot")?;
            r.generated.push(tok);
            r.first_token_us.get_or_insert(now);
            if r.done() {
                finished.push((slot, FinishReason::Length));
            } else if r.kv_len() + 1 > self.scheduler.geometry().max_seq {
                finished.push((slot, FinishReason::CacheFull));
            }
        }
        for (slot, reason) in finished {
            self.retire(slot, reason)?;
        }
        Ok(slots.len())
    }

    fn retire(&mut self, slot: usize, reason: FinishReason) -> Result<()> {
        let r: RunningRequest = self.batcher.take(slot).context("retire empty slot")?;
        self.blocks.release(r.req.id)?;
        if let Some(cache) = self.cache.as_mut() {
            cache.clear_row(slot);
        }
        let now = self.now_us();
        let timing = RequestTiming {
            arrival_us: r.req.arrival_us,
            scheduled_us: r.scheduled_us,
            first_token_us: r.first_token_us.unwrap_or(now),
            finished_us: now,
            n_generated: r.generated.len(),
        };
        self.metrics.record_finished(&timing);
        self.finished.push(FinishedRequest {
            id: r.req.id,
            prompt_len: r.req.prompt.len(),
            tokens: r.generated,
            reason,
            timing,
        });
        Ok(())
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

// ----------------------------------------------------------------------
// Threaded server facade
// ----------------------------------------------------------------------

/// Handle to an engine running on its own thread (tokio is unavailable
/// offline; a dedicated thread + channels is the same architecture).
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
    pub results: mpsc::Receiver<FinishedRequest>,
    join: Option<std::thread::JoinHandle<EngineMetrics>>,
}

impl EngineHandle {
    /// Spawn `engine` on a worker thread. The engine drains its queue,
    /// sleeping briefly when idle, until the sender is dropped.
    pub fn spawn(mut engine: Engine) -> EngineHandle {
        let (tx, rx) = mpsc::channel::<Request>();
        let (out_tx, out_rx) = mpsc::channel::<FinishedRequest>();
        let join = std::thread::spawn(move || {
            loop {
                // Pull everything currently queued.
                let mut disconnected = false;
                loop {
                    match rx.try_recv() {
                        Ok(req) => engine.submit(req),
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            disconnected = true;
                            break;
                        }
                    }
                }
                if engine.is_idle() {
                    if disconnected {
                        break;
                    }
                    // Block for the next request to avoid spinning.
                    match rx.recv() {
                        Ok(req) => engine.submit(req),
                        Err(_) => break,
                    }
                }
                if let Err(e) = engine.step() {
                    eprintln!("engine step failed: {e:#}");
                    break;
                }
                for fin in std::mem::take(&mut engine.finished) {
                    let _ = out_tx.send(fin);
                }
            }
            engine.metrics.wall_us = engine.now_us();
            engine.metrics
        });
        EngineHandle { tx, results: out_rx, join: Some(join) }
    }

    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx.send(req).map_err(|_| anyhow::anyhow!("engine thread gone"))
    }

    /// Close the submit side and wait for the engine to drain.
    pub fn shutdown(mut self) -> EngineMetrics {
        let EngineHandle { tx, join, .. } = &mut self;
        drop(std::mem::replace(tx, mpsc::channel().0));
        join.take().expect("joined once").join().expect("engine thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_engine(planner: Planner) -> Engine {
        Engine::with_simulator(
            Simulator::h100(),
            planner,
            AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 },
            vec![1, 3],
            EngineConfig::default(),
        )
    }

    #[test]
    fn simulated_generation_completes() {
        let mut e = sim_engine(Planner::sequence_aware());
        e.submit(Request::new(1, vec![7; 100], 20));
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 20);
        assert_eq!(done[0].reason, FinishReason::Length);
        assert!(e.metrics.tokens_generated >= 20);
        assert!(e.blocks.check_invariants().is_ok());
        assert_eq!(e.blocks.num_seqs(), 0, "all blocks released");
    }

    #[test]
    fn patched_policy_faster_through_boundary_bucket() {
        // Decode from KV 400 to 512: inside nblk=4 bucket, tiles=1.
        let run = |planner: Planner| {
            let mut e = sim_engine(planner);
            e.submit(Request::new(1, vec![1; 400], 112));
            let done = e.run_until_idle().unwrap();
            (done[0].timing.tpot_us(), e.metrics.split_histogram.clone())
        };
        let (tpot_std, hist_std) = run(Planner::standard());
        let (tpot_pat, hist_pat) = run(Planner::sequence_aware());
        assert!(tpot_std / tpot_pat > 1.1, "std {tpot_std:.1} vs pat {tpot_pat:.1}");
        // Standard never splits here; patched uses s=3 throughout.
        assert!(hist_std.get(3).copied().unwrap_or(0) == 0);
        assert!(hist_pat[3] > 100);
    }

    #[test]
    fn batched_requests_share_steps() {
        let mut e = sim_engine(Planner::standard());
        for id in 0..4 {
            e.submit(Request::new(id, vec![1; 50], 10));
        }
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 4);
        // 4 requests x 10 tokens but batched: decode steps ≈ 10, not 40.
        assert!(e.metrics.decode_steps <= 12, "steps={}", e.metrics.decode_steps);
    }

    #[test]
    fn queueing_beyond_batch_capacity() {
        let mut e = sim_engine(Planner::standard());
        for id in 0..9 {
            e.submit(Request::new(id, vec![1; 10], 5));
        }
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 9);
        // Later requests must have queued (scheduled after arrival).
        let queued = done.iter().filter(|f| f.timing.queue_us() > 0).count();
        assert!(queued >= 1);
    }

    #[test]
    fn open_loop_arrivals_respect_virtual_time() {
        let mut e = sim_engine(Planner::sequence_aware());
        // Three arrivals spaced 10 ms apart on the virtual clock.
        for (i, t) in [0u64, 10_000, 20_000].iter().enumerate() {
            e.submit_at(Request::new(i as u64, vec![1; 40], 8), *t);
        }
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 3);
        let mut by_id = done.clone();
        by_id.sort_by_key(|f| f.id);
        for (i, f) in by_id.iter().enumerate() {
            assert_eq!(f.timing.arrival_us, 10_000 * i as u64);
            // Scheduled at-or-after arrival on the virtual clock.
            assert!(f.timing.first_token_us >= f.timing.arrival_us);
        }
        // The clock fast-forwarded through idle gaps: total wall is at
        // least the last arrival.
        assert!(e.metrics.wall_us >= 20_000);
    }

    #[test]
    fn abort_all_releases_everything() {
        let mut e = sim_engine(Planner::standard());
        for id in 0..6 {
            e.submit(Request::new(id, vec![1; 50], 1000));
        }
        // Run a few steps so some requests are mid-flight.
        for _ in 0..5 {
            e.step().unwrap();
        }
        let aborted = e.abort_all().unwrap();
        assert_eq!(aborted.len(), 6);
        assert!(aborted.iter().all(|f| f.reason == FinishReason::Aborted));
        assert!(e.is_idle());
        assert!(e.blocks.check_invariants().is_ok());
        assert_eq!(e.blocks.num_seqs(), 0);
    }

    #[test]
    fn threaded_handle_round_trip() {
        let e = sim_engine(Planner::sequence_aware());
        let handle = EngineHandle::spawn(e);
        for id in 0..3 {
            handle.submit(Request::new(id, vec![2; 64], 8)).unwrap();
        }
        let mut got = 0;
        while got < 3 {
            if handle.results.recv_timeout(std::time::Duration::from_secs(10)).is_ok() {
                got += 1;
            } else {
                panic!("timed out waiting for results");
            }
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests_finished, 3);
    }
}
