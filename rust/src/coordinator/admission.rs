//! Admission control: bounded priority queues in front of the running set.
//!
//! Semantics (DESIGN.md §Serving engine):
//!
//! * **Bounded queues** — each priority class holds at most
//!   [`AdmissionConfig::queue_capacity`] waiting requests; a full queue
//!   rejects the submission with an explicit [`Backpressure`] outcome
//!   instead of queueing unboundedly (shed load at the front door, not by
//!   OOM).
//! * **Strict priority, FIFO within a class** — classes drain in
//!   [`Priority`] order; inside a class, admission order equals submission
//!   order. A class head that doesn't fit the KV-block budget blocks all
//!   lower classes too (head-of-line blocking is the no-starvation
//!   trade: a cheap Batch request must not leapfrog a starved
//!   Interactive one).
//! * **Worst-case KV reservation, charged net of sharing** — a request
//!   is admitted only when the blocks its prompt does *not* share fit
//!   the budget *now* ([`BlockManager::can_admit_prompt`]): a prompt
//!   whose prefix is already resident (live or recently freed) is
//!   charged only for its private remainder, so a shared system prompt
//!   multiplies admission *concurrency* instead of consuming it.
//!   Requests that could never fit ([`BlockManager::can_ever_admit`] —
//!   deliberately prefix-blind, since sharing never shrinks a single
//!   request's resident footprint) are rejected at submission with
//!   [`SubmitError::Unschedulable`] rather than wedging the queue head
//!   forever.
//! * **Cancellation while queued** — cancelled/deadline-expired waiters
//!   are reaped before each admission pass; they hold no blocks, so
//!   reaping is pure queue surgery.

use std::collections::VecDeque;

use super::batcher::Batcher;
use super::kv_cache::BlockManager;
use super::lifecycle::{CancelKind, Priority, TrackedRequest, PRIORITY_CLASSES};
use super::request::{RequestId, RunningRequest};

/// Admission configuration.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Waiting-queue capacity per priority class.
    pub queue_capacity: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { queue_capacity: 1024 }
    }
}

/// The explicit rejection outcome of a bounded queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Backpressure {
    pub priority: Priority,
    /// Waiting requests in that class when the submission arrived.
    pub queue_depth: usize,
    pub capacity: usize,
}

impl std::fmt::Display for Backpressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "backpressure: '{}' queue full ({}/{})",
            self.priority.name(),
            self.queue_depth,
            self.capacity
        )
    }
}

/// Why a submission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The class queue is full — retry later or shed.
    Backpressure(Backpressure),
    /// The request can never fit this engine's KV budget.
    Unschedulable { required_tokens: usize, max_seq: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure(bp) => write!(f, "{bp}"),
            SubmitError::Unschedulable { required_tokens, max_seq } => write!(
                f,
                "unschedulable: {required_tokens} tokens can never fit (max_seq {max_seq})"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Admission counters (surfaced through `EngineMetrics`, and per class
/// as the `fa3_admission_rejected_total{class,reason}` Prometheus
/// family). `admitted` counts *admissions*, so a preempted-then-resumed
/// request contributes twice.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    pub rejected_backpressure: usize,
    pub rejected_unschedulable: usize,
    pub cancelled_while_queued: usize,
    pub admitted: usize,
    /// Queued requests dropped as hopeless by the SLO shed pass (they
    /// could no longer produce any goodput).
    pub shed: usize,
    /// Per-class splits of the rejection/shed counters (index =
    /// `Priority::index()`).
    pub rejected_backpressure_class: [usize; PRIORITY_CLASSES],
    pub rejected_unschedulable_class: [usize; PRIORITY_CLASSES],
    pub shed_class: [usize; PRIORITY_CLASSES],
}

/// The admission controller: bounded waiting queues, one per class.
pub struct AdmissionController {
    cfg: AdmissionConfig,
    queues: [VecDeque<TrackedRequest>; PRIORITY_CLASSES],
    pub stats: AdmissionStats,
}

impl AdmissionController {
    /// An admission controller with empty queues.
    pub fn new(cfg: AdmissionConfig) -> AdmissionController {
        assert!(cfg.queue_capacity > 0, "queue capacity must be positive");
        AdmissionController {
            cfg,
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            stats: AdmissionStats::default(),
        }
    }

    /// The configuration this controller was built with.
    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Waiting requests across all classes.
    pub fn waiting_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Waiting requests in one priority class.
    pub fn waiting_in(&self, priority: Priority) -> usize {
        self.queues[priority.index()].len()
    }

    /// The shared never-fits check (used by `offer` and by the engine's
    /// open-loop `submit_at` path, so the stats stay the single source of
    /// truth for rejections). Deliberately prefix-blind — sharing can
    /// never shrink a single request's resident footprint, only the
    /// *new* blocks it charges, so admitting on the strength of today's
    /// sharing would let a donor eviction wedge the queue head forever
    /// (see [`BlockManager::can_ever_admit`]).
    pub fn check_schedulable(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        priority: Priority,
        blocks: &BlockManager,
    ) -> Result<(), SubmitError> {
        if !blocks.can_ever_admit(prompt.len(), max_new) {
            self.stats.rejected_unschedulable += 1;
            self.stats.rejected_unschedulable_class[priority.index()] += 1;
            return Err(SubmitError::Unschedulable {
                required_tokens: prompt.len() + max_new,
                max_seq: blocks.config().max_seq,
            });
        }
        Ok(())
    }

    /// Enqueue a submission, or refuse it. Refused requests are returned
    /// to the caller inside the error path untouched — the engine emits
    /// the rejection on the request's stream.
    pub fn offer(
        &mut self,
        tracked: TrackedRequest,
        blocks: &BlockManager,
    ) -> Result<(), (TrackedRequest, SubmitError)> {
        if let Err(err) = self.check_schedulable(
            &tracked.req.prompt,
            tracked.req.max_new_tokens,
            tracked.priority(),
            blocks,
        ) {
            return Err((tracked, err));
        }
        let q = &mut self.queues[tracked.priority().index()];
        if q.len() >= self.cfg.queue_capacity {
            self.stats.rejected_backpressure += 1;
            self.stats.rejected_backpressure_class[tracked.priority().index()] += 1;
            let bp = Backpressure {
                priority: tracked.priority(),
                queue_depth: q.len(),
                capacity: self.cfg.queue_capacity,
            };
            return Err((tracked, SubmitError::Backpressure(bp)));
        }
        q.push_back(tracked);
        Ok(())
    }

    /// Remove queued requests that were cancelled or whose deadline passed
    /// (stamping the deadline cause). They hold no blocks; the engine
    /// finishes their streams. Runs every engine step, so the common
    /// nothing-to-reap case is a scan with no moves or allocation.
    pub fn reap_cancelled(&mut self, now_us: u64) -> Vec<TrackedRequest> {
        let needs_reap = self.queues.iter().flatten().any(|t| {
            t.ticket.past_deadline(now_us) || t.ticket.cancel.is_cancelled()
        });
        if !needs_reap {
            return Vec::new();
        }
        let mut reaped = Vec::new();
        for q in &mut self.queues {
            let mut keep = VecDeque::with_capacity(q.len());
            while let Some(t) = q.pop_front() {
                if t.ticket.past_deadline(now_us) {
                    t.ticket.cancel.cancel(CancelKind::Deadline);
                }
                if t.ticket.cancel.is_cancelled() {
                    self.stats.cancelled_while_queued += 1;
                    reaped.push(t);
                } else {
                    keep.push_back(t);
                }
            }
            *q = keep;
        }
        reaped
    }

    /// Admit waiting requests into free batcher slots while the block
    /// manager accepts them. Strict priority across classes, FIFO within;
    /// the first head that doesn't fit stops the whole pass. Admission is
    /// sharing-aware: the head is charged only for the blocks its prompt
    /// does not share, and the prefix-cache grant (tokens whose KV
    /// already exists) rides into the running set so prefill can skip
    /// them.
    pub fn admit(
        &mut self,
        batcher: &mut Batcher,
        blocks: &mut BlockManager,
        now_us: u64,
    ) -> Vec<RequestId> {
        let mut admitted = Vec::new();
        'classes: for priority in Priority::all() {
            let q = &mut self.queues[priority.index()];
            while let Some(front) = q.front() {
                // A swap-parked head whose host transfer hasn't landed
                // blocks like a KV-starved head: strict priority means
                // nothing may leapfrog it, and the engine fast-forwards
                // the virtual clock to its ready time when idle.
                if front.resume_ready_at().is_some_and(|ready| ready > now_us) {
                    break 'classes;
                }
                let Some(slot) = batcher.free_slot() else { break 'classes };
                // One probe, not two: `admit` applies the same
                // sharing-aware capacity predicate `can_admit_prompt`
                // does and refuses gracefully BEFORE any state change,
                // so a refusal here is exactly head-of-line blocking
                // (queue heads already passed the shape checks at
                // `offer`, so capacity is the only way it can fail).
                let grant = match blocks.admit(
                    front.req.id,
                    &front.req.prompt,
                    front.req.max_new_tokens,
                ) {
                    Ok(grant) => grant,
                    // Head-of-line: a blocked head blocks lower classes too.
                    Err(_full) => break 'classes,
                };
                let mut t = q.pop_front().unwrap();
                let resume = t.resume.take();
                admitted.push(t.req.id);
                self.stats.admitted += 1;
                let mut running = RunningRequest::new(t.req, t.ticket, slot, now_us);
                running.cached_prompt_tokens = grant.cached_tokens;
                if let Some(rs) = resume {
                    running.restore(*rs);
                }
                batcher.install(running);
            }
        }
        admitted
    }

    /// Re-enqueue a preempted request at the HEAD of its class: it keeps
    /// its FIFO position relative to everything that arrived after it,
    /// so preemption delays a victim, never starves it. Deliberately
    /// exempt from the queue-capacity bound — the request was already
    /// inside the system (it held a slot a moment ago); bouncing it at
    /// the door would turn preemption into silent cancellation.
    pub(crate) fn requeue_preempted(&mut self, t: TrackedRequest) {
        self.queues[t.priority().index()].push_front(t);
    }

    /// Class index of the head request blocked on *capacity* (slots or
    /// KV), if any: the front of the highest-priority non-empty class,
    /// unless that front is swap-parked (then it waits on its transfer,
    /// and preempting victims for it would be pointless).
    pub(crate) fn blocked_head_class(&self, now_us: u64) -> Option<usize> {
        for priority in Priority::all() {
            if let Some(front) = self.queues[priority.index()].front() {
                if front.resume_ready_at().is_some_and(|ready| ready > now_us) {
                    return None;
                }
                return Some(priority.index());
            }
        }
        None
    }

    /// The head request's prompt/max_new (admission cost probe for the
    /// preemption pass), for the blocked head identified by
    /// [`AdmissionController::blocked_head_class`].
    pub(crate) fn head_request(&self, class: usize) -> Option<&TrackedRequest> {
        self.queues[class].front()
    }

    /// If the highest-priority non-empty class's head is swap-parked in
    /// the future, when it becomes ready — the engine's idle
    /// fast-forward target (without it, a virtual-clock engine whose
    /// only remaining work is a parked resume would spin forever).
    pub(crate) fn blocking_resume_ready_us(&self, now_us: u64) -> Option<u64> {
        for priority in Priority::all() {
            if let Some(front) = self.queues[priority.index()].front() {
                return front.resume_ready_at().filter(|&ready| ready > now_us);
            }
        }
        None
    }

    /// Drop queued requests the predicate deems hopeless (negative
    /// slack: no schedule can land them inside their deadline/SLO).
    /// They hold no blocks, so this is pure queue surgery like
    /// [`AdmissionController::reap_cancelled`]; the engine finishes
    /// their streams. The common nothing-hopeless case is a scan with
    /// no moves or allocation.
    pub(crate) fn shed_where(
        &mut self,
        mut hopeless: impl FnMut(&TrackedRequest) -> bool,
    ) -> Vec<TrackedRequest> {
        if !self.queues.iter().flatten().any(&mut hopeless) {
            return Vec::new();
        }
        let mut shed = Vec::new();
        for (class, q) in self.queues.iter_mut().enumerate() {
            let mut keep = VecDeque::with_capacity(q.len());
            while let Some(t) = q.pop_front() {
                if hopeless(&t) {
                    self.stats.shed += 1;
                    self.stats.shed_class[class] += 1;
                    shed.push(t);
                } else {
                    keep.push_back(t);
                }
            }
            *q = keep;
        }
        shed
    }

    /// Cancel a queued request by id (running requests are the engine's
    /// responsibility). Returns whether it was found waiting.
    pub fn cancel(&mut self, id: RequestId, kind: CancelKind) -> bool {
        for q in &self.queues {
            if let Some(t) = q.iter().find(|t| t.req.id == id) {
                t.ticket.cancel.cancel(kind);
                return true;
            }
        }
        false
    }

    /// Mark every waiting request cancelled (engine shutdown).
    pub fn cancel_all(&mut self, kind: CancelKind) {
        for q in &self.queues {
            for t in q {
                t.ticket.cancel.cancel(kind);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::kv_cache::BlockManagerConfig;
    use crate::coordinator::lifecycle::{handle_pair, SubmitOptions};
    use crate::coordinator::request::Request;

    fn tracked(id: u64, prompt_len: usize, max_new: usize, opts: SubmitOptions) -> TrackedRequest {
        let (_handle, ticket) = handle_pair(id, &opts);
        // Content unique per id: these tests exercise the prefix-blind
        // accounting; sharing has its own suites.
        let prompt = (0..prompt_len).map(|i| (id as i32 + 1) * 10_000 + i as i32).collect();
        TrackedRequest { req: Request::new(id, prompt, max_new), ticket, resume: None }
    }

    fn setup(max_batch: usize, num_blocks: usize) -> (AdmissionController, Batcher, BlockManager) {
        let buckets: Vec<usize> = [1, 2, 4, 8].into_iter().filter(|&b| b <= max_batch).collect();
        (
            AdmissionController::new(AdmissionConfig { queue_capacity: 4 }),
            Batcher::new(BatcherConfig { max_batch, batch_buckets: buckets }),
            BlockManager::new(BlockManagerConfig {
                block_size: 16,
                num_blocks,
                max_seq: 1024,
                ..Default::default()
            }),
        )
    }

    #[test]
    fn fifo_admission_respects_batch_and_blocks() {
        let (mut adm, mut b, mut m) = setup(2, 8); // 128-token budget
        for id in 1..=3 {
            adm.offer(tracked(id, 32, 16, SubmitOptions::default()), &m).unwrap(); // 3 blocks each
        }
        let admitted = adm.admit(&mut b, &mut m, 0);
        assert_eq!(admitted, vec![1, 2]); // #3 blocked: 8 - 6 = 2 < 3 blocks
        assert_eq!(b.running_len(), 2);
        assert_eq!(adm.waiting_len(), 1);
        // Slot freed => next admit picks up request 3.
        let r = b.take(0).unwrap();
        m.release(r.req.id).unwrap();
        assert_eq!(adm.admit(&mut b, &mut m, 1), vec![3]);
    }

    #[test]
    fn bounded_queue_rejects_with_backpressure() {
        let (mut adm, _b, m) = setup(2, 1024);
        for id in 0..4 {
            adm.offer(tracked(id, 8, 8, SubmitOptions::default()), &m).unwrap();
        }
        let (_t, err) = adm.offer(tracked(9, 8, 8, SubmitOptions::default()), &m).unwrap_err();
        match err {
            SubmitError::Backpressure(bp) => {
                assert_eq!(bp.queue_depth, 4);
                assert_eq!(bp.capacity, 4);
                assert_eq!(bp.priority, Priority::Standard);
            }
            other => panic!("expected backpressure, got {other:?}"),
        }
        // Other classes are unaffected by a full Standard queue.
        adm.offer(tracked(10, 8, 8, SubmitOptions::default().priority(Priority::Batch)), &m)
            .unwrap();
        assert_eq!(adm.stats.rejected_backpressure, 1);
    }

    #[test]
    fn unschedulable_rejected_at_offer() {
        let (mut adm, _b, m) = setup(2, 1024); // max_seq 1024
        let (_t, err) = adm.offer(tracked(1, 1000, 500, SubmitOptions::default()), &m).unwrap_err();
        assert!(matches!(err, SubmitError::Unschedulable { required_tokens: 1500, .. }));
        assert_eq!(adm.waiting_len(), 0);
    }

    #[test]
    fn strict_priority_across_classes_fifo_within() {
        let (mut adm, mut b, mut m) = setup(8, 1024);
        adm.offer(tracked(1, 8, 8, SubmitOptions::default().priority(Priority::Batch)), &m)
            .unwrap();
        adm.offer(tracked(2, 8, 8, SubmitOptions::default()), &m).unwrap();
        adm.offer(tracked(3, 8, 8, SubmitOptions::default().priority(Priority::Interactive)), &m)
            .unwrap();
        adm.offer(tracked(4, 8, 8, SubmitOptions::default().priority(Priority::Interactive)), &m)
            .unwrap();
        let admitted = adm.admit(&mut b, &mut m, 0);
        assert_eq!(admitted, vec![3, 4, 2, 1]);
    }

    #[test]
    fn blocked_head_blocks_lower_classes_too() {
        let (mut adm, mut b, mut m) = setup(4, 4); // tiny: 64 tokens
        adm.offer(tracked(1, 60, 4, SubmitOptions::default()), &m).unwrap(); // 4 blocks — fits alone
        adm.offer(tracked(2, 8, 8, SubmitOptions::default().priority(Priority::Batch)), &m)
            .unwrap(); // 1 block — would fit, but must NOT leapfrog
        assert_eq!(adm.admit(&mut b, &mut m, 0), vec![1]);
        assert_eq!(adm.admit(&mut b, &mut m, 0), Vec::<u64>::new());
        assert_eq!(adm.waiting_len(), 1);
    }

    #[test]
    fn reap_removes_cancelled_and_expired_waiters() {
        let (mut adm, _b, m) = setup(2, 1024);
        let t1 = tracked(1, 8, 8, SubmitOptions::default());
        t1.ticket.cancel.cancel(CancelKind::User);
        adm.offer(t1, &m).unwrap();
        adm.offer(tracked(2, 8, 8, SubmitOptions::default().deadline_us(100)), &m).unwrap();
        adm.offer(tracked(3, 8, 8, SubmitOptions::default()), &m).unwrap();
        let reaped = adm.reap_cancelled(150);
        let ids: Vec<u64> = reaped.iter().map(|t| t.req.id).collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(reaped[1].ticket.cancel.get(), Some(CancelKind::Deadline));
        assert_eq!(adm.waiting_len(), 1);
        assert_eq!(adm.stats.cancelled_while_queued, 2);
    }

    #[test]
    fn cancel_by_id_marks_waiting_request() {
        let (mut adm, _b, m) = setup(2, 1024);
        adm.offer(tracked(5, 8, 8, SubmitOptions::default()), &m).unwrap();
        assert!(adm.cancel(5, CancelKind::User));
        assert!(!adm.cancel(99, CancelKind::User));
        assert_eq!(adm.reap_cancelled(0).len(), 1);
    }
}
