//! Request lifecycle: streaming handles, per-request cancellation, and
//! deadlines.
//!
//! The lifecycle state machine (DESIGN.md §Serving engine):
//!
//! ```text
//! submit ──► Queued ──admit──► Running ──last token──► Finished(Length)
//!    │          │                 │  │
//!    │          │                 │  └─cache full────► Finished(CacheFull)
//!    │          └─cancel/deadline─┴────────────────► Finished(Cancelled |
//!    │                                                DeadlineExceeded |
//!    │                                                Aborted)
//!    └─queue full──────────────────────────────────► Rejected(Backpressure)
//! ```
//!
//! Every `submit` mints a ([`RequestHandle`], [`Ticket`]) pair sharing a
//! [`CancelCell`] and an event channel. The handle is the client side:
//! consume [`StreamEvent`]s as they arrive, call
//! [`RequestHandle::cancel`] at any point. The ticket travels with the
//! request through admission and the running set; the engine pushes
//! tokens into it as they decode and observes the cancel cell between
//! steps. Dropping a handle only discards the stream — the request still
//! runs to completion (results remain available from
//! `Engine::run_until_idle`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use super::admission::SubmitError;
use super::request::{FinishReason, FinishedRequest, Request, RequestId};

/// Admission priority class. Lower index = served first; FIFO within a
/// class. Strict priority: a blocked higher class is never leapfrogged
/// (no priority inversion under KV-budget pressure).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Latency-sensitive chat traffic.
    Interactive = 0,
    /// The default class.
    #[default]
    Standard = 1,
    /// Throughput traffic (batch jobs, evals).
    Batch = 2,
}

/// Number of priority classes (queue array size).
pub const PRIORITY_CLASSES: usize = 3;

impl Priority {
    /// Queue-array index of this class.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Every class, highest priority first.
    pub fn all() -> [Priority; PRIORITY_CLASSES] {
        [Priority::Interactive, Priority::Standard, Priority::Batch]
    }

    /// CLI-facing class name.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }
}

/// Why a request was cancelled. First cause wins; later cancels are no-ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelKind {
    /// Client-side `RequestHandle::cancel` (or `Engine::cancel`).
    User,
    /// The request's deadline elapsed before completion.
    Deadline,
    /// Engine shutdown (`abort_all`).
    Shutdown,
}

impl CancelKind {
    /// The terminal [`FinishReason`] this cancellation cause maps to.
    pub fn finish_reason(self) -> FinishReason {
        match self {
            CancelKind::User => FinishReason::Cancelled,
            CancelKind::Deadline => FinishReason::DeadlineExceeded,
            CancelKind::Shutdown => FinishReason::Aborted,
        }
    }
}

/// Shared cancellation flag. Lock-free: the client thread sets it, the
/// engine observes it between steps.
#[derive(Debug, Default)]
pub struct CancelCell {
    // 0 = live, 1..=3 = CancelKind + 1.
    state: AtomicU8,
}

impl CancelCell {
    /// Request cancellation. The first cause sticks; returns whether this
    /// call was the one that cancelled.
    pub fn cancel(&self, kind: CancelKind) -> bool {
        let code = match kind {
            CancelKind::User => 1,
            CancelKind::Deadline => 2,
            CancelKind::Shutdown => 3,
        };
        self.state.compare_exchange(0, code, Ordering::AcqRel, Ordering::Acquire).is_ok()
    }

    /// The first cancellation cause, if any.
    pub fn get(&self) -> Option<CancelKind> {
        match self.state.load(Ordering::Acquire) {
            1 => Some(CancelKind::User),
            2 => Some(CancelKind::Deadline),
            3 => Some(CancelKind::Shutdown),
            _ => None,
        }
    }

    /// Whether any cause has cancelled this request.
    pub fn is_cancelled(&self) -> bool {
        self.get().is_some()
    }
}

/// One event on a request's stream.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// A decoded token, in order. `index` counts from 0; `emitted_us` is
    /// the engine clock when it decoded.
    Token { token: i32, index: usize, emitted_us: u64 },
    /// The request never entered the queue (bounded-queue backpressure or
    /// an unschedulable shape).
    Rejected(SubmitError),
    /// Terminal event: the request left the engine. Always last.
    Finished(FinishedRequest),
}

/// Terminal outcome of [`RequestHandle::wait`]: completion, an admission
/// rejection, and a dead engine are three different things.
#[derive(Debug, Clone)]
pub enum WaitOutcome {
    Finished(FinishedRequest),
    Rejected(SubmitError),
    /// The engine dropped the ticket without a terminal event.
    Disconnected,
}

impl WaitOutcome {
    /// The finished request, if the outcome was completion.
    pub fn finished(self) -> Option<FinishedRequest> {
        match self {
            WaitOutcome::Finished(f) => Some(f),
            _ => None,
        }
    }
}

/// Client side of a submitted request.
pub struct RequestHandle {
    id: RequestId,
    events: mpsc::Receiver<StreamEvent>,
    cancel: Arc<CancelCell>,
}

impl RequestHandle {
    /// The submitted request's id.
    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Ask the engine to stop this request. Takes effect at the next step
    /// boundary; the stream then ends with
    /// `Finished(reason = Cancelled)` carrying the tokens generated so far.
    pub fn cancel(&self) {
        self.cancel.cancel(CancelKind::User);
    }

    /// Whether this request has been cancelled (any cause).
    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Non-blocking: the next queued event, if any.
    pub fn try_event(&self) -> Option<StreamEvent> {
        self.events.try_recv().ok()
    }

    /// Block up to `timeout` for the next event (threaded engines).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<StreamEvent> {
        self.events.recv_timeout(timeout).ok()
    }

    /// Drain the stream until a terminal outcome: the finished request, an
    /// admission rejection, or disconnection (the engine dropped the
    /// ticket without finishing — engine thread died). Blocks if the
    /// engine is still producing — on a threaded engine this waits for
    /// completion; on a synchronous engine call it after `run_until_idle`.
    pub fn wait(self) -> WaitOutcome {
        loop {
            match self.events.recv() {
                Ok(StreamEvent::Finished(f)) => return WaitOutcome::Finished(f),
                Ok(StreamEvent::Rejected(err)) => return WaitOutcome::Rejected(err),
                Ok(StreamEvent::Token { .. }) => continue,
                Err(_) => return WaitOutcome::Disconnected,
            }
        }
    }

    /// Convenience: drain whatever tokens are currently queued.
    pub fn drain_tokens(&self) -> Vec<i32> {
        let mut out = Vec::new();
        while let Ok(ev) = self.events.try_recv() {
            if let StreamEvent::Token { token, .. } = ev {
                out.push(token);
            }
        }
        out
    }
}

impl std::fmt::Debug for RequestHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RequestHandle")
            .field("id", &self.id)
            .field("cancelled", &self.cancel.is_cancelled())
            .finish()
    }
}

/// Engine side of a request's stream: send-only, best-effort (a dropped
/// handle must not wedge the engine).
pub(crate) struct StreamSink {
    tx: mpsc::Sender<StreamEvent>,
    /// Latched on the first failed send (receiver dropped). Channels are
    /// SPSC here and disconnection is permanent, so later sends skip the
    /// channel entirely — fire-and-forget submitters pay nothing per
    /// token, which is what keeps the steady-state decode step
    /// allocation-free for them.
    dead: std::cell::Cell<bool>,
}

impl StreamSink {
    pub(crate) fn new(tx: mpsc::Sender<StreamEvent>) -> StreamSink {
        StreamSink { tx, dead: std::cell::Cell::new(false) }
    }

    pub(crate) fn send(&self, ev: StreamEvent) {
        if self.dead.get() {
            return;
        }
        if self.tx.send(ev).is_err() {
            self.dead.set(true);
        }
    }
}

/// Per-request serving metadata that travels with the request through
/// admission and the running set.
pub struct Ticket {
    pub(crate) sink: StreamSink,
    pub(crate) cancel: Arc<CancelCell>,
    /// Absolute engine-clock deadline, µs. The engine cancels the request
    /// (queued or running) once `now_us` passes it.
    pub deadline_us: Option<u64>,
    pub priority: Priority,
}

impl Ticket {
    /// A ticket with no listening handle (internal/synthetic requests).
    pub(crate) fn detached(opts: &SubmitOptions) -> Ticket {
        let (tx, _rx) = mpsc::channel();
        Ticket {
            sink: StreamSink::new(tx),
            cancel: Arc::new(CancelCell::default()),
            deadline_us: opts.deadline_us,
            priority: opts.priority,
        }
    }

    /// Deadline check against the engine clock.
    pub(crate) fn past_deadline(&self, now_us: u64) -> bool {
        self.deadline_us.is_some_and(|d| now_us >= d)
    }
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket")
            .field("priority", &self.priority)
            .field("deadline_us", &self.deadline_us)
            .field("cancelled", &self.cancel.is_cancelled())
            .finish()
    }
}

/// Submission options. `Default` is an interactive-tier-free request: the
/// `Standard` class, no deadline.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    pub priority: Priority,
    /// Absolute engine-clock deadline, µs since engine start.
    pub deadline_us: Option<u64>,
}

impl SubmitOptions {
    /// Select the priority class.
    pub fn priority(mut self, priority: Priority) -> SubmitOptions {
        self.priority = priority;
        self
    }

    /// Set an absolute engine-clock deadline, µs.
    pub fn deadline_us(mut self, deadline_us: u64) -> SubmitOptions {
        self.deadline_us = Some(deadline_us);
        self
    }
}

/// How a preempted request's KV state comes back when it is re-admitted.
///
/// Chosen at preemption time by the engine's
/// [`crate::coordinator::ResumePolicy`] and carried through the admission
/// queue inside [`ResumeState`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResumeKind {
    /// KV blocks parked on the modeled host-transfer ledger
    /// (`sim/host_transfer.rs`): the request is ineligible for
    /// re-admission until the round trip completes at `ready_at_us`, then
    /// resumes decoding exactly where it stopped.
    Swapped { ready_at_us: u64 },
    /// KV blocks discarded: on re-admission the prompt is re-prefilled
    /// (chunked through the step composer, prefix-cache-assisted) and the
    /// already-delivered tokens are regenerated position-pure — the
    /// resumed stream stays byte-identical, already-emitted indices are
    /// not re-sent.
    Recompute,
}

impl ResumeKind {
    /// The trace-event tag for this kind.
    pub fn tag(&self) -> crate::obs::PreemptClass {
        match self {
            ResumeKind::Swapped { .. } => crate::obs::PreemptClass::Swap,
            ResumeKind::Recompute => crate::obs::PreemptClass::Recompute,
        }
    }
}

/// Everything a preempted request needs to continue after re-admission.
/// Boxed on [`TrackedRequest`] so the common never-preempted case pays
/// one `Option` discriminant, not the full struct.
#[derive(Debug)]
pub struct ResumeState {
    /// Tokens generated before preemption (moved out of the running
    /// state; keeps its `max_new_tokens` capacity across the round trip).
    pub(crate) generated: Vec<i32>,
    /// Prompt tokens whose KV existed at preemption time.
    pub(crate) prefilled: usize,
    /// Tokens already delivered to the request's stream — regenerated
    /// tokens below this index are suppressed so the stream never
    /// duplicates an index.
    pub(crate) emitted: usize,
    /// Original first-token stamp, restored so TTFT stays truthful.
    pub(crate) first_token_us: Option<u64>,
    /// Original admission stamp, restored so queue_us stays truthful.
    pub(crate) scheduled_us: u64,
    pub(crate) kind: ResumeKind,
}

/// A request plus its lifecycle ticket (what flows through admission).
#[derive(Debug)]
pub struct TrackedRequest {
    pub req: Request,
    pub(crate) ticket: Ticket,
    /// Present iff this request was preempted and is waiting to resume.
    pub(crate) resume: Option<Box<ResumeState>>,
}

impl TrackedRequest {
    /// The tracked request's id.
    pub fn id(&self) -> RequestId {
        self.req.id
    }

    /// The tracked request's priority class.
    pub fn priority(&self) -> Priority {
        self.ticket.priority
    }

    /// If this is a swap-parked resume, the engine-clock instant its
    /// host transfer completes (it may not re-admit earlier).
    pub(crate) fn resume_ready_at(&self) -> Option<u64> {
        match self.resume.as_deref() {
            Some(ResumeState { kind: ResumeKind::Swapped { ready_at_us }, .. }) => {
                Some(*ready_at_us)
            }
            _ => None,
        }
    }
}

/// Mint the (handle, ticket) pair for a submission.
pub(crate) fn handle_pair(id: RequestId, opts: &SubmitOptions) -> (RequestHandle, Ticket) {
    let (tx, rx) = mpsc::channel();
    let cancel = Arc::new(CancelCell::default());
    (
        RequestHandle { id, events: rx, cancel: cancel.clone() },
        Ticket {
            sink: StreamSink::new(tx),
            cancel,
            deadline_us: opts.deadline_us,
            priority: opts.priority,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_cancel_cause_wins() {
        let cell = CancelCell::default();
        assert!(!cell.is_cancelled());
        assert!(cell.cancel(CancelKind::Deadline));
        assert!(!cell.cancel(CancelKind::User));
        assert_eq!(cell.get(), Some(CancelKind::Deadline));
        assert_eq!(cell.get().unwrap().finish_reason(), FinishReason::DeadlineExceeded);
    }

    #[test]
    fn handle_streams_tokens_then_finish() {
        let (handle, ticket) = handle_pair(7, &SubmitOptions::default());
        assert_eq!(handle.id(), 7);
        ticket.sink.send(StreamEvent::Token { token: 11, index: 0, emitted_us: 5 });
        ticket.sink.send(StreamEvent::Token { token: 12, index: 1, emitted_us: 9 });
        assert_eq!(handle.drain_tokens(), vec![11, 12]);
        ticket.sink.send(StreamEvent::Finished(FinishedRequest {
            id: 7,
            prompt_len: 3,
            tokens: vec![11, 12],
            reason: FinishReason::Length,
            priority: Priority::Standard,
            timing: Default::default(),
        }));
        drop(ticket);
        let fin = handle.wait().finished().expect("finished event");
        assert_eq!(fin.tokens, vec![11, 12]);
    }

    #[test]
    fn cancel_flows_from_handle_to_ticket() {
        let (handle, ticket) = handle_pair(1, &SubmitOptions::default());
        handle.cancel();
        assert_eq!(ticket.cancel.get(), Some(CancelKind::User));
    }

    #[test]
    fn dropped_handle_does_not_wedge_the_sink() {
        let (handle, ticket) = handle_pair(1, &SubmitOptions::default());
        drop(handle);
        ticket.sink.send(StreamEvent::Token { token: 1, index: 0, emitted_us: 0 });
    }

    #[test]
    fn deadline_applies_to_ticket() {
        let opts = SubmitOptions::default().deadline_us(100).priority(Priority::Interactive);
        let (_h, ticket) = handle_pair(1, &opts);
        assert_eq!(ticket.priority, Priority::Interactive);
        assert!(!ticket.past_deadline(99));
        assert!(ticket.past_deadline(100));
    }
}
