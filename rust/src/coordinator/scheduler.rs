//! The decode scheduler: where the paper's contribution meets the engine.
//!
//! Each decode step, the scheduler derives the live attention shape from
//! the running batch (max KV length across rows, bucketed to the artifact
//! grid), asks the configured [`SplitPolicy`] for scheduler metadata —
//! exactly FA3's `get_scheduler_metadata()` deployment path — and routes
//! to the AOT artifact compiled for that (bucket, num_splits).

use anyhow::{Context, Result};

use crate::heuristics::tiles::DecodeShape;
use crate::heuristics::{SchedulerMetadata, SplitPolicy};

/// Model attention geometry the scheduler needs (from the manifest).
#[derive(Debug, Clone, Copy)]
pub struct AttnGeometry {
    pub h_q: usize,
    pub h_kv: usize,
    pub d: usize,
    pub max_seq: usize,
}

/// The split decision for one engine step.
#[derive(Debug, Clone)]
pub struct StepDecision {
    /// Metadata handed to the launch (the paper's precomputed-metadata path).
    pub metadata: SchedulerMetadata,
    /// Split count actually requested from the artifact registry (the
    /// metadata's num_splits snapped onto the compiled split variants).
    pub artifact_splits: usize,
}

/// Per-step split scheduler.
pub struct DecodeScheduler {
    policy: Box<dyn SplitPolicy>,
    geometry: AttnGeometry,
    /// Split variants the artifact set was compiled with (ascending).
    available_splits: Vec<usize>,
    pub sm_margin: usize,
    pub pack_gqa: bool,
}

impl DecodeScheduler {
    pub fn new(
        policy: Box<dyn SplitPolicy>,
        geometry: AttnGeometry,
        mut available_splits: Vec<usize>,
    ) -> DecodeScheduler {
        assert!(!available_splits.is_empty(), "no split variants available");
        available_splits.sort_unstable();
        assert_eq!(available_splits[0], 1, "s = 1 variant must exist");
        DecodeScheduler { policy, geometry, available_splits, sm_margin: 0, pack_gqa: true }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Decide the split schedule for a decode step over `batch` rows whose
    /// longest row attends over `max_kv_len` cache entries.
    pub fn decide(&self, batch: usize, max_kv_len: usize) -> Result<StepDecision> {
        let l_k = max_kv_len.min(self.geometry.max_seq).max(1);
        let shape =
            DecodeShape::decode(batch, l_k, self.geometry.h_q, self.geometry.h_kv, self.geometry.d);
        let metadata = self.policy.metadata(&shape, self.sm_margin, self.pack_gqa);
        let artifact_splits = self.snap_splits(metadata.num_splits);
        Ok(StepDecision { metadata, artifact_splits })
    }

    /// Snap the policy's split count onto the compiled variants: the
    /// largest available split <= requested (falling back to 1). Static
    /// artifact grids can't realize arbitrary s — same constraint as
    /// CUDA-Graph-captured kernels in vLLM.
    fn snap_splits(&self, requested: usize) -> usize {
        self.available_splits
            .iter()
            .copied()
            .filter(|&s| s <= requested)
            .next_back()
            .unwrap_or(1)
    }

    pub fn geometry(&self) -> AttnGeometry {
        self.geometry
    }

    pub fn available_splits(&self) -> &[usize] {
        &self.available_splits
    }
}

impl std::fmt::Debug for DecodeScheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DecodeScheduler")
            .field("policy", &self.policy.name())
            .field("geometry", &self.geometry)
            .field("available_splits", &self.available_splits)
            .finish()
    }
}

/// Build the scheduler from a loaded manifest (geometry + split variants
/// come from the artifacts themselves, so engine and artifacts can't skew).
pub fn scheduler_from_manifest(
    manifest: &crate::runtime::Manifest,
    policy: Box<dyn SplitPolicy>,
) -> Result<DecodeScheduler> {
    let model = manifest.model.as_ref().context("manifest has no model block")?;
    let geometry = AttnGeometry {
        h_q: model.config.n_heads_q,
        h_kv: model.config.n_heads_kv,
        d: model.config.head_dim,
        max_seq: model.config.max_seq,
    };
    let mut splits: Vec<usize> = manifest
        .entries
        .iter()
        .filter(|e| e.kind == crate::runtime::ArtifactKind::Decode)
        .filter_map(|e| e.meta.num_splits)
        .collect();
    splits.sort_unstable();
    splits.dedup();
    Ok(DecodeScheduler::new(policy, geometry, splits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::{SequenceAwarePolicy, StandardPolicy};

    fn geom() -> AttnGeometry {
        AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 }
    }

    #[test]
    fn patched_policy_splits_in_boundary_bucket() {
        let s = DecodeScheduler::new(Box::new(SequenceAwarePolicy), geom(), vec![1, 3]);
        let d = s.decide(1, 512).unwrap();
        assert_eq!(d.metadata.num_splits, 3);
        assert_eq!(d.artifact_splits, 3);
        // Short context: unchanged.
        let d = s.decide(1, 384).unwrap();
        assert_eq!(d.metadata.num_splits, 1);
        assert_eq!(d.artifact_splits, 1);
    }

    #[test]
    fn standard_policy_never_splits_short() {
        let s = DecodeScheduler::new(Box::new(StandardPolicy), geom(), vec![1, 3]);
        for kv in [64, 128, 384, 512] {
            let d = s.decide(1, kv).unwrap();
            assert_eq!(d.artifact_splits, 1, "kv={kv}");
        }
    }

    #[test]
    fn snapping_caps_to_available_variants() {
        // Long context: the efficiency loop may ask for s = 8; with only
        // {1, 3} compiled, snap down to 3.
        let s = DecodeScheduler::new(Box::new(StandardPolicy), geom(), vec![1, 3]);
        let d = s.decide(1, 1024).unwrap(); // nblk = 8 > 4: loop engages
        assert!(d.metadata.num_splits > 1);
        assert_eq!(d.artifact_splits, 3);
    }

    #[test]
    fn kv_len_clamped_to_max_seq() {
        let s = DecodeScheduler::new(Box::new(SequenceAwarePolicy), geom(), vec![1, 3]);
        let d = s.decide(1, 4096).unwrap();
        assert_eq!(d.metadata.shape.l_k, 1024);
        let d0 = s.decide(1, 0).unwrap();
        assert_eq!(d0.metadata.shape.l_k, 1);
    }

    #[test]
    #[should_panic]
    fn requires_split_one_variant() {
        DecodeScheduler::new(Box::new(StandardPolicy), geom(), vec![3]);
    }
}
