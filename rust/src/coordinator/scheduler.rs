//! The decode scheduler: where the paper's contribution meets the engine.
//!
//! Each decode step, the scheduler derives the live attention shape from
//! the running batch (max KV length across rows, bucketed to the artifact
//! grid), asks its [`PlanCursor`] for a launch plan — exactly FA3's
//! `get_scheduler_metadata()` deployment path, ridden at zero cost:
//! decode monotonicity pins the decision until `L_K` crosses a horizon,
//! so the steady-state decide is a range check plus a metadata stamp (the
//! configured [`Planner`]'s LRU cache is the refill source) — and routes
//! to the AOT artifact compiled for that (bucket, num_splits). One cursor
//! per live decode-batch size, because the batch dimension is part of the
//! pinned shape.

use anyhow::Result;

use crate::heuristics::tiles::DecodeShape;
use crate::heuristics::SchedulerMetadata;
use crate::planner::{CursorStats, LaunchPlan, PlanCursor, Planner};

// The geometry now lives with the execution backends (a PJRT backend
// derives it from its own manifest and hands it up through
// `BackendTopology`); re-exported here because the scheduler is its main
// consumer.
pub use crate::backend::AttnGeometry;

/// The split decision for one engine step.
#[derive(Debug, Clone, Copy)]
pub struct StepDecision {
    /// The planner's launch plan (the paper's precomputed-metadata path).
    pub plan: LaunchPlan,
    /// The plan's num_splits snapped onto this scheduler's configured
    /// split variants — advisory, for consumers that inspect routing
    /// (benches, multi-queue schedulers). The engine ignores it: the
    /// execution backend re-snaps against its OWN compiled variants in
    /// `prepare`, which is the authoritative routing decision.
    pub artifact_splits: usize,
}

impl StepDecision {
    /// Metadata handed to the launch.
    pub fn metadata(&self) -> &SchedulerMetadata {
        &self.plan.metadata
    }
}

/// Per-step split scheduler.
pub struct DecodeScheduler {
    planner: Planner,
    geometry: AttnGeometry,
    /// Split variants the artifact set was compiled with (ascending).
    available_splits: Vec<usize>,
    /// One plan cursor per live decode-batch size (looked up linearly —
    /// engines run a handful of batch sizes). Grows once per first-seen
    /// batch size; steady-state decide never allocates.
    cursors: Vec<PlanCursor>,
    /// Scratch for `decide_batch_into` (shapes + plans reused across steps).
    shapes_scratch: Vec<DecodeShape>,
    plans_scratch: Vec<LaunchPlan>,
}

impl DecodeScheduler {
    /// A scheduler over `planner` for a fixed geometry and artifact split grid.
    pub fn new(
        planner: Planner,
        geometry: AttnGeometry,
        mut available_splits: Vec<usize>,
    ) -> DecodeScheduler {
        assert!(!available_splits.is_empty(), "no split variants available");
        available_splits.sort_unstable();
        assert_eq!(available_splits[0], 1, "s = 1 variant must exist");
        DecodeScheduler {
            planner,
            geometry,
            available_splits,
            cursors: Vec::new(),
            shapes_scratch: Vec::new(),
            plans_scratch: Vec::new(),
        }
    }

    /// The planner's policy name.
    pub fn policy_name(&self) -> &'static str {
        self.planner.name()
    }

    /// The underlying planner (read-only; cache/cursor stats).
    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    /// Decide the split schedule for a decode step over `batch` rows whose
    /// longest row attends over `max_kv_len` cache entries. Steady state
    /// (same batch size, `max_kv_len` inside the cursor's horizon) costs a
    /// range check and a metadata stamp; horizon crossings refill through
    /// the planner's LRU. Element-wise identical to planning every step
    /// from scratch (the cursor equivalence property).
    // pallas-lint: no_alloc
    pub fn decide(&mut self, batch: usize, max_kv_len: usize) -> Result<StepDecision> {
        self.decide_mixed(batch, 1, max_kv_len)
    }

    /// Generalized decision for a wave of `batch` rows of `l_q` query
    /// tokens each — the chunked-prefill (and, later, speculative-verify)
    /// regime where `q_len > 1` rows shift `m_blocks` and with it the
    /// occupancy the split policy reasons about. `l_q = 1` is exactly
    /// [`DecodeScheduler::decide`]. Rides the same [`PlanCursor`]
    /// machinery (both the plan-cache key and the cursor key already
    /// carry `l_q`); cursors are indexed on `(batch, l_q)` so chunk waves
    /// never thrash the decode cursors' pinned decisions.
    // pallas-lint: no_alloc
    pub fn decide_mixed(
        &mut self,
        batch: usize,
        l_q: usize,
        max_kv_len: usize,
    ) -> Result<StepDecision> {
        let shape = self.wave_shape(batch, l_q, max_kv_len);
        // Linear cursor lookup by live (batch, l_q); a fresh cursor keys
        // itself on its first refill inside `plan`.
        let idx = match self
            .cursors
            .iter()
            .position(|c| c.batch() == batch && c.l_q() == shape.l_q)
        {
            Some(idx) => idx,
            None => {
                self.cursors.push(self.planner.cursor());
                self.cursors.len() - 1
            }
        };
        let plan = self.cursors[idx].plan(&mut self.planner, &shape);
        let artifact_splits = self.snap_splits(plan.metadata.num_splits);
        Ok(StepDecision { plan, artifact_splits })
    }

    /// Batched variant into caller-owned scratch (cleared first): one
    /// entry per (batch, max_kv_len) bucket, element-wise identical to
    /// calling [`DecodeScheduler::decide`] per bucket (the planner
    /// guarantees batch planning ≡ per-shape `plan`, and the cursor is
    /// plan-identical by construction). The built-in engine forms a single
    /// bucket per step and uses `decide`; this is the entry point for
    /// schedulers that plan several buckets at once
    /// (multi-queue/disaggregated serving, and the `scheduler_throughput`
    /// bench).
    // pallas-lint: no_alloc
    pub fn decide_batch_into(
        &mut self,
        out: &mut Vec<StepDecision>,
        buckets: &[(usize, usize)],
    ) -> Result<()> {
        out.clear();
        let mut shapes = std::mem::take(&mut self.shapes_scratch);
        shapes.clear();
        shapes.extend(buckets.iter().map(|&(batch, max_kv)| self.step_shape(batch, max_kv)));
        let mut plans = std::mem::take(&mut self.plans_scratch);
        self.planner.plan_batch_into(&mut plans, &shapes);
        out.reserve(plans.len());
        for plan in &plans {
            let artifact_splits = self.snap_splits(plan.metadata.num_splits);
            out.push(StepDecision { plan: *plan, artifact_splits });
        }
        self.shapes_scratch = shapes;
        self.plans_scratch = plans;
        Ok(())
    }

    /// Allocating convenience over [`DecodeScheduler::decide_batch_into`].
    pub fn decide_batch(&mut self, buckets: &[(usize, usize)]) -> Result<Vec<StepDecision>> {
        let mut out = Vec::new();
        self.decide_batch_into(&mut out, buckets)?;
        Ok(out)
    }

    /// Aggregate hit/refill counters across this scheduler's cursors.
    pub fn cursor_stats(&self) -> CursorStats {
        let mut stats = CursorStats::default();
        for c in &self.cursors {
            stats.merge(c.stats());
        }
        stats
    }

    fn step_shape(&self, batch: usize, max_kv_len: usize) -> DecodeShape {
        self.wave_shape(batch, 1, max_kv_len)
    }

    /// The live attention shape for a `q_len = l_q` wave: `l_k` clamped to
    /// the artifact grid's `max_seq` (and to ≥ 1 — an empty cache still
    /// launches over one padded block), `l_q` clamped to ≥ 1 by
    /// [`DecodeShape::mixed`].
    fn wave_shape(&self, batch: usize, l_q: usize, max_kv_len: usize) -> DecodeShape {
        let l_k = max_kv_len.min(self.geometry.max_seq).max(1);
        DecodeShape::mixed(batch, l_q, l_k, self.geometry.h_q, self.geometry.h_kv, self.geometry.d)
    }

    /// Snap the policy's split count onto the compiled variants: the
    /// largest available split <= requested (falling back to 1). Static
    /// artifact grids can't realize arbitrary s — same constraint as
    /// CUDA-Graph-captured kernels in vLLM.
    fn snap_splits(&self, requested: usize) -> usize {
        self.available_splits
            .iter()
            .copied()
            .filter(|&s| s <= requested)
            .next_back()
            .unwrap_or(1)
    }

    /// The attention geometry this scheduler plans.
    pub fn geometry(&self) -> AttnGeometry {
        self.geometry
    }

    /// Split variants the artifact set was compiled with (ascending).
    pub fn available_splits(&self) -> &[usize] {
        &self.available_splits
    }
}

impl std::fmt::Debug for DecodeScheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DecodeScheduler")
            .field("planner", &self.planner)
            .field("geometry", &self.geometry)
            .field("available_splits", &self.available_splits)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::Planner;

    fn geom() -> AttnGeometry {
        AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 }
    }

    #[test]
    fn patched_policy_splits_in_boundary_bucket() {
        let mut s = DecodeScheduler::new(Planner::sequence_aware(), geom(), vec![1, 3]);
        let d = s.decide(1, 512).unwrap();
        assert_eq!(d.plan.metadata.num_splits, 3);
        assert_eq!(d.artifact_splits, 3);
        // Short context: unchanged.
        let d = s.decide(1, 384).unwrap();
        assert_eq!(d.plan.metadata.num_splits, 1);
        assert_eq!(d.artifact_splits, 1);
    }

    #[test]
    fn standard_policy_never_splits_short() {
        let mut s = DecodeScheduler::new(Planner::standard(), geom(), vec![1, 3]);
        for kv in [64, 128, 384, 512] {
            let d = s.decide(1, kv).unwrap();
            assert_eq!(d.artifact_splits, 1, "kv={kv}");
        }
    }

    #[test]
    fn snapping_caps_to_available_variants() {
        // Long context: the efficiency loop may ask for s = 8; with only
        // {1, 3} compiled, snap down to 3.
        let mut s = DecodeScheduler::new(Planner::standard(), geom(), vec![1, 3]);
        let d = s.decide(1, 1024).unwrap(); // nblk = 8 > 4: loop engages
        assert!(d.plan.metadata.num_splits > 1);
        assert_eq!(d.artifact_splits, 3);
    }

    #[test]
    fn kv_len_clamped_to_max_seq() {
        let mut s = DecodeScheduler::new(Planner::sequence_aware(), geom(), vec![1, 3]);
        let d = s.decide(1, 4096).unwrap();
        assert_eq!(d.plan.metadata.shape.l_k, 1024);
        let d0 = s.decide(1, 0).unwrap();
        assert_eq!(d0.plan.metadata.shape.l_k, 1);
    }

    #[test]
    fn repeated_steps_ride_the_plan_cursor() {
        let mut s = DecodeScheduler::new(Planner::sequence_aware(), geom(), vec![1, 3]);
        for kv in 400..=512 {
            s.decide(1, kv).unwrap();
        }
        // One refill at kv=400 pins the nblk=4 decision through 512; every
        // later step is a cursor hit that never reaches the LRU.
        let cursor = s.cursor_stats();
        assert_eq!(cursor.refills, 1, "{cursor:?}");
        assert_eq!(cursor.hits, 112, "{cursor:?}");
        let cache = s.planner().cache_stats();
        assert_eq!(cache.misses, 1, "{cache:?}"); // the refill's cold lookup
        assert_eq!(cache.hits, 0, "cursor shields the LRU: {cache:?}");
    }

    #[test]
    fn per_batch_cursors_do_not_thrash_each_other() {
        // Alternating decode-batch sizes (two live buckets, the fleet
        // steady state) must each ride their own cursor.
        let mut s = DecodeScheduler::new(Planner::sequence_aware(), geom(), vec![1, 3]);
        let mut oracle = Planner::sequence_aware();
        for i in 0..64usize {
            let batch = 1 + (i & 1);
            let kv = 400 + i / 2;
            let got = s.decide(batch, kv).unwrap();
            let want = oracle.plan(&DecodeShape::decode(batch, kv, 8, 1, 128));
            assert_eq!(got.plan, want, "i={i}");
        }
        let cursor = s.cursor_stats();
        assert_eq!(cursor.refills, 2, "one per batch size: {cursor:?}");
        assert_eq!(cursor.hits, 62, "{cursor:?}");
    }

    #[test]
    fn mixed_waves_ride_their_own_cursor() {
        // Interleaving a decode wave (l_q = 1) with a chunk wave (l_q = 64)
        // at the same batch size must not thrash either cursor: the lookup
        // keys on (batch, l_q).
        let mut s = DecodeScheduler::new(Planner::sequence_aware(), geom(), vec![1, 3]);
        let mut oracle = Planner::sequence_aware();
        for i in 0..32usize {
            let kv = 400 + i;
            let d = s.decide(1, kv).unwrap();
            assert_eq!(d.plan, oracle.plan(&DecodeShape::decode(1, kv, 8, 1, 128)), "i={i}");
            let m = s.decide_mixed(1, 64, kv).unwrap();
            assert_eq!(m.plan, oracle.plan(&DecodeShape::mixed(1, 64, kv, 8, 1, 128)), "i={i}");
        }
        let cursor = s.cursor_stats();
        assert_eq!(cursor.refills, 2, "one per (batch, l_q): {cursor:?}");
        assert_eq!(cursor.hits, 62, "{cursor:?}");
    }

    #[test]
    fn decide_mixed_lq_one_is_decide() {
        let mut a = DecodeScheduler::new(Planner::sequence_aware(), geom(), vec![1, 3]);
        let mut b = DecodeScheduler::new(Planner::sequence_aware(), geom(), vec![1, 3]);
        for kv in [64usize, 385, 512, 1024] {
            let via_decide = a.decide(2, kv).unwrap();
            let via_mixed = b.decide_mixed(2, 1, kv).unwrap();
            assert_eq!(via_decide.plan, via_mixed.plan, "kv={kv}");
            assert_eq!(via_decide.artifact_splits, via_mixed.artifact_splits);
        }
        // l_q = 0 clamps to 1: same cursor as decode, no phantom extra key
        // (kv stays inside the window the kv=1024 step pinned).
        b.decide_mixed(2, 0, 1024).unwrap();
        assert_eq!(b.cursor_stats().refills, a.cursor_stats().refills);
    }

    #[test]
    fn decide_batch_matches_decide() {
        let buckets = [(1usize, 512usize), (2, 512), (1, 1024), (1, 512)];
        let mut a = DecodeScheduler::new(Planner::sequence_aware(), geom(), vec![1, 3]);
        let batch = a.decide_batch(&buckets).unwrap();
        let mut b = DecodeScheduler::new(Planner::sequence_aware(), geom(), vec![1, 3]);
        for (i, &(n, kv)) in buckets.iter().enumerate() {
            let single = b.decide(n, kv).unwrap();
            assert_eq!(batch[i].plan, single.plan, "bucket {i}");
            assert_eq!(batch[i].artifact_splits, single.artifact_splits);
        }
    }

    #[test]
    #[should_panic]
    fn requires_split_one_variant() {
        DecodeScheduler::new(Planner::standard(), geom(), vec![3]);
    }
}
