//! Layer 3: the serving coordinator (vLLM-shaped).
//!
//! The paper's improvement only materializes on the *precomputed scheduler
//! metadata* path (§5.1) — the path where an inference stack decides
//! `num_splits` before launch. This module is that stack: a continuous-
//! batching decode engine whose per-step scheduler asks the configured
//! [`crate::planner::Planner`] for a (cached) launch plan derived from the
//! live batch shape and routes each step to the matching AOT artifact.
//!
//! * [`request`]  — request/response types and lifecycle timing,
//! * [`kv_cache`] — paged KV block manager (admission + capacity),
//! * [`batcher`]  — continuous batcher (FCFS admission, bucket packing),
//! * [`scheduler`]— per-step split decision + artifact routing,
//! * [`engine`]   — the serving loop over the PJRT runtime or the H100
//!                  simulator backend,
//! * [`metrics`]  — TTFT/TPOT/throughput accounting.

pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod scheduler;

pub use batcher::{Batcher, BatcherConfig, StepPlan};
pub use engine::{Engine, EngineBackend, EngineConfig};
pub use kv_cache::{BlockManager, BlockManagerConfig};
pub use metrics::{EngineMetrics, RequestTiming};
pub use request::{FinishReason, FinishedRequest, Request, RequestId};
pub use scheduler::{DecodeScheduler, StepDecision};
