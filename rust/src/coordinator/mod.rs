//! Layer 3: the serving coordinator (vLLM-shaped).
//!
//! The paper's improvement only materializes on the *precomputed scheduler
//! metadata* path (§5.1) — the path where an inference stack decides
//! `num_splits` before launch. This module is that stack: a continuous-
//! batching decode engine whose per-step scheduler asks the configured
//! [`crate::planner::Planner`] for a (cached) launch plan derived from the
//! live batch shape, and whose execution is delegated entirely to a
//! [`crate::backend::ExecutionBackend`] (sim, PJRT, or replay — the
//! coordinator never knows which).
//!
//! * [`request`]  — request/response types and lifecycle timing,
//! * [`lifecycle`]— streaming [`RequestHandle`]s, per-request cancellation,
//!                  deadlines, priority classes,
//! * [`admission`]— bounded priority queues with explicit [`Backpressure`],
//! * [`kv_cache`] — prefix-sharing paged KV block manager (budget +
//!                  capacity + content-hashed block reuse with
//!                  copy-on-write),
//! * [`batcher`]  — the running set (slots, bucket packing),
//! * [`scheduler`]— per-step split decision (planner metadata path),
//! * [`engine`]   — the step loop over the execution backend,
//! * [`metrics`]  — TTFT/TPOT/throughput/cancellation accounting.

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod lifecycle;
pub mod metrics;
pub mod request;
pub mod scheduler;

pub use admission::{AdmissionConfig, AdmissionController, AdmissionStats, Backpressure, SubmitError};
pub use batcher::{Batcher, BatcherConfig, StepPlan};
pub use engine::{
    Engine, EngineBuilder, EngineConfig, EngineHandle, PreemptionConfig, ResumePolicy,
};
pub use kv_cache::{
    AdmitGrant, BlockId, BlockManager, BlockManagerConfig, PrefixCacheStats, PrefixProbe,
};
pub use lifecycle::{
    CancelKind, Priority, RequestHandle, ResumeKind, StreamEvent, SubmitOptions, WaitOutcome,
};
pub use metrics::{EngineMetrics, RequestTiming, SloConfig};
pub use request::{FinishReason, FinishedRequest, Request, RequestId};
pub use scheduler::{AttnGeometry, DecodeScheduler, StepDecision};
