//! Request/response types for the decode engine.

use super::lifecycle::{ResumeKind, ResumeState, Ticket};

/// Engine-assigned request identifier.
pub type RequestId = u64;

/// An incoming generation request. Prompts are token ids (the synthetic
/// serving model has no tokenizer — clients send ids directly).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Arrival timestamp, µs since engine start (set by the engine).
    pub arrival_us: u64,
}

impl Request {
    /// A request with `arrival_us` unset (the engine stamps it at submit).
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, arrival_us: 0 }
    }
}

/// Why a request finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated `max_new_tokens`.
    Length,
    /// KV cache would exceed the model's max_seq.
    CacheFull,
    /// Cancelled through its `RequestHandle` (or `Engine::cancel`).
    Cancelled,
    /// The request's deadline elapsed before completion.
    DeadlineExceeded,
    /// Engine shutdown (`abort_all`) before completion.
    Aborted,
}

impl FinishReason {
    /// Did the request run to a natural completion (vs being cut short)?
    pub fn is_natural(self) -> bool {
        matches!(self, FinishReason::Length | FinishReason::CacheFull)
    }
}

/// A completed request with its generation and timing.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub reason: FinishReason,
    /// Admission class the request ran under (mixed-load drivers split
    /// TTFT/TPOT by class).
    pub priority: super::lifecycle::Priority,
    pub timing: super::metrics::RequestTiming,
}

/// Internal per-request state while scheduled.
#[derive(Debug)]
pub(crate) struct RunningRequest {
    pub req: Request,
    /// Lifecycle ticket: stream sink, cancel cell, deadline, priority.
    pub ticket: Ticket,
    /// Generated tokens so far.
    pub generated: Vec<i32>,
    /// Tokens of the prompt already ingested into the KV cache.
    pub prefilled: usize,
    /// Leading prompt tokens whose KV already existed at admission (the
    /// prefix-cache grant): prefill charges only the remainder, and the
    /// engine performs the pending copy-on-write fork at this request's
    /// first generated token.
    pub cached_prompt_tokens: usize,
    /// Row in the backend's KV cache store.
    pub slot: usize,
    /// µs timestamp of first generated token (TTFT), if any.
    pub first_token_us: Option<u64>,
    /// µs timestamp when scheduling started.
    pub scheduled_us: u64,
    /// Tokens already delivered on the stream. Trails `generated.len()`
    /// only while a recompute-resume regenerates history: indices below
    /// this are suppressed so the stream never duplicates an index.
    pub emitted: usize,
    /// Set when this running state was restored from a preemption
    /// (consumed by the engine's post-admission pass for Resume events
    /// and counters).
    pub resumed: Option<crate::obs::PreemptClass>,
}

impl RunningRequest {
    /// Install a request into `slot`, pre-sizing its token buffer.
    pub fn new(req: Request, ticket: Ticket, slot: usize, now_us: u64) -> RunningRequest {
        // Reserve the full generation up front (admission already
        // reserved the worst-case KV budget, so max_new_tokens is bounded
        // by max_seq): steady-state decode pushes never regrow this Vec —
        // part of the zero-allocation step-loop contract.
        let generated = Vec::with_capacity(req.max_new_tokens);
        RunningRequest {
            req,
            ticket,
            generated,
            prefilled: 0,
            cached_prompt_tokens: 0,
            slot,
            first_token_us: None,
            scheduled_us: now_us,
            emitted: 0,
            resumed: None,
        }
    }

    /// Restore state carried across a preemption. Swap resumes continue
    /// exactly where they stopped (their KV is back after the modeled
    /// host round trip); recompute resumes keep only the stream ledger
    /// and re-derive KV from scratch — the prompt re-prefills and the
    /// generated tokens replay position-pure, so the visible stream is
    /// unchanged. Timing stamps are restored so TTFT/queue_us stay
    /// truthful across the round trip.
    pub fn restore(&mut self, rs: ResumeState) {
        self.emitted = rs.emitted;
        self.first_token_us = rs.first_token_us;
        self.scheduled_us = rs.scheduled_us;
        self.resumed = Some(rs.kind.tag());
        match rs.kind {
            ResumeKind::Swapped { .. } => {
                self.prefilled = rs.prefilled;
                self.generated = rs.generated;
            }
            ResumeKind::Recompute => {
                self.prefilled = 0;
                // Keep the buffer (and its max_new capacity); regeneration
                // refills it with the same position-pure tokens.
                self.generated = rs.generated;
                self.generated.clear();
            }
        }
    }

    /// Current KV length: ingested prompt + generated tokens.
    pub fn kv_len(&self) -> usize {
        self.prefilled + self.generated.len()
    }

    /// Whether the whole prompt has been ingested.
    pub fn prompt_done(&self) -> bool {
        self.prefilled >= self.req.prompt.len()
    }

    /// Whether the request has also generated all its tokens.
    pub fn done(&self) -> bool {
        self.prompt_done() && self.generated.len() >= self.req.max_new_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lifecycle::SubmitOptions;

    #[test]
    fn lifecycle_counters() {
        let req = Request::new(1, vec![5, 6, 7], 2);
        let ticket = Ticket::detached(&SubmitOptions::default());
        let mut run = RunningRequest::new(req, ticket, 0, 100);
        assert_eq!(run.kv_len(), 0);
        assert!(!run.prompt_done());
        run.prefilled = 3;
        assert!(run.prompt_done());
        assert!(!run.done());
        run.generated.push(9);
        run.generated.push(10);
        assert!(run.done());
        assert_eq!(run.kv_len(), 5);
    }

    #[test]
    fn natural_vs_cut_short() {
        assert!(FinishReason::Length.is_natural());
        assert!(FinishReason::CacheFull.is_natural());
        assert!(!FinishReason::Cancelled.is_natural());
        assert!(!FinishReason::DeadlineExceeded.is_natural());
        assert!(!FinishReason::Aborted.is_natural());
    }
}
